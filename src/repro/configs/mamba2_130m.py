"""Mamba-2 130M [arXiv:2405.21060]: attention-free SSD stack."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50_280,
    d_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    pattern=("mamba",), tie_embeddings=True,
))

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, vocab=512, d_state=16, ssm_headdim=16,
    ssm_chunk=8)
