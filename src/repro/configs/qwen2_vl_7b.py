"""Qwen2-VL 7B [arXiv:2409.12191]: qwen2-7b backbone with M-RoPE.
Vision frontend is a stub (input_specs supplies patch embeddings)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152_064,
    act="silu", qkv_bias=True, pos="mrope", mrope_sections=(16, 24, 24),
    n_vision_tokens=256, pattern=("global",),
    rope_theta=1_000_000.0, tie_embeddings=False,
))

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, n_vision_tokens=4, mrope_sections=(2, 3, 3))
