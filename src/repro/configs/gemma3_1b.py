"""Gemma-3 1B [hf:google/gemma-3-1b-pt]: 5:1 local:global attention,
window 512, 1 KV head.  26 = 4 full super-blocks + 2 tail layers."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262_144,
    act="gelu", norm="rmsnorm", norm_offset=True,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=512, rope_theta=1_000_000.0, tie_embeddings=True,
))

SMOKE = CONFIG.scaled(
    n_layers=6, d_model=64, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512, window=8)
