"""Whisper base [arXiv:2212.04356]: encoder-decoder; conv audio frontend
is a stub (input_specs supplies 1500 precomputed frame embeddings)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, enc_seq=1500,
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51_865,
    act="gelu", norm="layernorm", pos="learned",
    pattern=("global",), tie_embeddings=True,
))

SMOKE = CONFIG.scaled(
    n_layers=2, enc_layers=2, enc_seq=16, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=512)
