"""Mixtral 8x22B [arXiv:2401.04088]: 8-expert top-2 MoE with
sliding-window attention (per assignment brief)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32_768,
    n_experts=8, top_k=2, capacity_factor=1.25,
    act="silu", pattern=("local",), window=4096,
    rope_theta=1_000_000.0, tie_embeddings=False,
))

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=512, n_experts=4, top_k=2, window=8)
