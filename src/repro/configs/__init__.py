"""Architecture registry: importing this package registers all configs."""
from . import base
from .base import ModelConfig, SHAPES, cells, get, names, register
from . import (deepseek_67b, gemma2_27b, gemma3_1b, granite_moe_1b,
               mamba2_130m, mixtral_8x22b, qwen2_7b, qwen2_vl_7b,
               whisper_base, zamba2_1b)

ALL = {
    m.CONFIG.name: m for m in (
        gemma2_27b, deepseek_67b, gemma3_1b, qwen2_7b, mixtral_8x22b,
        granite_moe_1b, whisper_base, qwen2_vl_7b, zamba2_1b, mamba2_130m)
}

SMOKES = {name: m.SMOKE for name, m in ALL.items()}
