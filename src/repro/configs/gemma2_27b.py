"""Gemma-2 27B [arXiv:2408.00118]: local+global alternating attention,
logit soft-capping, GeGLU, RMSNorm with (1+w) offset."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256_000,
    act="gelu", norm="rmsnorm", norm_offset=True,
    attn_softcap=50.0, final_softcap=30.0,
    pattern=("local", "global"), window=4096,
    rope_theta=10_000.0, tie_embeddings=True,
))

SMOKE = CONFIG.scaled(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, window=8)
