"""Model configuration schema + registry for the assigned architectures.

Every architecture is a frozen, hashable ``ModelConfig`` so configs can be
static jit arguments.  ``pattern`` describes one *super-block* -- the
repeating unit the transformer scans over (e.g. Gemma-2's
("local", "global") alternation); layers not covered by full super-blocks
form an unrolled tail (e.g. gemma3-1b's 26 = 4 x (5 local + 1 global) + 2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

_REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab: int = 1000

    act: str = "silu"            # silu | gelu
    qkv_bias: bool = False
    attn_softcap: float = 0.0    # gemma-2 logit soft-capping
    final_softcap: float = 0.0
    rope_theta: float = 10_000.0
    pos: str = "rope"            # rope | mrope | learned | none
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_offset: bool = False    # gemma (1 + w) RMSNorm convention
    pattern: Tuple[str, ...] = ("global",)   # global | local | mamba
    window: int = 4096           # sliding-window size for "local"

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD)
    d_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    shared_period: int = 0       # zamba2: shared attn block every k layers

    # Encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500          # whisper 30s window -> 1500 frames

    # VLM (qwen2-vl)
    n_vision_tokens: int = 0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    tie_embeddings: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # ----- derived -----
    @property
    def n_superblocks(self) -> int:
        return self.n_layers // max(len(self.pattern), 1)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        r = self.n_layers - self.n_superblocks * len(self.pattern)
        return self.pattern[:r]

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // max(self.ssm_headdim, 1)

    def scaled(self, **kw) -> "ModelConfig":
        """A reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from . import ALL  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list:
    from . import ALL  # noqa: F401
    return sorted(_REGISTRY)


# ----------------------------------------------------------------------
# Assigned input shapes (same four for every LM-family architecture).
# ----------------------------------------------------------------------
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# Archs allowed to run long_500k (sub-quadratic sequence mixing).
LONG_CONTEXT_OK = ("mamba2-130m", "zamba2-1.2b")


def cells():
    """All (arch, shape) dry-run cells with skip annotations."""
    out = []
    for arch in names():
        for shape, spec in SHAPES.items():
            skip = None
            if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
                skip = ("full-attention prefill is quadratic at 512k; "
                        "run reserved for SSM/hybrid archs per brief")
            out.append((arch, shape, spec, skip))
    return out
