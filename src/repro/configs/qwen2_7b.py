"""Qwen2 7B [arXiv:2407.10671]: GQA with QKV bias."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152_064,
    act="silu", qkv_bias=True, pattern=("global",),
    rope_theta=1_000_000.0, tie_embeddings=False,
))

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512)
