"""Zamba2 1.2B [arXiv:2411.15242]: Mamba-2 backbone with a shared
attention+MLP block every 6 layers (per-invocation LoRA simplified away;
see DESIGN.md)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32_000,
    d_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    pattern=("mamba",), shared_period=6,
    act="gelu", rope_theta=10_000.0, tie_embeddings=True,
))

SMOKE = CONFIG.scaled(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, d_state=16, ssm_headdim=16, ssm_chunk=8,
    shared_period=3)
