"""Granite 3.0 1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
fine-grained 32-expert top-8 MoE."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49_155,
    n_experts=32, top_k=8, capacity_factor=1.25,
    act="silu", pattern=("global",), rope_theta=10_000.0,
    tie_embeddings=True,
))

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab=512, n_experts=8, top_k=4)
