"""DeepSeek 67B [arXiv:2401.02954]: LLaMA-architecture dense decoder."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=102_400,
    act="silu", pattern=("global",), rope_theta=10_000.0,
    tie_embeddings=False,
))

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512)
