"""Shared model building blocks: norms, rotary embeddings, init helpers.

Pure-functional style: parameters are plain pytrees (nested dicts of
arrays); every block is ``apply(params, x, ...) -> y``.  Compute runs in
``cfg.compute_dtype`` (bf16) with fp32 softmax/norm statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# Initialisation
# ----------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / jnp.sqrt(jnp.maximum(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    std = shape[-1] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def rmsnorm(w, x, *, offset=False, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if offset else w.astype(jnp.float32)
    return (xf * scale).astype(dt)


def layernorm(params, x, *, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


def norm_apply(cfg, params, x):
    if cfg.norm == "layernorm":
        return layernorm(params, x)
    return rmsnorm(params["scale"], x, offset=cfg.norm_offset)


def norm_init(cfg, d, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    init = jnp.zeros if cfg.norm_offset else jnp.ones
    return {"scale": init((d,), dtype)}


# ----------------------------------------------------------------------
# Soft-capping (Gemma-2)
# ----------------------------------------------------------------------

def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ----------------------------------------------------------------------
# Rotary position embeddings (RoPE + Qwen2-VL M-RoPE)
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)          # [head_dim/2]


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    ang = ang[..., None, :]                                 # heads axis
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL multimodal RoPE: positions3 [3, ..., S] (t, h, w ids);
    the head_dim/2 frequency bands split into ``sections`` groups, each
    rotated by its own position stream."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                            # [D/2]
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    p = jnp.moveaxis(positions3, 0, -1)                     # [..., S, 3]
    band_pos = p[..., sec]                                  # [..., S, D/2]
    ang = band_pos.astype(jnp.float32) * freqs
    ang = ang[..., None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ----------------------------------------------------------------------
# Activation sharding anchors
# ----------------------------------------------------------------------

def _ambient_mesh():
    from jax._src import mesh as mesh_lib  # legacy `with mesh:` context
    env = mesh_lib.thread_resources.env.physical_mesh
    if not env.empty:
        return env
    am = jax.sharding.get_abstract_mesh()
    return None if (am is None or am.empty) else am


def shard_hint(x, batch_axis: int = 0, seq_axis: int = 1,
               sequence: bool = True):
    """Constrain a residual-stream activation [B, S, D]:
    batch over ("pod", "data") and -- sequence parallelism -- S over
    "model" where divisible.

    Without the batch anchor GSPMD may resolve the FSDP-weight /
    batch-sharding conflict by replicating activations and all-reducing
    [B, S, *] partials every layer (measured 10 TB/device on deepseek-67b
    train_4k).  Without the sequence anchor the residual stream is
    replicated across the model axis, so the per-layer saved activations
    of the backward pass cost model_parallel times more HBM (measured
    311 GB/device on the same cell), and every TP partial-sum becomes a
    full hidden-sized all-reduce instead of a reduce-scatter+all-gather
    pair.  Both anchors are divisibility-guarded no-ops when they cannot
    apply (e.g. decode steps with S == 1), and no-ops outside a mesh
    context (single-device smoke tests).
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while axes:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if x.shape[batch_axis] % size == 0:
            break
        axes = axes[1:]  # drop "pod" first, then give up
    spec = [None] * x.ndim
    if axes:
        spec[batch_axis] = axes if len(axes) > 1 else axes[0]
    if (sequence and x.ndim >= 3 and "model" in mesh.axis_names
            and x.shape[seq_axis] % mesh.shape["model"] == 0):
        spec[seq_axis] = "model"
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))
