"""Mamba-2 (SSD, state-space duality) blocks -- arXiv:2405.21060.

Chunked SSD algorithm (the paper's Listing 1, re-expressed in jnp):
sequence split into chunks of Q tokens; within a chunk the quadratic
"attention-like" form runs on the MXU; across chunks a scan carries the
[H, P, N] state.  This is the same split the Pallas kernel
(repro.kernels.ssd_scan) tiles into VMEM; this module is its oracle and
the XLA execution path.

Decode keeps a constant-size recurrent state per layer:
  state <- state * exp(dt * A) + dt * B outer x ;  y = C . state
so 500k-token contexts cost O(1) memory/step (the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, rmsnorm


def d_inner(cfg):
    return cfg.ssm_expand * cfg.d_model


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    di = d_inner(cfg)
    h = cfg.ssm_heads          # di // headdim
    n = cfg.d_state
    ks = jax.random.split(key, 5)
    # in_proj emits [z (di), x (di), B (N), C (N), dt (H)]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), d, dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, di + 2 * n),
                             cfg.conv_width, dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),           # A = -exp(a_log)
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), dtype),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), di, dtype),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv, width W.  x: [B, S, C]; w: [W, C].

    conv_state: [B, W-1, C] history for decode; returns (y, new_state).
    """
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else \
        jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return jax.nn.silu(y + b), new_state


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int,
                return_state: bool = False):
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H] (>0); a: [H] (<0);
    b_mat, c_mat: [B, S, N] (single B/C group shared over heads).
    Returns y: [B, S, H, P], or (y, final_state [B,H,P,N]) when
    ``return_state`` (prefill filling a decode cache).
    """
    bs, s0, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s0)
    s = -(-s0 // q) * q
    if s != s0:  # pad to a chunk multiple (dt=0 -> identity transition)
        pad = ((0, 0), (0, s - s0))
        x = jnp.pad(x, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        b_mat = jnp.pad(b_mat, pad + ((0, 0),))
        c_mat = jnp.pad(c_mat, pad + ((0, 0),))
    nc = s // q
    f32 = jnp.float32

    xr = jnp.moveaxis(x.reshape(bs, nc, q, h, p), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(bs, nc, q, h).astype(f32), 1, 0)
    br = jnp.moveaxis(b_mat.reshape(bs, nc, q, n), 1, 0)
    cr = jnp.moveaxis(c_mat.reshape(bs, nc, q, n), 1, 0)

    causal = jnp.tril(jnp.ones((q, q), bool))

    def chunk_body(state, inp):
        """Sequential over chunks; remat'd so the backward recomputes the
        quadratic intra-chunk tensors per chunk instead of storing all of
        them (the [B, nc, Q, Q, H] decay tensor dominates memory
        otherwise)."""
        xc, dtc, bc, cc = inp        # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        da = dtc * a                                 # [B,Q,H] (<0)
        cum = jnp.cumsum(da, axis=1)
        seg_end = cum[:, -1, :]                      # [B,H]

        # intra-chunk (quadratic within Q)
        cb = jnp.einsum("bqn,bkn->bqk", cc, bc,
                        preferred_element_type=f32)  # [B,Q,K]
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        dec = jnp.where(causal[None, :, :, None], dec, 0.0)
        w = cb[..., None] * dec * dtc[:, None, :, :]  # [B,Q,K,H]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w.astype(x.dtype), xc,
                             preferred_element_type=f32)

        # contribution of the carried state
        dec_q = jnp.exp(cum)                         # [B,Q,H]
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cc,
                             state.astype(x.dtype),
                             preferred_element_type=f32) * dec_q[..., None]

        # state update: S <- S * exp(seg_end) + sum_k decay_k dt_k B_k x_k
        decay_to_end = jnp.exp(seg_end[:, None, :] - cum)     # [B,Q,H]
        wk = (decay_to_end * dtc).astype(x.dtype)
        s_c = jnp.einsum("bqn,bqh,bqhp->bhpn", bc, wk, xc,
                         preferred_element_type=f32)
        new_state = state * jnp.exp(seg_end)[:, :, None, None] + s_c
        return new_state, (y_intra + y_inter).astype(x.dtype)

    init = jnp.zeros((bs, h, p, n), f32)
    final_state, y = jax.lax.scan(jax.checkpoint(chunk_body), init,
                                  (xr, dtr, br, cr))
    y = jnp.moveaxis(y, 0, 1).reshape(bs, s, h, p)[:, :s0]
    if return_state:
        return y, final_state
    return y


def mamba_block(params, cfg, x, cache=None):
    """x: [B, S, D] -> (y, new_cache).

    cache: None or dict(conv [B,W-1,C], ssm [B,H,P,N]) for decode (S==1).
    """
    bs, s, d = x.shape
    di = d_inner(cfg)
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.d_state
    cdt = x.dtype

    zxbcdt = x @ params["in_proj"].astype(cdt)
    z, xin, b_mat, c_mat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    conv_out, new_conv = _causal_conv(
        conv_in, params["conv_w"].astype(cdt), params["conv_b"].astype(cdt),
        conv_state)
    xin, b_mat, c_mat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                      # [H] < 0
    xh = xin.reshape(bs, s, h, p)

    if cache is None:
        y = ssd_chunked(xh, dt, a, b_mat, c_mat, cfg.ssm_chunk)
        new_ssm = None
    elif s > 1:
        # prefill into a decode cache: chunked scan + final state
        y, new_ssm = ssd_chunked(xh, dt, a, b_mat, c_mat, cfg.ssm_chunk,
                                 return_state=True)
    else:
        # single-step recurrence (S == 1)
        state = cache["ssm"].astype(jnp.float32)       # [B,H,P,N]
        dt1 = dt[:, 0]                                 # [B,H]
        g = jnp.exp(dt1 * a)                           # [B,H]
        bx = jnp.einsum("bn,bh,bhp->bhpn", b_mat[:, 0].astype(jnp.float32),
                        dt1, xh[:, 0].astype(jnp.float32))
        state = state * g[:, :, None, None] + bx
        y1 = jnp.einsum("bn,bhpn->bhp", c_mat[:, 0].astype(jnp.float32),
                        state)
        y = y1[:, None].astype(cdt)
        new_ssm = state
    y = y + xh * params["d_skip"].astype(cdt)[:, None]  # D skip (per head)
    y = y.reshape(bs, s, di)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y)
    out = y @ params["out_proj"].astype(cdt)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_ssm}
    return out, new_cache


def init_mamba_cache(cfg, batch: int, n_layers: int, dtype):
    di = d_inner(cfg)
    c = di + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1, c), dtype),
        "ssm": jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_headdim,
                          cfg.d_state), jnp.float32),
    }
