"""Public model API: one constructor for all 10 assigned architectures.

``make(cfg)`` returns a ``ModelApi`` with pure functions:

  init(key) -> params
  loss(params, batch) -> scalar            (training objective, remat'd)
  prefill(params, batch) -> (last_logits, cache)
  decode(params, cache, batch) -> (logits, cache)   one new token
  init_cache(batch, max_len, dtype) -> cache pytree
  input_specs(kind, batch, seq) -> batch dict of ShapeDtypeStruct

Batch layouts per family:
  dense/moe/ssm/hybrid: tokens [B,S] (+ targets for loss)
  vlm:    tokens [B,S-nvis], vision_embeds [B,nvis,D], positions3 [3,B,S]
  encdec: audio_embed [B,enc_seq,D], tokens [B,S]
  decode adds: tokens [B,1] (+ positions3 [3,B,1] for vlm) and
  cache_index: [] i32 (current cache fill; the new token writes there).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import transformer as tf
from . import whisper as wh
from . import mamba2 as mamba_mod


class ModelApi(NamedTuple):
    cfg: Any
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    input_specs: Callable


def _positions(batch, seq, cache_index=0):
    return cache_index + jnp.broadcast_to(jnp.arange(seq)[None],
                                          (batch, seq))


# ----------------------------------------------------------------------
# Decoder-only families (dense / moe / ssm / hybrid / vlm)
# ----------------------------------------------------------------------

def _decoder_api(cfg) -> ModelApi:
    is_vlm = cfg.family == "vlm"
    nvis = cfg.n_vision_tokens if is_vlm else 0

    def embed_inputs(params, batch):
        x = tf.embed(params, cfg, batch["tokens"])
        if is_vlm:
            cdt = x.dtype
            x = jnp.concatenate(
                [batch["vision_embeds"].astype(cdt), x], axis=1)
            pos = batch["positions3"]
        else:
            b, s = batch["tokens"].shape
            pos = _positions(b, s, batch.get("cache_index", 0))
        return x, pos

    def loss(params, batch):
        x, pos = embed_inputs(params, batch)
        hidden, _ = tf.hidden_states(params, cfg, x, pos, remat=True)
        mask = batch.get("mask")
        if is_vlm and mask is None:
            b, s = hidden.shape[:2]
            mask = jnp.concatenate(
                [jnp.zeros((b, nvis), jnp.float32),
                 jnp.ones((b, s - nvis), jnp.float32)], axis=1)
        return tf.lm_loss(params, cfg, hidden, batch["targets"], mask)

    def prefill(params, batch):
        cache = batch["cache"]
        x, pos = embed_inputs(params, batch)
        hidden, cache = tf.hidden_states(params, cfg, x, pos, cache=cache,
                                         cache_index=0)
        lg = tf.logits(params, cfg, hidden[:, -1:])
        return lg, cache

    def decode(params, cache, batch):
        ci = batch["cache_index"]
        x = tf.embed(params, cfg, batch["tokens"])
        if is_vlm:
            pos = batch["positions3"]
        else:
            b, s = batch["tokens"].shape
            pos = _positions(b, s, ci)
        hidden, cache = tf.hidden_states(params, cfg, x, pos, cache=cache,
                                         cache_index=ci)
        return tf.logits(params, cfg, hidden), cache

    def init_cache(batch, max_len, dtype=jnp.bfloat16):
        return tf.init_caches(cfg, batch, max_len, dtype)

    def input_specs(kind, batch, seq):
        i32 = jnp.int32
        cdt = jnp.dtype(cfg.compute_dtype)
        sds = jax.ShapeDtypeStruct
        if is_vlm:
            base = {
                "tokens": sds((batch, seq - nvis), i32),
                "vision_embeds": sds((batch, nvis, cfg.d_model), cdt),
                "positions3": sds((3, batch, seq), i32),
            }
        else:
            base = {"tokens": sds((batch, seq), i32)}
        if kind == "train":
            base["targets"] = sds(
                (batch, seq if is_vlm else seq), i32)
            return base
        if kind == "prefill":
            return base
        if kind == "decode":
            d = {"tokens": sds((batch, 1), i32),
                 "cache_index": sds((), i32)}
            if is_vlm:
                d["positions3"] = sds((3, batch, 1), i32)
            return d
        raise ValueError(kind)

    return ModelApi(cfg, lambda key: tf.init_lm(key, cfg), loss, prefill,
                    decode, init_cache, input_specs)


# ----------------------------------------------------------------------
# Encoder-decoder (whisper)
# ----------------------------------------------------------------------

def _encdec_api(cfg) -> ModelApi:
    def loss(params, batch):
        enc = wh.encode(params, cfg, batch["audio_embed"], remat=True)
        kv = wh.cross_kv(params, cfg, enc)
        hidden, _ = wh.decode_stack(params, cfg, batch["tokens"], kv,
                                    remat=True)
        return tf.lm_loss(params, cfg, hidden, batch["targets"])

    def prefill(params, batch):
        enc = wh.encode(params, cfg, batch["audio_embed"])
        kv = wh.cross_kv(params, cfg, enc)
        hidden, self_cache = wh.decode_stack(
            params, cfg, batch["tokens"], kv,
            cache=batch["cache"]["self"], cache_index=0)
        lg = wh.logits(params, cfg, hidden[:, -1:])
        return lg, {"self": self_cache, "cross": kv}

    def decode(params, cache, batch):
        ci = batch["cache_index"]
        hidden, self_cache = wh.decode_stack(
            params, cfg, batch["tokens"], cache["cross"],
            cache=cache["self"], cache_index=ci)
        return wh.logits(params, cfg, hidden), \
            {"self": self_cache, "cross": cache["cross"]}

    def init_cache(batch, max_len, dtype=jnp.bfloat16):
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                 cfg.head_dim)
        kvshape = (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads,
                   cfg.head_dim)
        return {
            "self": {"k": jnp.zeros(shape, dtype),
                     "v": jnp.zeros(shape, dtype)},
            "cross": (jnp.zeros(kvshape, dtype),
                      jnp.zeros(kvshape, dtype)),
        }

    def input_specs(kind, batch, seq):
        i32 = jnp.int32
        cdt = jnp.dtype(cfg.compute_dtype)
        sds = jax.ShapeDtypeStruct
        audio = sds((batch, cfg.enc_seq, cfg.d_model), cdt)
        if kind == "train":
            return {"audio_embed": audio,
                    "tokens": sds((batch, seq), i32),
                    "targets": sds((batch, seq), i32)}
        if kind == "prefill":
            return {"audio_embed": audio, "tokens": sds((batch, seq), i32)}
        if kind == "decode":
            return {"tokens": sds((batch, 1), i32),
                    "cache_index": sds((), i32)}
        raise ValueError(kind)

    return ModelApi(cfg, lambda key: wh.init_whisper(key, cfg), loss,
                    prefill, decode, init_cache, input_specs)


def make(cfg) -> ModelApi:
    if cfg.family == "encdec":
        return _encdec_api(cfg)
    return _decoder_api(cfg)


# ----------------------------------------------------------------------
# Parameter counting (MODEL_FLOPS = 6 * N * D convention)
# ----------------------------------------------------------------------

def count_params(cfg):
    """(total, active-per-token) parameter counts, analytic."""
    d, f = cfg.d_model, cfg.d_ff
    attn = d * cfg.n_heads * cfg.head_dim * 2 + \
        d * cfg.n_kv_heads * cfg.head_dim * 2
    dense_mlp = 3 * d * f
    expert = 3 * d * f
    moe_total = cfg.n_experts * expert + d * cfg.n_experts
    moe_active = cfg.top_k * expert + d * cfg.n_experts

    di = cfg.ssm_expand * d
    mamba = d * (2 * di + 2 * cfg.d_state + cfg.ssm_heads) + di * d + \
        cfg.conv_width * (di + 2 * cfg.d_state)

    total = active = cfg.vocab * d  # embedding (tied head)
    if cfg.family == "encdec":
        enc = cfg.enc_layers * (attn + dense_mlp)
        dec = cfg.n_layers * (2 * attn + dense_mlp)
        total += enc + dec
        return total, total
    for layer in range(cfg.n_layers):
        kind = tf._kind_of(cfg, layer)
        if kind == "mamba":
            total += mamba
            active += mamba
        else:
            total += attn
            active += attn
            if cfg.family == "moe":
                total += moe_total
                active += moe_active
            else:
                total += dense_mlp
                active += dense_mlp
    if cfg.shared_period:
        shared = attn + dense_mlp
        total += shared
        n_inv = tf.n_shared_invocations(cfg)
        active += shared * n_inv  # applied n_inv times per token
    return total, active
