"""GQA attention with sliding-window, soft-capping and KV caches.

The dense math lives in ``attend_chunked`` -- a pure-jnp flash-style
implementation: one ``lax.scan`` over a *statically precomputed list of
(q-block, kv-block) pairs* with an online-softmax accumulator, so that

  * peak memory is O(S * block) instead of O(S^2) -- required for the
    32k prefill dry-runs;
  * causal masking skips upper-triangle block pairs entirely and "local"
    layers enumerate only in-window pairs: the compiled HLO FLOPs honestly
    reflect O(S^2/2) causal and O(S * w) sliding-window cost (XLA counts
    masked-but-executed work, so sparsity must be structural);
  * the Pallas kernel (repro.kernels.flash_attention) implements the same
    block algorithm with explicit VMEM BlockSpecs; this module is its
    oracle and the CPU/dry-run execution path.

Decode (single query against a pre-allocated cache) takes the dynamic
path: the pair list cannot depend on the traced cache index, so it scans
the (window-sliced) cache with a validity mask -- decode attention is
bytes-bound and reads exactly the cache it should.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import (_ambient_mesh, apply_mrope, apply_rope, dense_init,
                     softcap)

NEG_INF = -2.0 ** 30


def _attn_sharding(q_block: int, batch: int):
    """Sharding plan for the flash pair-scan buffers.

    The q-block token axis takes "model" (512/16 = 32 rows/device): score
    and PV matmuls then contract only replicated dims (head_dim / kv
    block), so the forward pass needs NO per-pair collectives, and the
    online-softmax carries are 1/model_parallel-sized per device.  KV
    blocks replicate over "model" (they are the small side under GQA).
    Heads deliberately do NOT take "model": hkv x g (e.g. 8 x 8 for 64
    heads on a 16-way axis) is not expressible as a single-dim sharding,
    and head_dim sharding would psum every score block (see
    dist.sharding notes).  Returns (batch_axes, use_model) or None.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return None
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while baxes:
        size = 1
        for a in baxes:
            size *= mesh.shape[a]
        if batch % size == 0:
            break
        baxes = baxes[1:]
    use_model = ("model" in mesh.axis_names
                 and q_block % mesh.shape["model"] == 0
                 and q_block > mesh.shape["model"])
    if not baxes and not use_model:
        return None
    return (baxes if len(baxes) != 1 else baxes[0], use_model)


def _constrain(x, spec):
    from jax.sharding import PartitionSpec as P
    spec = [None if (isinstance(s, tuple) and not s) else s for s in spec]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _plan_specs(plan):
    baxes, use_model = plan
    m = "model" if use_model else None
    return baxes, m


def init_attn(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), d, dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), d, dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), d, dtype),
        "wo": dense_init(ks[3], (hq, hd, d), hq * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    return p


def _pairs(nq, nk, q_block, kv_block, causal, window, q_offset):
    """Static block pairs with unmasked entries, in two orders:
    i-major (forward + dq pass) and j-major (dk/dv pass), each with
    (idx0, idx1, is_first, is_last) flags for its major key."""
    base = []
    for i in range(nq):
        q_lo = q_offset + i * q_block
        q_hi = q_offset + (i + 1) * q_block - 1
        for j in range(nk):
            k_lo, k_hi = j * kv_block, (j + 1) * kv_block - 1
            if causal and k_lo > q_hi:
                continue
            # window keeps kv positions kp > qp - window; the weakest
            # constraint inside the block is at qp = q_lo.
            if window and k_hi <= q_lo - window:
                continue
            base.append((i, j))
    return _with_flags(base, 0), _with_flags(
        sorted(base, key=lambda p: (p[1], p[0])), 1)


def _dense_pairs(nq, nk):
    base = [(i, j) for i in range(nq) for j in range(nk)]
    return _with_flags(base, 0), _with_flags(
        sorted(base, key=lambda p: (p[1], p[0])), 1)


def _with_flags(pairs, major):
    out = []
    n = len(pairs)
    for t, (i, j) in enumerate(pairs):
        key = (i, j)[major]
        first = 1 if t == 0 or (pairs[t - 1][0], pairs[t - 1][1])[major] \
            != key else 0
        last = 1 if t == n - 1 or (pairs[t + 1][0], pairs[t + 1][1])[major] \
            != key else 0
        out.append((i, j, first, last))
    import numpy as _np
    return _np.asarray(out, _np.int32)


def _block_mask(pair_i, pair_j, q_block, kv_block, q_offset, valid_kv,
                causal, window):
    q_pos = q_offset + pair_i * q_block + jnp.arange(q_block)
    kv_pos = pair_j * kv_block + jnp.arange(kv_block)
    mask = kv_pos[None, :] < valid_kv
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    return mask


@functools.lru_cache(maxsize=None)
def _attend_fn(causal, window, cap, q_block, kv_block):
    """Flash attention over static block-pair lists with a custom VJP.

    Forward and dq-backward iterate i-major, dk/dv-backward iterates a
    second j-major list (the canonical two-pass flash backward): each
    pass keeps only the CURRENT major block's accumulator in the scan
    carry and commits finished blocks with a write-only dynamic-update
    (a dummy extra row absorbs non-final steps).  Earlier designs that
    sliced+updated an [nq, ...] buffer every step made XLA copy/convert
    the whole buffer per pair -- measured 13.5-111 TB/chip of HBM
    traffic on deepseek-67b cells (EXPERIMENTS.md section Perf iter 4).
    The backward recomputes p from the saved (out, logsumexp), so
    training memory stays O(S) per layer -- the same recompute scheme as
    the Pallas kernel in repro.kernels."""

    def _scores(q_i, k_j, i, j, q_offset, valid_kv, want_tanh=False):
        scale = q_i.shape[-1] ** -0.5
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                       preferred_element_type=jnp.float32) * scale
        t = None
        if cap:
            t = jnp.tanh(s / cap)
            s = t * cap
        mask = _block_mask(i, j, q_block, kv_block, q_offset,
                           valid_kv, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        return s, t, mask

    def fwd_impl(qb, kb, vb, pairs, q_offset, valid_kv):
        nq, b, _, hkv, g, hd = qb.shape

        def step(carry, pair):
            m, l, acc, o_out, lse_out = carry
            i, j, first, last = pair[0], pair[1], pair[2], pair[3]
            q_i = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
            k_j = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
            fresh = first > 0
            m = jnp.where(fresh, NEG_INF, m)
            l = jnp.where(fresh, 0.0, l)
            acc = jnp.where(fresh, 0.0, acc)
            s, _, _ = _scores(q_i, k_j, i, j, q_offset, valid_kv)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            a_new = acc * alpha[..., None] + pv
            # commit the block on its final pair (dummy row nq otherwise)
            slot = jnp.where(last > 0, i, nq)
            o_blk = (a_new / jnp.maximum(l_new[..., None], 1e-30)
                     ).astype(o_out.dtype)
            lse_blk = m_new + jnp.log(jnp.maximum(l_new, 1e-30))
            o_out = jax.lax.dynamic_update_index_in_dim(
                o_out, o_blk, slot, 0)
            lse_out = jax.lax.dynamic_update_index_in_dim(
                lse_out, lse_blk, slot, 0)
            return (m_new, l_new, a_new, o_out, lse_out), None

        m0 = jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, hd), jnp.float32)
        o0 = jnp.zeros((nq + 1, b, hkv, g, q_block, hd), qb.dtype)
        lse0 = jnp.zeros((nq + 1, b, hkv, g, q_block), jnp.float32)
        plan = _attn_sharding(q_block, b)
        if plan is not None:
            ba, mo = _plan_specs(plan)
            m0 = _constrain(m0, (ba, None, None, mo))
            l0 = _constrain(l0, (ba, None, None, mo))
            a0 = _constrain(a0, (ba, None, None, mo, None))
            o0 = _constrain(o0, (None, ba, None, None, mo, None))
            lse0 = _constrain(lse0, (None, ba, None, None, mo))
        (_, _, _, o_out, lse_out), _ = jax.lax.scan(
            step, (m0, l0, a0, o0, lse0), pairs)
        return o_out[:nq], lse_out[:nq]

    @jax.custom_vjp
    def attend(qb, kb, vb, pairs, pairs_kv, q_offset, valid_kv):
        return fwd_impl(qb, kb, vb, pairs, q_offset, valid_kv)[0]

    def attend_fwd(qb, kb, vb, pairs, pairs_kv, q_offset, valid_kv):
        out, lse = fwd_impl(qb, kb, vb, pairs, q_offset, valid_kv)
        return out, (qb, kb, vb, pairs, pairs_kv, q_offset, valid_kv,
                     out, lse)

    def attend_bwd(res, dout):
        qb, kb, vb, pairs, pairs_kv, q_offset, valid_kv, out, lse = res
        nq, b, _, hkv, g, hd = qb.shape
        nk = kb.shape[0]
        scale = hd ** -0.5
        f32 = jnp.float32
        delta = jnp.sum(dout.astype(f32) * out.astype(f32), -1)
        lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)

        plan = _attn_sharding(q_block, b)

        def block_grads(i, j, shard_kb=False):
            """Recompute p and ds for one pair.

            Pass A keeps the q-block axis model-sharded (inherited from
            the forward buffers).  Pass B re-shards to the KV-block axis
            instead: its dk/dv contraction runs over q, so kb-sharding
            makes every step fully local -- with qb-sharding GSPMD
            all-gathered the [.., qb, kb] ds blocks every pair (measured
            3.8 TB/device, EXPERIMENTS.md Perf iter 5)."""
            q_i = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
            k_j = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
            do_i = jax.lax.dynamic_index_in_dim(dout, i, 0,
                                                keepdims=False)
            lse_i = jax.lax.dynamic_index_in_dim(lse_safe, i, 0,
                                                 keepdims=False)
            d_i = jax.lax.dynamic_index_in_dim(delta, i, 0,
                                               keepdims=False)
            if shard_kb and plan is not None:
                ba_, _ = _plan_specs(plan)
                mk = "model" if plan[1] else None
                q_i = _constrain(q_i, (ba_, None, None, None, None))
                do_i = _constrain(do_i, (ba_, None, None, None, None))
                lse_i = _constrain(lse_i, (ba_, None, None, None))
                d_i = _constrain(d_i, (ba_, None, None, None))
                k_j = _constrain(k_j, (ba_, mk, None, None))
                v_j = _constrain(v_j, (ba_, mk, None, None))
            s, t, mask = _scores(q_i, k_j, i, j, q_offset, valid_kv)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lse_i[..., None]), 0.0)
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", do_i.astype(f32), v_j,
                            preferred_element_type=f32)
            ds = p * (dp - d_i[..., None])
            if cap:
                ds = ds * (1.0 - t * t)
            ds = ds * scale
            return q_i, k_j, do_i, p, ds

        # ---- pass A (i-major): dq ----
        def step_q(carry, pair):
            dq_cur, dq_out = carry
            i, j, first, last = pair[0], pair[1], pair[2], pair[3]
            _, k_j, _, _, ds = block_grads(i, j)
            dq_cur = jnp.where(first > 0, 0.0, dq_cur)
            dq_cur = dq_cur + jnp.einsum(
                "bhgqk,bkhd->bqhgd", ds, k_j,
                preferred_element_type=f32)
            slot = jnp.where(last > 0, i, nq)
            dq_out = jax.lax.dynamic_update_index_in_dim(
                dq_out, dq_cur.astype(dq_out.dtype), slot, 0)
            return (dq_cur, dq_out), None

        dq_cur0 = jnp.zeros((b, q_block, hkv, g, hd), f32)
        dq_out0 = jnp.zeros((nq + 1,) + dq_cur0.shape, qb.dtype)
        if plan is not None:
            ba, mo = _plan_specs(plan)
            dq_cur0 = _constrain(dq_cur0, (ba, mo, None, None, None))
            dq_out0 = _constrain(dq_out0, (None, ba, mo, None, None,
                                           None))
        (_, dq_out), _ = jax.lax.scan(step_q, (dq_cur0, dq_out0), pairs)

        # ---- pass B (j-major): dk, dv ----
        def step_kv(carry, pair):
            dk_cur, dv_cur, dk_out, dv_out = carry
            i, j, first, last = pair[0], pair[1], pair[2], pair[3]
            q_i, _, do_i, p, ds = block_grads(i, j, shard_kb=True)
            dk_cur = jnp.where(first > 0, 0.0, dk_cur)
            dv_cur = jnp.where(first > 0, 0.0, dv_cur)
            dk_delta = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_i,
                                  preferred_element_type=f32)
            dv_delta = jnp.einsum("bhgqk,bhgqd->bkhd", p,
                                  do_i.astype(f32),
                                  preferred_element_type=f32)
            dk_cur = dk_cur + dk_delta
            dv_cur = dv_cur + dv_delta
            slot = jnp.where(last > 0, j, nk)
            dk_out = jax.lax.dynamic_update_index_in_dim(
                dk_out, dk_cur.astype(dk_out.dtype), slot, 0)
            dv_out = jax.lax.dynamic_update_index_in_dim(
                dv_out, dv_cur.astype(dv_out.dtype), slot, 0)
            return (dk_cur, dv_cur, dk_out, dv_out), None

        dk_cur0 = jnp.zeros((b, kv_block, hkv, hd), f32)
        dv_cur0 = jnp.zeros((b, kv_block, hkv, hd), f32)
        dk_out0 = jnp.zeros((nk + 1,) + dk_cur0.shape, kb.dtype)
        dv_out0 = jnp.zeros((nk + 1,) + dv_cur0.shape, vb.dtype)
        if plan is not None:
            ba, _ = _plan_specs(plan)
            mk = "model" if plan[1] else None
            dk_cur0 = _constrain(dk_cur0, (ba, mk, None, None))
            dv_cur0 = _constrain(dv_cur0, (ba, mk, None, None))
            dk_out0 = _constrain(dk_out0, (None, ba, mk, None, None))
            dv_out0 = _constrain(dv_out0, (None, ba, mk, None, None))
        (_, _, dk_out, dv_out), _ = jax.lax.scan(
            step_kv, (dk_cur0, dv_cur0, dk_out0, dv_out0), pairs_kv)

        return (dq_out[:nq], dk_out[:nk], dv_out[:nk],
                None, None, None, None)

    attend.defvjp(attend_fwd, attend_bwd)
    return attend


def attend_chunked(q, k, v, *, causal: bool, window: int = 0,
                   cap: float = 0.0, q_offset=0, kv_valid_len=None,
                   q_block: int = 512, kv_block: int = 1024):
    """q: [B, Sq, Hkv, G, hd]; k, v: [B, Skv, Hkv, hd] -> out like q.

    ``q_offset``: absolute position of q[0]; a python int enables the
    static block-sparse pair list; a tracer (decode) falls back to a dense
    kv scan with masking.  ``kv_valid_len`` masks KV positions >= it.
    """
    b, sq, hkv, g, hd = q.shape
    skv = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    sq_p = -(-sq // q_block) * q_block
    skv_p = -(-skv // kv_block) * kv_block
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq)) + ((0, 0),) * 3)
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    nq, nk = sq_p // q_block, skv_p // kv_block

    static_offset = isinstance(q_offset, (int, np.integer))
    if static_offset:
        pairs, pairs_kv = _pairs(nq, nk, q_block, kv_block, causal,
                                 window, q_offset)
    else:
        pairs, pairs_kv = _dense_pairs(nq, nk)

    valid_kv = jnp.asarray(
        skv if kv_valid_len is None else kv_valid_len, jnp.int32)
    q_off = jnp.asarray(q_offset, jnp.int32)
    qb = jnp.moveaxis(q.reshape(b, nq, q_block, hkv, g, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nk, kv_block, hkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, kv_block, hkv, hd), 1, 0)
    plan = _attn_sharding(q_block, b)
    if plan is not None:
        ba, mo = _plan_specs(plan)
        qb = _constrain(qb, (None, ba, mo, None, None, None))
        kb = _constrain(kb, (None, ba, None, None, None))
        vb = _constrain(vb, (None, ba, None, None, None))

    attend = _attend_fn(bool(causal), int(window), float(cap),
                        int(q_block), int(kv_block))
    out = attend(qb, kb, vb, jnp.asarray(pairs), jnp.asarray(pairs_kv),
                 q_off, valid_kv)
    out = jnp.moveaxis(out, 0, 1)                  # [B,nq,H,G,qb,hd]
    out = jnp.moveaxis(out, 4, 2).reshape(b, sq_p, hkv, g, hd)
    return out[:, :sq].astype(q.dtype)


def attention(params, cfg, x, positions, *, layer_kind: str = "global",
              cache=None, cache_index=None):
    """Full attention block: qkv proj, rope, mix, out proj.

    cache: None (training / un-cached prefill) or dict(k, v) preallocated
    [B, S_max, Hkv, hd]; returns (y, new_cache).  ``cache_index`` is the
    write offset (0 for prefill, current length for decode).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    cdt = x.dtype

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cdt)
        k = k + params["bk"].astype(cdt)
        v = v + params["bv"].astype(cdt)

    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    window = cfg.window if layer_kind == "local" else 0
    new_cache = None
    if cache is None:
        kv_valid, q_offset = None, 0
        if layer_kind == "decode_like":  # pragma: no cover - guard
            raise ValueError("decode requires a cache")
        out_kv = (k, v)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        kv_valid = cache_index + s
        q_offset = cache_index
        kk, vv = ck.astype(cdt), cv.astype(cdt)
        if window and s == 1 and ck.shape[1] > window:
            # decode on a local layer: read only the last ``window`` slots
            start = jnp.clip(kv_valid - window, 0, ck.shape[1] - window)
            kk = jax.lax.dynamic_slice_in_dim(kk, start, window, axis=1)
            vv = jax.lax.dynamic_slice_in_dim(vv, start, window, axis=1)
            kv_valid = kv_valid - start
            q_offset = q_offset - start
            window = 0  # slice already enforces the window
        out_kv = (kk, vv)

    k_used, v_used = out_kv
    qg = q.reshape(b, s, hkv, g, hd)
    out = attend_chunked(qg, k_used, v_used,
                         causal=(layer_kind != "bidir"), window=window,
                         cap=cfg.attn_softcap, q_offset=q_offset,
                         kv_valid_len=kv_valid)
    out = out.reshape(b, s, hq, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cdt))
    return y, new_cache


def init_cache(cfg, batch: int, max_len: int, n_layers: int, dtype):
    """Stacked KV cache for ``n_layers`` layers: [L, B, S, Hkv, hd]."""
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cross_attention(params, cfg, x, enc_kv):
    """Whisper-style cross-attention; enc_kv precomputed from encoder."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cdt))
    k, v = enc_kv
    qg = q.reshape(b, s, hkv, hq // hkv, hd)
    out = attend_chunked(qg, k.astype(cdt), v.astype(cdt), causal=False)
    out = out.reshape(b, s, hq, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cdt))


def encode_kv(params, cfg, enc_out):
    cdt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(cdt))
    return k, v
