from . import api, attention, common, mamba2, mlp, transformer, whisper
from .api import ModelApi, count_params, make
