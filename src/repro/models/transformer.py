"""Unified decoder-only LM covering dense / MoE / SSM / hybrid families.

Depth is organised as *super-blocks* (cfg.pattern): parameters of each
pattern position are stacked over super-blocks and the stack is
``lax.scan``-ed, so HLO size is O(|pattern|), not O(depth) -- essential to
keep 95-layer dry-runs compilable.  Layers not covered by whole
super-blocks (e.g. gemma3-1b's 26 = 4 x (5 local + 1 global) + 2 tail) are
unrolled separately.

Caches are stored pre-grouped in scan layout -- ``cache["sb"][pos]`` is a
[n_superblocks, ...] stack consumed directly as scan xs -- so decode never
gathers/scatters multi-GB cache tensors.

Hybrid (zamba2): pattern ("mamba",) plus a *shared* attention+MLP block
(single parameter set, per-invocation KV cache) fired every
``cfg.shared_period`` layers inside the scan, following Zamba2's shared
transformer design (per-invocation LoRA deltas simplified away; DESIGN.md
section 3 notes this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba2 as mamba_mod
from . import mlp as mlp_mod
from .common import (dense_init, embed_init, norm_apply, norm_init,
                     shard_hint, softcap)

LOSS_CHUNK = 256


def _kind_of(cfg, layer: int) -> str:
    pat = cfg.pattern
    if layer < cfg.n_superblocks * len(pat):
        return pat[layer % len(pat)]
    return cfg.tail_pattern[layer - cfg.n_superblocks * len(pat)]


def _shared_fire(cfg):
    """fire[sb] == 1 when the shared block runs after super-block sb.
    NumPy (not jnp) so it stays concrete under eval_shape tracing."""
    import numpy as np
    n_sb = cfg.n_superblocks
    if not cfg.shared_period:
        return np.zeros((n_sb,), np.int32)
    if len(cfg.pattern) != 1:
        raise ValueError("shared_period requires a length-1 pattern")
    per = cfg.shared_period
    return np.asarray([1 if sb % per == per - 1 else 0
                       for sb in range(n_sb)], np.int32)


def n_shared_invocations(cfg) -> int:
    return int(_shared_fire(cfg).sum()) if cfg.shared_period else 0


# ----------------------------------------------------------------------
# Parameter construction
# ----------------------------------------------------------------------

def _layer_init(key, cfg, kind, dtype):
    ks = jax.random.split(key, 2)
    if kind == "mamba":
        return {
            "norm": norm_init(cfg, cfg.d_model, dtype),
            "mamba": mamba_mod.init_mamba(ks[0], cfg, dtype),
        }
    p = {
        "norm1": norm_init(cfg, cfg.d_model, dtype),
        "attn": attn_mod.init_attn(ks[0], cfg, dtype),
        "norm2": norm_init(cfg, cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = mlp_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp(ks[1], cfg, dtype)
    return p


def init_lm(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    n_sb = cfg.n_superblocks

    blocks = []
    kb = jax.random.split(ks[0], len(cfg.pattern))
    for pos, kind in enumerate(cfg.pattern):
        keys = jax.random.split(kb[pos], max(n_sb, 1))
        blocks.append(
            jax.vmap(lambda kk, kind=kind: _layer_init(kk, cfg, kind,
                                                       dtype))(keys))

    kt = jax.random.split(ks[1], max(len(cfg.tail_pattern), 1))
    tail = [_layer_init(kt[pos], cfg, kind, dtype)
            for pos, kind in enumerate(cfg.tail_pattern)]

    params = {
        "embed": embed_init(ks[2], (cfg.vocab, cfg.d_model), dtype),
        "blocks": blocks,
        "tail": tail,
        "final_norm": norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            ks[3], (cfg.d_model, cfg.vocab), cfg.d_model, dtype)
    if cfg.shared_period:
        params["shared"] = _layer_init(ks[4], cfg, "global", dtype)
    if cfg.pos == "learned":
        params["pos_embed"] = embed_init(ks[5], (32768, cfg.d_model), dtype)
    return params


# ----------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------

def _apply_layer(p, cfg, kind, x, positions, cache, cache_index):
    """One layer; ``cache`` is None or the per-layer cache pytree."""
    x = shard_hint(x)  # anchor batch sharding (see common.shard_hint)
    if kind == "mamba":
        h = norm_apply(cfg, p["norm"], x)
        y, new_cache = mamba_mod.mamba_block(p["mamba"], cfg, h, cache)
        return x + y, new_cache
    h = norm_apply(cfg, p["norm1"], x)
    y, new_cache = attn_mod.attention(p["attn"], cfg, h, positions,
                                      layer_kind=kind, cache=cache,
                                      cache_index=cache_index)
    x = shard_hint(x + y)
    h = norm_apply(cfg, p["norm2"], x)
    if cfg.family == "moe":
        x = x + mlp_mod.moe(p["moe"], cfg, h)
    else:
        x = x + mlp_mod.mlp(p["mlp"], cfg, h)
    return x, new_cache


def hidden_states(params, cfg, x, positions, cache=None, cache_index=0,
                  remat: bool = False):
    """x: [B, S, D] embedded input -> (normed hidden, new_cache)."""
    n_sb = cfg.n_superblocks
    pat = cfg.pattern
    cdt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    has_cache = cache is not None
    fire = jnp.asarray(_shared_fire(cfg))

    def sb_body(carry, inputs):
        x, sh_cache, inv = carry
        sb_params, sb_caches, do_shared = inputs
        new_caches = []
        for pos, kind in enumerate(pat):
            c = sb_caches[pos] if has_cache else None
            x, nc = _apply_layer(sb_params[pos], cfg, kind, x, positions,
                                 c, cache_index)
            new_caches.append(nc if nc is not None
                              else jnp.zeros((0,), cdt))

        if cfg.shared_period:
            def run_shared(args):
                x, sh_cache, inv = args
                sc = None
                if has_cache:
                    sc = jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, inv, 0, keepdims=False), sh_cache)
                y, new_sc = _apply_layer(params["shared"], cfg, "global",
                                         x, positions, sc, cache_index)
                if has_cache and new_sc is not None:
                    sh_cache = jax.tree_util.tree_map(
                        lambda a, b: jax.lax.dynamic_update_index_in_dim(
                            a, b.astype(a.dtype), inv, 0),
                        sh_cache, new_sc)
                return y, sh_cache, inv + 1

            x, sh_cache, inv = jax.lax.cond(
                do_shared > 0, run_shared, lambda a: a,
                (x, sh_cache, inv))
        return (x, sh_cache, inv), tuple(new_caches)

    body = jax.checkpoint(sb_body) if remat else sb_body

    sh_cache0 = cache.get("shared") if has_cache else jnp.zeros((0,), cdt)
    if sh_cache0 is None:
        sh_cache0 = jnp.zeros((0,), cdt)
    new_sb_caches = tuple(jnp.zeros((0,), cdt) for _ in pat)
    if n_sb > 0:
        xs_caches = tuple(
            cache["sb"][pos] if has_cache else jnp.zeros((n_sb,), cdt)
            for pos in range(len(pat)))
        (x, sh_cache_new, _), new_sb_caches = jax.lax.scan(
            body, (x, sh_cache0, jnp.asarray(0, jnp.int32)),
            (tuple(params["blocks"]), xs_caches, fire))
    else:
        sh_cache_new = sh_cache0

    # --- tail layers (unrolled) ---
    tail_new = []
    for pos, kind in enumerate(cfg.tail_pattern):
        c = cache["tail"][pos] if has_cache else None
        x, nc = _apply_layer(params["tail"][pos], cfg, kind, x, positions,
                             c, cache_index)
        tail_new.append(nc)

    x = norm_apply(cfg, params["final_norm"], x)

    new_cache = None
    if has_cache:
        new_cache = {"sb": tuple(new_sb_caches), "tail": tuple(tail_new)}
        if cfg.shared_period:
            new_cache["shared"] = sh_cache_new
    return x, new_cache


def embed(params, cfg, tokens):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.norm_offset:  # gemma convention: sqrt(d) input normaliser
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    return x


def logits(params, cfg, hidden):
    cdt = hidden.dtype
    table = params.get("lm_head")
    if table is None:
        out = jnp.einsum("bsd,vd->bsv", hidden, params["embed"].astype(cdt))
    else:
        out = hidden @ table.astype(cdt)
    return softcap(out, cfg.final_softcap)


def lm_loss(params, cfg, hidden, targets, mask=None):
    """Chunked cross-entropy over the vocab (memory O(chunk * V))."""
    b, s, d = hidden.shape
    chunk = min(LOSS_CHUNK, s)
    s_p = -(-s // chunk) * chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    if s_p != s:
        hidden = jnp.pad(hidden, ((0, 0), (0, s_p - s), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, s_p - s)))
        mask = jnp.pad(mask, ((0, 0), (0, s_p - s)))
    nc = s_p // chunk
    hc = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    def body(carry, inp):
        h, t, m = inp
        lg = logits(params, cfg, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ----------------------------------------------------------------------
# Cache construction (scan layout)
# ----------------------------------------------------------------------

def _single_cache(cfg, kind, batch, max_len, dtype, stack=None):
    if kind == "mamba":
        di = mamba_mod.d_inner(cfg)
        c = di + 2 * cfg.d_state
        shape_conv = (batch, cfg.conv_width - 1, c)
        shape_ssm = (batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.d_state)
        if stack:
            shape_conv = (stack,) + shape_conv
            shape_ssm = (stack,) + shape_ssm
        return {"conv": jnp.zeros(shape_conv, dtype),
                "ssm": jnp.zeros(shape_ssm, jnp.float32)}
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if stack:
        shape = (stack,) + shape
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_caches(cfg, batch: int, max_len: int, dtype):
    """Cache pytree in scan layout: sb[pos] stacked [n_sb, ...]."""
    n_sb = cfg.n_superblocks
    out = {
        "sb": tuple(
            _single_cache(cfg, kind, batch, max_len, dtype, stack=n_sb)
            for kind in cfg.pattern),
        "tail": tuple(
            _single_cache(cfg, kind, batch, max_len, dtype)
            for kind in cfg.tail_pattern),
    }
    n_inv = n_shared_invocations(cfg)
    if n_inv:
        out["shared"] = _single_cache(cfg, "global", batch, max_len,
                                      dtype, stack=n_inv)
    return out
