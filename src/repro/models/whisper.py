"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the brief: ``input_specs`` supplies
precomputed log-mel *frame embeddings* [B, enc_seq, D] (enc_seq = 1500,
Whisper's 30 s window).  Encoder: bidirectional attention + GELU MLP with
sinusoidal positions.  Decoder: causal self-attention + cross-attention
with learned positions.  The assigned seq_len applies to the decoder
token stream (32k decode is a stress configuration far beyond Whisper's
448-token practical max; intentional, see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mlp as mlp_mod
from .common import embed_init, norm_apply, norm_init, shard_hint, softcap


def _sinusoid(seq: int, d: int, dtype):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm1": norm_init(cfg, cfg.d_model, dtype),
        "attn": attn_mod.init_attn(ks[0], cfg, dtype),
        "norm2": norm_init(cfg, cfg.d_model, dtype),
        "mlp": mlp_mod.init_mlp(ks[1], cfg, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg, cfg.d_model, dtype),
        "attn": attn_mod.init_attn(ks[0], cfg, dtype),
        "norm_x": norm_init(cfg, cfg.d_model, dtype),
        "xattn": attn_mod.init_attn(ks[1], cfg, dtype),
        "norm2": norm_init(cfg, cfg.d_model, dtype),
        "mlp": mlp_mod.init_mlp(ks[2], cfg, dtype),
    }


def init_whisper(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_blocks": jax.vmap(
            lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_norm": norm_init(cfg, cfg.d_model, dtype),
        "dec_blocks": jax.vmap(
            lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "final_norm": norm_init(cfg, cfg.d_model, dtype),
        "embed": embed_init(ks[2], (cfg.vocab, cfg.d_model), dtype),
        "pos_embed": embed_init(ks[3], (32768, cfg.d_model), dtype),
    }


def encode(params, cfg, audio_embed, remat: bool = False):
    """audio_embed: [B, enc_seq, D] -> encoder states."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = audio_embed.astype(cdt)
    x = x + _sinusoid(x.shape[1], cfg.d_model, cdt)[None]
    pos = jnp.arange(x.shape[1])[None]

    def body(x, p):
        x = shard_hint(x)
        h = norm_apply(cfg, p["norm1"], x)
        y, _ = attn_mod.attention(p["attn"], cfg, h, pos,
                                  layer_kind="bidir")
        x = x + y
        h = norm_apply(cfg, p["norm2"], x)
        return x + mlp_mod.mlp(p["mlp"], cfg, h), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return norm_apply(cfg, params["enc_norm"], x)


def cross_kv(params, cfg, enc_out):
    """Per-decoder-layer cross K/V, stacked [L, B, Senc, Hkv, hd]."""
    def body(_, p):
        return None, attn_mod.encode_kv(p["xattn"], cfg, enc_out)
    _, kv = jax.lax.scan(body, None, params["dec_blocks"])
    return kv  # (k, v) each [L, B, Senc, Hkv, hd]


def decode_stack(params, cfg, tokens, enc_kv, cache=None, cache_index=0,
                 remat: bool = False):
    """tokens: [B, S] -> (hidden, new_cache).

    enc_kv: per-layer stacked cross K/V.  cache: None or stacked self-attn
    KV {k, v} [L, B, S_max, Hkv, hd].
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    pos = cache_index + jnp.arange(s)[None]
    x = params["embed"][tokens].astype(cdt)
    x = x + params["pos_embed"][cache_index + jnp.arange(s)].astype(cdt)
    has_cache = cache is not None

    def body(x, inputs):
        p, kv, c = inputs
        x = shard_hint(x)
        h = norm_apply(cfg, p["norm1"], x)
        y, nc = attn_mod.attention(p["attn"], cfg, h, pos,
                                   layer_kind="global",
                                   cache=c if has_cache else None,
                                   cache_index=cache_index)
        x = x + y
        h = norm_apply(cfg, p["norm_x"], x)
        x = x + attn_mod.cross_attention(p["xattn"], cfg, h, kv)
        h = norm_apply(cfg, p["norm2"], x)
        x = x + mlp_mod.mlp(p["mlp"], cfg, h)
        return x, (nc if nc is not None else jnp.zeros((0,), cdt))

    body_fn = jax.checkpoint(body) if remat else body
    dummy = jnp.zeros((cfg.n_layers,), cdt)
    x, new_cache = jax.lax.scan(
        body_fn, x,
        (params["dec_blocks"], enc_kv, cache if has_cache else dummy))
    x = norm_apply(cfg, params["final_norm"], x)
    return x, (new_cache if has_cache else None)


def logits(params, cfg, hidden):
    return jnp.einsum("bsd,vd->bsv", hidden,
                      params["embed"].astype(hidden.dtype))
