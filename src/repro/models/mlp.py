"""Dense gated MLPs and capacity-based top-k Mixture-of-Experts.

MoE dispatch is the sort-free GShard/MaxText-style capacity scheme:
scatter tokens into a [experts, capacity, d_model] buffer (position =
running count per expert), run batched expert GEMMs, gather back with the
router weights.  Compiled FLOPs therefore track *active* parameters
(6 * N_active * D in the roofline's MODEL_FLOPS convention); the only
waste is the capacity-factor padding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import activation, dense_init


def init_mlp(key, cfg, dtype, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(ks[0], (d, f), d, dtype),
        "wi_up": dense_init(ks[1], (d, f), d, dtype),
        "wo": dense_init(ks[2], (f, d), f, dtype),
    }


def mlp(params, cfg, x):
    cdt = x.dtype
    act = activation(cfg.act)
    h = act(x @ params["wi_gate"].astype(cdt)) * \
        (x @ params["wi_up"].astype(cdt))
    return h @ params["wo"].astype(cdt)


# ----------------------------------------------------------------------
# Mixture of Experts
# ----------------------------------------------------------------------

def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), d, dtype),
        "wi_gate": dense_init(ks[1], (e, d, f), d, dtype),
        "wi_up": dense_init(ks[2], (e, d, f), d, dtype),
        "wo": dense_init(ks[3], (e, f, d), f, dtype),
    }


def moe(params, cfg, x):
    """x: [B, S, D] -> [B, S, D], top-k routing with capacity dropping."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cdt = x.dtype
    act = activation(cfg.act)

    xf = x.reshape(t, d)
    logits = (xf @ params["router"].astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                   # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = max(int(cfg.capacity_factor * t * k / e), 1)

    e_flat = top_i.reshape(t * k)                            # [T*k]
    w_flat = top_p.reshape(t * k).astype(cdt)
    # position of each assignment within its expert (running count)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)      # [T*k, E]
    pos_all = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_all, e_flat[:, None], -1)[:, 0]
    keep = (pos < cap).astype(cdt)

    # dispatch: scatter x into [E, cap, D]
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    buf = jnp.zeros((e, cap, d), cdt)
    buf = buf.at[e_flat, jnp.clip(pos, 0, cap - 1)].add(
        xf[tok] * keep[:, None], mode="drop")

    # expert GEMMs
    h = act(jnp.einsum("ecd,edf->ecf", buf,
                       params["wi_gate"].astype(cdt))) * \
        jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(cdt))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(cdt))

    # combine: gather each assignment's slot, weight, and sum over k
    gathered = out_buf[e_flat, jnp.clip(pos, 0, cap - 1)]    # [T*k, D]
    gathered = gathered * (w_flat * keep)[:, None]
    yf = jax.ops.segment_sum(gathered, tok, num_segments=t)
    # auxiliary load-balancing loss term (Switch-style), returned via
    # a side channel when needed; kept here as a pure function of probs.
    return yf.reshape(b, s, d).astype(cdt)


def load_balance_loss(params, cfg, x):
    """Switch-Transformer auxiliary loss: E * sum(f_e * p_e)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(b * s, d)
    logits = (xf @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, top_i = jax.lax.top_k(probs, k)
    frac = jnp.mean(jax.nn.one_hot(top_i, e, dtype=jnp.float32), (0, 1))
    imp = jnp.mean(probs, 0)
    return e * jnp.sum(frac * imp)
