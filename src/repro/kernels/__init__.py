"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention  fused GQA attention (window / softcap / causal)
ssd_scan         Mamba-2 SSD chunk scan with VMEM-resident state
event_scan       GridSim Fig 8 PE-share allocation + forecast

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a jitted wrapper in
ops.py, and a pure-jnp oracle in ref.py.  On this CPU container they run
in interpret mode; the BlockSpec tiling targets TPU v5e VMEM.
"""
from . import event_scan, flash_attention, ops, ref, ssd_scan
