"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each reference is written for clarity, not speed: naive materialised
attention, a token-by-token SSM recurrence, and a direct transcription of
paper Fig 8.  Kernel tests sweep shapes/dtypes and assert_allclose
against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0):
    """q: [B, Hq, Sq, d]; k, v: [B, Hkv, Skv, d] -> [B, Hq, Sq, d]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf,
                   k.astype(jnp.float32)) * d ** -0.5
    if cap:
        s = jnp.tanh(s / cap) * cap
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def ssd_ref(x, dt, a, b_mat, c_mat):
    """Token-by-token SSM recurrence (the SSD semantics).

    x: [B,S,H,P]; dt: [B,S,H]; a: [H]; b_mat/c_mat: [B,S,N] -> [B,S,H,P].
    """
    bs, s, h, p = x.shape
    n = b_mat.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp
        gain = jnp.exp(dtt * a)
        state = state * gain[..., None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", bt.astype(jnp.float32),
            dtt.astype(jnp.float32), xt.astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), state)
        return state, y

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, init, tuple(jnp.moveaxis(t, 1, 0)
                          for t in (x, dt, b_mat, c_mat)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def event_scan_ref(remaining, mips_eff, num_pe, tie=None, policy=None,
                   pe_blocked=None, row_ok=None, with_rank=False):
    """Paper Fig 8, directly transcribed per resource row.

    remaining: [R, J] (<=0 / huge marks empty); mips_eff, num_pe,
    policy: [R] (policy 1 = space-shared: every job owns a whole PE);
    tie: [R, J] FIFO tie-break priority (default: col index);
    pe_blocked: [R] PEs held by reservation windows (shrink the
    time-shared share pool; space-shared admission is enforced by the
    engine); row_ok: [R] up-mask -- a down row contributes nothing.
    Returns (rate [R, J], t_min [R], argmin_col [R], occupancy [R]);
    argmin_col is J for empty (or dead) rows.  ``with_rank=True``
    appends the per-row (remaining, tie) sort rank f32[R, J] (only the
    ranks of occupied slots are contractual -- kernels place empty
    slots at arbitrary tail positions).
    """
    import numpy as np
    remaining = np.asarray(remaining, np.float64)
    mips_eff = np.asarray(mips_eff, np.float64)
    num_pe = np.asarray(num_pe, np.int64)
    r_n, j_n = remaining.shape
    if tie is None:
        tie = np.broadcast_to(np.arange(j_n, dtype=np.float64),
                              (r_n, j_n))
    else:
        tie = np.asarray(tie, np.float64)
    if policy is None:
        policy = np.zeros((r_n,), np.int64)
    else:
        policy = np.asarray(policy, np.int64)
    if pe_blocked is None:
        pe_blocked = np.zeros((r_n,), np.float64)
    else:
        pe_blocked = np.asarray(pe_blocked, np.float64)
    if row_ok is None:
        row_ok = np.ones((r_n,), bool)
    else:
        row_ok = np.asarray(row_ok, np.float64) > 0.5
    rate = np.zeros((r_n, j_n))
    tmin = np.full((r_n,), 3.0e38)
    amin = np.full((r_n,), j_n, np.int32)
    occ = np.zeros((r_n,), np.int32)
    rank_out = np.zeros((r_n, j_n))
    for r in range(r_n):
        # Ranks mirror the engine's definition even for dead rows (rank
        # only *matters* for occupied slots of live rows).
        order = sorted(range(j_n),
                       key=lambda j: (remaining[r, j]
                                      if 0 < remaining[r, j] < 3.0e38
                                      else 3.0e38,
                                      tie[r, j]
                                      if 0 < remaining[r, j] < 3.0e38
                                      else 3.0e38,
                                      j))
        for p, j in enumerate(order):
            rank_out[r, j] = p
        pe = int(num_pe[r]) - int(pe_blocked[r])
        if not row_ok[r] or (policy[r] == 0 and pe <= 0):
            continue                       # dead row: masked entirely
        jobs = [(remaining[r, j], tie[r, j], j) for j in range(j_n)
                if 0 < remaining[r, j] < 3.0e38]
        g = len(jobs)
        occ[r] = g
        if g == 0:
            continue
        jobs.sort()
        if g <= pe or policy[r] == 1:
            shares = {j: 1.0 for _, _, j in jobs}
        else:
            k, extra = g // pe, g % pe
            msc = (pe - extra) * k
            shares = {}
            for rank, (_, _, j) in enumerate(jobs):
                shares[j] = 1.0 / (k if rank < msc else k + 1)
        best = None
        for j, sh in shares.items():
            rate[r, j] = mips_eff[r] * sh
            t = remaining[r, j] / rate[r, j]
            tmin[r] = min(tmin[r], t)
            if best is None or (t, tie[r, j]) < best[:2]:
                best = (t, tie[r, j], j)
        amin[r] = best[2]
    res = (jnp.asarray(rate, jnp.float32),
           jnp.asarray(tmin, jnp.float32),
           jnp.asarray(amin, jnp.int32),
           jnp.asarray(occ, jnp.int32))
    if with_rank:
        res = res + (jnp.asarray(rank_out, jnp.float32),)
    return res


def event_scan_slab_ref(remaining, mips_eff, num_pe, k, tie=None,
                        policy=None, pe_blocked=None, row_ok=None,
                        live=None):
    """Oracle for the k-wave slab forecast: literally iterate
    :func:`event_scan_ref` k times, after each wave advancing every job
    of a row by its own rate over that row's head completion interval
    and removing the completed column.  Rows evolve independently (each
    by its own wave clock), matching the slab kernel's row-local
    semantics.  Returns (t_wave f32[R, k] -- time from now, BIG-padded;
    col_wave i32[R, k], J-padded).
    """
    import numpy as np
    rem = np.array(remaining, np.float64)
    r_n, j_n = rem.shape
    if live is not None:
        # scalar no-op gate: live=False == every row masked off
        base = (np.ones(r_n, bool) if row_ok is None
                else np.asarray(row_ok, bool))
        row_ok = base & bool(live)
    t_acc = np.zeros((r_n,))
    t_out = np.full((r_n, k), 3.0e38)
    col_out = np.full((r_n, k), j_n, np.int32)
    for w in range(k):
        rate, tmin, amin, _ = (np.asarray(x, np.float64) for x in
                               event_scan_ref(rem, mips_eff, num_pe,
                                              tie=tie, policy=policy,
                                              pe_blocked=pe_blocked,
                                              row_ok=row_ok))
        live = amin < j_n
        dt = np.where(live, tmin, 0.0)
        t_acc = t_acc + dt
        t_out[:, w] = np.where(live, t_acc, 3.0e38)
        col_out[:, w] = amin.astype(np.int32)
        # Advance survivors, clamped to a tiny epsilon: a job tied with
        # the head rounds to 0 here but must stay visible (the kernel
        # freezes validity at wave 0), emitting its own dt~0 wave next.
        was_valid = (rem > 0.0) & (rem < 3.0e38)
        adv = np.maximum(rem - rate * dt[:, None], 1e-30)
        rem = np.where(was_valid, adv, rem)
        rem[np.arange(r_n)[live.astype(bool)],
            amin[live.astype(bool)].astype(int)] = 0.0
    return (jnp.asarray(t_out, jnp.float32),
            jnp.asarray(col_out, jnp.int32))


def event_scan_slab_assoc_ref(remaining, mips_eff, num_pe, k, tie=None,
                              policy=None, pe_blocked=None, row_ok=None,
                              live=None):
    """Float64 forward-substitution oracle of the associative slab
    operator (kernels.event_scan._slab_waves_assoc).

    The slab is a lower-triangular linear system per row: with A[w, p]
    the Fig 8 rate of the rank-p job during wave w (rank/count math
    only -- never the remaining work) and srem[p] the rank-p job's
    remaining MI, the head intervals satisfy

        dt_p = (srem_p - sum_{v<p} A[v, p] dt_v) / A[p, p]

    solved here by direct numpy forward substitution in float64 --
    an independent evaluation order from both the sequential wave
    recurrence and the matrix-compose scan.  Returns the usual
    (t_wave f32[R, k] BIG-padded, col_wave i32[R, k] J-padded).
    """
    import numpy as np
    big = 3.0e38
    rem = np.asarray(remaining, np.float64)
    r_n, j_n = rem.shape
    mips = np.asarray(mips_eff, np.float64)
    npe = np.asarray(num_pe, np.float64)
    pol = (np.zeros(r_n) if policy is None
           else np.asarray(policy, np.float64))
    blk = (np.zeros(r_n) if pe_blocked is None
           else np.asarray(pe_blocked, np.float64))
    ok = (np.ones(r_n) if row_ok is None
          else np.asarray(row_ok, np.float64))
    if live is not None:
        ok = ok * float(bool(live))
    if tie is None:
        tie = np.broadcast_to(np.arange(j_n, dtype=np.float64),
                              (r_n, j_n))
    else:
        tie = np.asarray(tie, np.float64)

    def fig8_rate(r, rank, g):
        pe = max(npe[r] - blk[r], 0.0)
        if pol[r] > 0.5 or g <= pe:
            return mips[r]
        kk = np.floor(g / max(pe, 1.0))
        extra = g - kk * max(pe, 1.0)
        msc = (pe - extra) * kk
        div = kk + (1.0 if rank >= msc else 0.0)
        return mips[r] / max(div, 1.0)

    t_out = np.full((r_n, k), big)
    col_out = np.full((r_n, k), j_n, np.int32)
    for r in range(r_n):
        pe = npe[r] - blk[r]
        dead = ok[r] < 0.5 or (pol[r] < 0.5 and pe < 0.5)
        jobs = sorted((rem[r, j], tie[r, j], j) for j in range(j_n)
                      if 0.0 < rem[r, j] < big and not dead)
        g = len(jobs)
        dt = np.zeros(min(g, k))
        for p in range(min(g, k)):
            srem_p = jobs[p][0]
            acc = srem_p - sum(fig8_rate(r, p - v, g - v) * dt[v]
                               for v in range(p))
            dt[p] = max(acc, 0.0) / max(fig8_rate(r, 0.0, g - p), 1e-30)
            t_out[r, p] = dt[:p + 1].sum()
            col_out[r, p] = jobs[p][2]
    return (jnp.asarray(t_out, jnp.float32),
            jnp.asarray(col_out, jnp.int32))


def link_scan_ref(remaining, baud, bg=None, tie=None, cap=None):
    """Fair-share link scan, directly transcribed per link row.

    remaining: [L, T] bytes (<= 0 / huge marks a free slot); baud: [L]
    link capacity; bg: [L] phantom background flows (default 0); tie:
    [L, T] FIFO tie-break key (default: col index); cap: optional [L]
    per-row rate ceiling (the shared-trunk fair share; None = no
    trunk).  Every active transfer on a link receives
    min(baud / (m + bg), cap); a link with non-positive or non-finite
    baud is dead (all outputs masked).  Returns (rate [L, T], t_min
    [L], argmin_col [L], occupancy [L]); argmin_col is T for empty (or
    dead) rows -- the contract of kernels.event_scan.link_scan.
    """
    import numpy as np
    remaining = np.asarray(remaining, np.float64)
    baud = np.asarray(baud, np.float64)
    l_n, t_n = remaining.shape
    if tie is None:
        tie = np.broadcast_to(np.arange(t_n, dtype=np.float64),
                              (l_n, t_n))
    else:
        tie = np.asarray(tie, np.float64)
    if bg is None:
        bg = np.zeros((l_n,), np.float64)
    else:
        bg = np.asarray(bg, np.float64)
    if cap is not None:
        cap = np.asarray(cap, np.float64)
    rate = np.zeros((l_n, t_n))
    tmin = np.full((l_n,), 3.0e38)
    amin = np.full((l_n,), t_n, np.int32)
    occ = np.zeros((l_n,), np.int32)
    for r in range(l_n):
        if not (0.0 < baud[r] < 3.0e38):
            continue                       # dead link: masked entirely
        xfers = [j for j in range(t_n) if 0 < remaining[r, j] < 3.0e38]
        m = len(xfers)
        occ[r] = m
        if m == 0:
            continue
        share = baud[r] / max(m + bg[r], 1.0)
        if cap is not None:
            # float32 the min like _link_math (its inputs are f32) so
            # oracle vs kernel agreement stays exact at the crossover.
            share = min(share, np.float64(np.float32(cap[r])))
        best = None
        for j in xfers:
            rate[r, j] = share
            t = remaining[r, j] / share
            tmin[r] = min(tmin[r], t)
            if best is None or (t, tie[r, j]) < best[:2]:
                best = (t, tie[r, j], j)
        amin[r] = best[2]
    return (jnp.asarray(rate, jnp.float32),
            jnp.asarray(tmin, jnp.float32),
            jnp.asarray(amin, jnp.int32),
            jnp.asarray(occ, jnp.int32))


def event_frontier_ref(cand, sizes, cuts=None):
    """Oracle for the fused event frontier: per-source python loops.

    cand: f32[C] concatenated per-source candidate instants (+inf =
    none pending); sizes: per-source segment lengths; cuts: bool[C]
    horizon-cut mask (default all True).  Returns (t_star, fired
    bool[S], counts i32[S], t_safe, per_source_min f32[S]) -- the
    contract of kernels.event_scan.event_frontier.
    """
    import numpy as np
    cand = np.asarray(cand, np.float64)
    cuts = (np.ones(cand.shape, bool) if cuts is None
            else np.asarray(cuts) > 0.5)
    mins, counts, safes = [], [], []
    off = 0
    for n in sizes:
        seg = cand[off:off + n]
        seg_cuts = cuts[off:off + n]
        mins.append(seg.min() if n else np.inf)
        safes.append(seg[seg_cuts].min() if seg_cuts.any() else np.inf)
        off += n
    t_star = min(mins) if sizes else np.inf
    off = 0
    for n in sizes:
        seg = cand[off:off + n]
        counts.append(int(np.sum(np.isfinite(seg) & (seg <= t_star))))
        off += n
    fired = [np.isfinite(m) and m <= t_star for m in mins]
    t_safe = min(safes) if sizes else np.inf
    return (jnp.asarray(t_star, jnp.float32),
            jnp.asarray(fired, bool),
            jnp.asarray(counts, jnp.int32),
            jnp.asarray(t_safe, jnp.float32),
            jnp.asarray(mins, jnp.float32))
