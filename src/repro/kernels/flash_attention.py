"""Pallas TPU flash-attention kernel (fwd) with GQA / window / softcap.

Tiling: grid = (batch x q_heads, Sq/block_q, Skv/block_kv); the innermost
grid dimension is sequential on TPU, so the online-softmax accumulators
(m, l, acc) live in VMEM scratch and persist across the KV sweep; the
output block is written once on the last KV step.  Block shapes keep the
working set in VMEM: q/o blocks [block_q, d], k/v blocks [block_kv, d],
acc [block_q, d] fp32 -- with the default 512/1024 blocks and d=128 that
is ~1.6 MB, well inside the ~16 MB VMEM budget, and both matmuls hit the
MXU at [block_q, d] x [d, block_kv] and [block_q, block_kv] x
[block_kv, d] (all dims multiples of 128 for the production head sizes).

Causal / window block pairs that are fully masked are skipped with
``pl.when`` (the XLA execution path in repro.models.attention skips them
structurally via its static pair list; the kernel grid is dense but does
no math on dead blocks).

The pure-jnp oracle is repro.kernels.ref.flash_attention_ref; correctness
is validated in interpret mode over a shape/dtype sweep in
tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, cap, block_q, block_kv, n_kv):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    iq = pl.program_id(1)
    q_lo = iq * block_q
    k_lo = ik * block_kv
    # static-shape block skip conditions (traced scalars)
    needed = jnp.asarray(True)
    if causal:
        needed &= k_lo <= q_lo + block_q - 1
    if window:
        needed &= k_lo + block_kv - 1 > q_lo - window

    @pl.when(needed)
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # [bq, d]
        k = k_ref[...].astype(jnp.float32)            # [bk, d]
        v = v_ref[...]                                # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if cap:
            s = jnp.tanh(s / cap) * cap
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_kv), 0)
        kv_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= kv_pos <= q_pos
        if window:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, 0]                     # [bq]
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [bq, d]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_ref[...][:, 0]
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, block_q: int = 512,
                    block_kv: int = 1024, interpret: bool = False):
    """q: [B, Hq, Sq, d]; k, v: [B, Hkv, Skv, d] -> [B, Hq, Sq, d]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, "pad upstream"
    n_q, n_kv = sq // block_q, skv // block_kv
    scale = d ** -0.5

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, cap=cap,
        block_q=block_q, block_kv=block_kv, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q, d),
                         lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((None, block_kv, d),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((None, block_kv, d),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
