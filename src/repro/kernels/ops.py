"""Jitted public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: compiled Pallas on TPU backends,
interpret mode elsewhere (this container is CPU-only, so tests and
benches run the kernels through the interpreter; the TPU lowering is the
TARGET and is exercised by .lower() in the dry-run-adjacent kernel
tests).

Every wrapper body runs under a ``jax.named_scope`` carrying the
kernel's public name, so device profiles (``jax.profiler.trace`` /
XProf) attribute time to ``event_scan`` / ``event_scan_slab`` /
``link_scan`` / ``event_frontier`` by name instead of a soup of fused
HLO ops -- see docs/OBSERVABILITY.md for the capture recipe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import event_scan as _event
from . import flash_attention as _flash
from . import ssd_scan as _ssd


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                    block_q=512, block_kv=1024, interpret=None):
    """q: [B, Hq, Sq, d]; k, v: [B, Hkv, Skv, d] -> [B, Hq, Sq, d]."""
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, cap=cap, block_q=block_q,
        block_kv=block_kv, interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "chunk", "block_h", "interpret"))
def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk=256, block_h=8,
             interpret=None):
    """Mamba-2 SSD over chunks; see kernels.ssd_scan for shapes."""
    return _ssd.ssd_scan(x, dt, a, b_mat, c_mat, chunk=chunk,
                         block_h=block_h,
                         interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_r", "interpret",
                                             "with_rank"))
def event_scan(remaining, mips_eff, num_pe, tie=None, policy=None,
               pe_blocked=None, row_ok=None, rank=None, *, block_r=8,
               interpret=None, with_rank=False):
    """GridSim Fig 8 share allocation + completion forecast.

    ``pe_blocked`` [R] masks reservation-held PEs out of the share pool;
    ``row_ok`` [R] masks failed resources out of every output (see
    kernels.event_scan).  Returns (rate [R, J], t_min [R], argmin_col
    [R], occupancy [R]); ``with_rank=True`` appends the per-row
    (remaining, tie) rank table f32[R, J].
    Routing: compiled Pallas on TPU (interpret=None/False); the
    vectorised XLA fallback on non-TPU hosts (interpret=None), so the
    engine hot path stays fast on CPU; Pallas interpret mode only when
    explicitly requested (interpret=True, used by the kernel tests).
    ``rank`` injects a precomputed rank table and always routes to the
    (then sort-free, purely elementwise) XLA implementation -- the
    engine's slab-fed speculative micro-steps use it on every backend.
    """
    with jax.named_scope("event_scan"):
        if rank is not None:
            return _event.event_scan_xla(remaining, mips_eff, num_pe,
                                         tie=tie, policy=policy,
                                         pe_blocked=pe_blocked,
                                         row_ok=row_ok,
                                         with_rank=with_rank, rank=rank)
        if interpret is None and jax.default_backend() != "tpu":
            return _event.event_scan_xla(remaining, mips_eff, num_pe,
                                         tie=tie, policy=policy,
                                         pe_blocked=pe_blocked,
                                         row_ok=row_ok,
                                         with_rank=with_rank)
        return _event.event_scan(remaining, mips_eff, num_pe, tie=tie,
                                 policy=policy, pe_blocked=pe_blocked,
                                 row_ok=row_ok, block_r=block_r,
                                 interpret=_auto_interpret(interpret),
                                 with_rank=with_rank)


@functools.partial(jax.jit, static_argnames=("k", "block_r", "interpret",
                                             "assoc"))
def event_scan_slab(remaining, mips_eff, num_pe, k=8, tie=None,
                    policy=None, pe_blocked=None, row_ok=None,
                    live=None, *, block_r=8, interpret=None,
                    assoc=True):
    """Next-k completion forecast per resource row in one fused call
    (the TPU-target primitive behind the engine's k-step superstep
    batching; see kernels.event_scan.event_scan_slab for semantics).

    ``live`` (scalar bool, optional) is the masked no-op gate:
    ``live=False`` returns all-sentinel waves, bitwise identical to
    masking every row off -- the sweep engine's unconditional slab
    commit relies on it.  Returns (t_wave [R, k] f32 -- time from now
    of each row's w-th completion, BIG-padded; col_wave [R, k] i32,
    J-padded).  Routing mirrors :func:`event_scan`: compiled Pallas on
    TPU, the vectorised XLA fallback on CPU hosts, Pallas interpret
    mode only on request.

    ``assoc`` (static, default True) evaluates the k waves through the
    associative wave-compose operator -- ``jax.lax.associative_scan``
    on the XLA path, a balanced product tree in-kernel -- for O(log k)
    dependent steps; ``assoc=False`` keeps the sequential k-step
    recurrence (the reference path the differential tests pin the scan
    against).  Wave 0 is bitwise identical either way.
    """
    with jax.named_scope("event_scan_slab"):
        if interpret is None and jax.default_backend() != "tpu":
            return _event.event_scan_slab_xla(remaining, mips_eff,
                                              num_pe, k, tie=tie,
                                              policy=policy,
                                              pe_blocked=pe_blocked,
                                              row_ok=row_ok, live=live,
                                              assoc=assoc)
        return _event.event_scan_slab(remaining, mips_eff, num_pe, k,
                                      tie=tie, policy=policy,
                                      pe_blocked=pe_blocked,
                                      row_ok=row_ok, live=live,
                                      block_r=block_r,
                                      interpret=_auto_interpret(interpret),
                                      assoc=assoc)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def link_scan(remaining, baud, bg=None, tie=None, cap=None, *,
              block_l=8, interpret=None):
    """Fair-share link transfer forecast (the network analogue of
    :func:`event_scan`; see kernels.event_scan.link_scan).

    ``remaining`` [L, T] bytes in flight per transfer slot, ``baud``
    [L] link capacity, ``bg`` [L] phantom background flows sharing each
    link, ``cap`` optional [L] per-row rate ceiling (the shared-trunk
    fair share computed across rows; None = private-link topology,
    bitwise-frozen legacy path).  Returns (rate [L, T], t_min [L],
    argmin_col [L], occupancy [L]).  Routing mirrors
    :func:`event_scan`: compiled Pallas on TPU, the vectorised XLA
    fallback on CPU hosts (the engine's NETWORK event source hot
    path), Pallas interpret mode only on request.
    """
    with jax.named_scope("link_scan"):
        if interpret is None and jax.default_backend() != "tpu":
            return _event.link_scan_xla(remaining, baud, bg=bg, tie=tie,
                                        cap=cap)
        return _event.link_scan(remaining, baud, bg=bg, tie=tie,
                                cap=cap, block_l=block_l,
                                interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("sizes", "interpret"))
def event_frontier(cand, sizes, cuts=None, *, interpret=None):
    """Fused superstep event frontier: one min/mask pass over the
    concatenated per-source candidate-time vectors.

    ``cand`` f32[C] (+inf = nothing pending), ``sizes`` the static
    per-source segment lengths, ``cuts`` bool[C] marking candidates
    that cut the k-step speculation horizon (source-aware horizons; see
    kernels.event_scan.event_frontier).  Returns (t_star, fired
    bool[S], counts i32[S], t_safe, per_source_min f32[S]).  Routing
    mirrors :func:`event_scan`: compiled Pallas on TPU, the vectorised
    XLA fallback on CPU hosts, Pallas interpret mode on request.
    """
    with jax.named_scope("event_frontier"):
        if interpret is None and jax.default_backend() != "tpu":
            return _event.event_frontier_xla(cand, sizes, cuts=cuts)
        return _event.event_frontier(cand, sizes, cuts=cuts,
                                     interpret=_auto_interpret(interpret))
