"""Pallas TPU kernel for the Mamba-2 SSD chunk scan (arXiv:2405.21060).

Grid = (batch, H/block_h, S/chunk); the chunk axis is innermost and
sequential on TPU, so the recurrent state [block_h, P, N] persists in
VMEM scratch across chunks (exactly the inter-chunk recurrence).  Within
a chunk, the quadratic "attention-like" form runs on the MXU:

    cum   = LT_ones[Q,Q] @ (dt * a)          (cumsum as a matmul)
    CB    = C[Q,N] @ B[Q,N]^T                (MXU)
    y_in  = (CB * decay * dt) @ x            (per-head batched MXU)
    y_out = (C * decay_q) @ state            (MXU)
    state = state * gain + (B * w)^T @ x     (MXU)

VMEM working set at production sizes (Q=256, block_h=8, P=64, N=128):
x 512 KB + decay [Q,Q,block_h] 2 MB + state 512 KB (fp32) -- ~4 MB total.
All matmul dims are multiples of 64/128 -> MXU-aligned.

Head blocking exists because B/C are shared across heads (n_groups=1):
the [Q,Q,H] decay tensor is the only H-wide intermediate, and blocking H
keeps it inside VMEM.  Oracle: repro.kernels.ref.ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = chunk
    x = x_ref[...].astype(jnp.float32)        # [Q, Hb, P]
    dt = dt_ref[...].astype(jnp.float32)      # [Q, Hb]
    a = a_ref[...].astype(jnp.float32)        # [1, Hb]
    bm = b_ref[...].astype(jnp.float32)       # [Q, N]
    cm = c_ref[...].astype(jnp.float32)       # [Q, N]
    hb, p = x.shape[1], x.shape[2]
    n = bm.shape[1]

    da = dt * a[0][None, :]                   # [Q, Hb]
    lt = jnp.tril(jnp.ones((q, q), jnp.float32))
    cum = jax.lax.dot_general(lt, da, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    seg_end = cum[-1]                         # [Hb]

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    dec = jnp.exp(cum[:, None, :] - cum[None, :, :])      # [Q,Q,Hb]
    causal = jnp.tril(jnp.ones((q, q), bool))
    dec = jnp.where(causal[:, :, None], dec, 0.0)
    w = cb[:, :, None] * dec * dt[None, :, :]             # [Q,K,Hb]

    # y_intra[q,h,p] = sum_k w[q,k,h] x[k,h,p]   (batched over h)
    w_h = jnp.transpose(w, (2, 0, 1))                     # [Hb,Q,K]
    x_h = jnp.transpose(x, (1, 0, 2))                     # [Hb,K,P]
    y_intra = jax.lax.dot_general(
        w_h, x_h, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # [Hb,Q,P]

    state = state_ref[...].reshape(hb, p, n)              # [Hb,P,N]
    dec_q = jnp.exp(cum)                                  # [Q,Hb]
    # y_inter[q,h,p] = dec_q[q,h] * sum_n c[q,n] state[h,p,n]
    cs = jax.lax.dot_general(
        jnp.broadcast_to(cm[None], (hb, q, n)), state,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # [Hb,Q,P]
    y_inter = cs * jnp.transpose(dec_q, (1, 0))[:, :, None]
    y = y_intra + y_inter                                 # [Hb,Q,P]
    y_ref[...] = jnp.transpose(y, (1, 0, 2)).astype(y_ref.dtype)

    # state update: S_h <- S_h * exp(seg_end_h) + sum_k wk[k,h] B_k x_k
    wk = jnp.exp(seg_end[None, :] - cum) * dt             # [Q,Hb]
    xw = x * wk[:, :, None]                               # [Q,Hb,P]
    xw_h = jnp.transpose(xw, (1, 2, 0))                   # [Hb,P,Q]
    s_c = jax.lax.dot_general(
        xw_h, jnp.broadcast_to(bm[None], (hb, q, n)),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # [Hb,P,N]
    new_state = state * jnp.exp(seg_end)[:, None, None] + s_c
    state_ref[...] = new_state.reshape(hb * p, n)


def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int = 256,
             block_h: int = 8, interpret: bool = False):
    """x: [B,S,H,P]; dt: [B,S,H] (>0); a: [H] (<0); b/c: [B,S,N]."""
    bs, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    block_h = min(block_h, h)
    assert s % chunk == 0 and h % block_h == 0, "pad upstream"
    nc, nh = s // chunk, h // block_h
    a2 = jnp.broadcast_to(a[None, :], (1, h))

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y = pl.pallas_call(
        kernel,
        grid=(bs, nh, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, block_h, p),
                         lambda b, ih, ic: (b, ic, ih, 0)),
            pl.BlockSpec((None, chunk, block_h),
                         lambda b, ih, ic: (b, ic, ih)),
            pl.BlockSpec((1, block_h), lambda b, ih, ic: (0, ih)),
            pl.BlockSpec((None, chunk, n), lambda b, ih, ic: (b, ic, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, ih, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, block_h, p),
                               lambda b, ih, ic: (b, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_h * p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, b_mat, c_mat)
    return y
