"""Pallas TPU kernel for the GridSim inner loop: Fig 8 PE-share
allocation + earliest-completion forecast, batched over resources.

This is the simulator's hot spot at fleet scale (the engine evaluates it
on every event over [resources x job-slots] state).  Per resource row:

  rank_j  = |{j' : remaining_j' < remaining_j}|     (within the row)
  k       = g // P,  extra = g % P,  msc = (P - extra) * k
  rate_j  = eff_mips / (k + [rank_j >= msc])        (Fig 8 shares)
  t_min   = min_j remaining_j / rate_j              (forecast event)

Tiling: grid over resource blocks; each block holds [block_r, J] state in
VMEM (J <= 256 -> <=256 KB fp32).  Ranking uses an explicit [J, J]
comparison per row -- O(J^2) VPU work that replaces the engine's XLA
lexsort; J is the per-resource job-slot bound, so the quadratic term is
tiny and fully data-parallel.  Oracle: repro.kernels.ref.event_scan_ref
(and transitively repro.core.engine._rates, which it must agree with).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38


def _kernel(remaining_ref, mips_ref, pe_ref, rate_ref, tmin_ref):
    rem = remaining_ref[...]                       # [R, J] f32
    mips = mips_ref[...]                           # [R, 1]
    npe = pe_ref[...]                              # [R, 1] f32
    r, j = rem.shape

    valid = (rem > 0.0) & (rem < BIG)
    g = jnp.sum(valid.astype(jnp.float32), axis=1, keepdims=True)  # [R,1]

    # rank within row by (remaining, index): pairwise comparison matrix
    key = jnp.where(valid, rem, BIG)
    lt = key[:, :, None] > key[:, None, :]         # j > j' strictly
    idx = jax.lax.broadcasted_iota(jnp.int32, (j, j), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (j, j), 1)
    tie = (key[:, :, None] == key[:, None, :]) & (idx > jdx)[None]
    rank = jnp.sum((lt | tie) & valid[:, None, :],
                   axis=2).astype(jnp.float32)     # [R, J]

    k = jnp.floor(g / jnp.maximum(npe, 1.0))       # [R,1] min jobs per PE
    extra = g - k * jnp.maximum(npe, 1.0)
    msc = (npe - extra) * k                        # max-share count
    divisor = k + (rank >= msc).astype(jnp.float32)
    # g <= P: everyone gets a full PE
    divisor = jnp.where(g <= npe, 1.0, divisor)
    rate = jnp.where(valid, mips / jnp.maximum(divisor, 1.0), 0.0)
    rate_ref[...] = rate

    t = jnp.where(valid, rem / jnp.maximum(rate, 1e-30), BIG)
    tmin_ref[...] = jnp.min(t, axis=1, keepdims=True)


def event_scan(remaining, mips_eff, num_pe, *, block_r: int = 8,
               interpret: bool = False):
    """remaining: [R, J] (<=0 or >=BIG marks empty slots);
    mips_eff, num_pe: [R].  Returns (rate [R, J], t_min [R])."""
    r, j = remaining.shape
    block_r = min(block_r, r)
    assert r % block_r == 0, "pad the resource axis upstream"

    rate, tmin = pl.pallas_call(
        _kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, j), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, j), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, j), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(remaining.astype(jnp.float32),
      mips_eff.astype(jnp.float32).reshape(r, 1),
      num_pe.astype(jnp.float32).reshape(r, 1))
    return rate, tmin[:, 0]
