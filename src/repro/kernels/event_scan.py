"""Pallas TPU kernel for the GridSim inner loop: Fig 8 PE-share
allocation + earliest-completion forecast, batched over resources.

This is the simulator's hot spot at fleet scale: the superstep engine
(repro.core.engine) evaluates it once per while-loop iteration over the
resource-major ``[R, J]`` job-slot table.  Per resource row:

  rank_j  = |{j' : (rem_j', tie_j') < (rem_j, tie_j)}|  (within the row)
  P_eff   = num_pe - pe_blocked                      (reservation windows)
  k       = g // P_eff,  extra = g % P_eff,  msc = (P_eff - extra) * k
  rate_j  = eff_mips / (k + [rank_j >= msc])        (Fig 8 shares; a
            space-shared row instead grants every job a whole PE)
  t_j     = remaining_j / rate_j
  t_min   = min_j t_j                               (forecast event)
  argmin  = col of the earliest completion, ties broken by tie key
  occ     = number of occupied job slots (space-shared PE occupancy)

Shape/dtype conventions: ``remaining``/``tie``/``rate`` are f32[R, J]
(J = job slots per resource, R padded to the block size); ``mips_eff``,
``num_pe``, ``policy``, ``pe_blocked``, ``row_ok`` are per-row [R]
vectors; ``t_min`` is f32[R], ``argmin_col``/``occupancy`` i32[R].

Masking inputs (both optional, identity when omitted):

  ``pe_blocked`` [R] f32 -- PEs held by advance-reservation windows.
      Time-shared rows compute Fig 8 shares over the remaining
      ``num_pe - pe_blocked`` PEs; a fully-reserved time-shared row
      contributes nothing (rate 0, excluded from argmin/occupancy).
      Space-shared rows are unaffected here: the engine enforces
      reservations at admission and never preempts residents.
  ``row_ok``     [R] bool -- resource up/registered mask (failures).
      A down row's slots are masked out of the rate, argmin and
      occupancy outputs entirely.

The per-row argmin and occupancy outputs exist so the engine needs no
second pass over the state to locate the completing job or to count busy
PEs for queue admission.

The ``tie`` input carries the engine's FIFO tie-break priority (the flat
gridlet index): equal-remaining jobs must receive MaxShare in submission
order for the Fig 9 / Table 1 trace to be reproduced exactly.  (Across
event *kinds* the engine orders same-time batches COMPLETION > FAILURE >
RECOVERY > RESERVATION > NETWORK > RETURN > ARRIVAL > CALENDAR_STEP >
BROKER; this
kernel only produces the COMPLETION forecasts.)

Tiling: grid over resource blocks; each block holds [block_r, J_pad]
state in VMEM.  The job-slot axis is **lane-tiled**: the Pallas wrappers
pad J up to a multiple of LANE = 128 (and, when the bitonic rank is
selected, to the next power of two) so every row maps cleanly onto the
8x128 VPU registers; outputs are sliced back to the caller's J and the
argmin/col sentinels re-mapped.  In-kernel ranking picks between two
exact algorithms by the *static* padded width:

  * J_pad <= RANK_BITONIC_MIN_J: the explicit [J, J] pairwise
    comparison -- O(J^2) VPU work, fully data-parallel, no lane
    shuffles, unbeatable for short rows;
  * J_pad >  RANK_BITONIC_MIN_J: an O(J log^2 J) **bitonic rank**
    (:func:`_bitonic_rank`): a compare-exchange network on (remaining,
    tie, col) triples built from static lane rolls, followed by a
    second network inverting the permutation -- the classic
    sorting-network formulation that keeps all traffic in registers.

Both produce the identical integer ranks for every valid slot (ranks of
empty slots are unused and may differ).  The crossover constant is
re-measured by ``benchmarks/engine_bench.py`` (``rank_crossover`` rows;
see docs/PERFORMANCE.md).  On CPU hosts the engine routes through
:func:`event_scan_xla`, an equivalent vectorised jnp implementation
whose per-row sort is one O(J log J) stable lexsort (the "reference
fallback" -- the Pallas path in interpret mode is reserved for kernel
tests); it optionally *accepts a precomputed rank* so the engine's
slab-fed speculative micro-steps can reuse the committing superstep's
ranking and run entirely sort-free.  Oracle:
repro.kernels.ref.event_scan_ref.

:func:`event_frontier` is the second fused primitive here: one
min/mask pass over the concatenated per-source candidate-time vectors
of the superstep engine's event sources, returning the earliest
pending instant t*, the per-source fired mask and due counts, and the
speculation horizon t_safe -- replacing a stack of 8 separate scalar
reductions per superstep.  Same three-way split (Pallas kernel / XLA
fallback / ref.event_frontier_ref oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38
INF = float("inf")
LANE = 128               # TPU lane width: job-slot axis padded to it
# Padded widths above this use the bitonic rank.  Measured (XLA CPU,
# benchmarks/engine_bench.py "_rank_crossover"): pairwise wins through
# J = 512 (1.5ms vs 5.4ms at 512) and loses decisively at 1024 (32ms
# vs 11.5ms) -- the ROADMAP's "J > 256" guess was one octave early.
# The TPU bound is also capacity: the pairwise path materialises a
# [block_r, J, J] comparison cube, which at block_r = 8, J = 1024
# is 32 MB -- past VMEM -- so the bitonic is mandatory there anyway.
RANK_BITONIC_MIN_J = 512


def _pad_j_for_kernel(j: int) -> int:
    """Lane-tiled job-slot width for the Pallas path: the next multiple
    of LANE, bumped to the next power of two once the bitonic rank is
    selected (the compare-exchange network needs a pow2 width)."""
    j_pad = -(-j // LANE) * LANE
    if j_pad > RANK_BITONIC_MIN_J:
        p = 1
        while p < j_pad:
            p *= 2
        j_pad = p
    return j_pad


def _row_masks(rem, npe, pol, blk, ok):
    """Shared masking prologue of every scan variant.

    Reservation windows shrink the PE pool of time-shared rows; a down
    (row_ok == 0) row, or a fully-reserved time-shared row, is dead:
    every slot masked out of all outputs.  Returns (npe_e [R,1] f32
    effective PE pool, valid [R,J] bool, g [R,1] f32 job count).
    """
    npe_e = jnp.maximum(npe - blk, 0.0)
    dead = (ok < 0.5) | ((pol < 0.5) & (npe_e < 0.5))
    valid = (rem > 0.0) & (rem < BIG) & ~dead
    g = jnp.sum(valid.astype(jnp.float32), axis=1, keepdims=True)
    return npe_e, valid, g


def _pairwise_rank(rem, tie, valid):
    """Within-row (remaining, tie) rank via the [J, J] comparison matrix
    -- the Pallas-side ranking (O(J^2) VPU work, fully data-parallel).
    Returns (rank [R,J] f32, key, tkey) with invalid slots keyed BIG."""
    key = jnp.where(valid, rem, BIG)
    tkey = jnp.where(valid, tie, BIG)
    lt = key[:, :, None] > key[:, None, :]         # j strictly after j'
    tie_lt = (key[:, :, None] == key[:, None, :]) & \
        (tkey[:, :, None] > tkey[:, None, :])
    rank = jnp.sum((lt | tie_lt) & valid[:, None, :],
                   axis=2).astype(jnp.float32)
    return rank, key, tkey


def _lexsort_rank(rem, tie, valid):
    """Same rank contract as :func:`_pairwise_rank` via one stable
    O(J log J) lexsort -- the XLA-fallback ranking."""
    key = jnp.where(valid, rem, BIG)
    tkey = jnp.where(valid, tie, BIG)
    order = jnp.lexsort((tkey, key), axis=-1)       # cols by (rem, tie)
    rank = jnp.argsort(order, axis=-1).astype(jnp.float32)  # inverse perm
    return rank, key, tkey


def _bitonic_exchange(arrays, lane, stride, size):
    """One compare-exchange stage of the bitonic network, lexicographic
    on ``(arrays[0], arrays[1])``; the rest ride along as payload.

    Element ``i`` pairs with ``i ^ stride`` -- reached with two lane
    rolls and a select, so the whole network lowers to VPU register
    traffic (no gathers).  ``size`` is the current bitonic block length
    (ascending where ``i & size == 0``); both may be traced scalars
    (the stage schedule runs under lax.scan).
    """
    upper = (lane & stride) != 0          # I am the higher lane of my pair
    asc = (lane & size) == 0              # my block sorts ascending
    partner = [jnp.where(upper, jnp.roll(a, stride, axis=-1),
                         jnp.roll(a, -stride, axis=-1)) for a in arrays]
    k, tk, pk, ptk = arrays[0], arrays[1], partner[0], partner[1]
    mine_gt = (k > pk) | ((k == pk) & (tk > ptk))
    partner_gt = (pk > k) | ((pk == k) & (ptk > tk))
    take = jnp.where(upper == asc, partner_gt, mine_gt)
    return [jnp.where(take, p, a) for a, p in zip(arrays, partner)]


def _bitonic_sort(arrays):
    """Bitonic-sort ``arrays`` (lex keys ``arrays[0], arrays[1]`` +
    payload) along the last axis, which must be a power of two.

    The O(log^2 J) stage schedule runs under two nested
    ``lax.fori_loop``s with the (size, stride) pair derived from the
    loop indices by scalar shifts, so the compare-exchange body
    compiles exactly once (an unrolled network blows XLA CPU compile
    time up by minutes at J >= 512, and Pallas kernels cannot capture
    a constant schedule array), at the cost of the rolls taking traced
    shifts.
    """
    n = arrays[0].shape[-1]
    assert n & (n - 1) == 0, "bitonic width must be a power of two"
    lane = jax.lax.broadcasted_iota(jnp.int32, arrays[0].shape,
                                    arrays[0].ndim - 1)
    n_outer = max(n.bit_length() - 1, 0)            # log2(n)

    def outer(k, arrs):
        size = jnp.int32(2) << k                    # 2, 4, ..., n

        def inner(j, arrs):
            stride = size >> (j + 1)                # size/2, ..., 1
            return tuple(_bitonic_exchange(list(arrs), lane, stride,
                                           size))

        return jax.lax.fori_loop(0, k + 1, inner, arrs)

    return list(jax.lax.fori_loop(0, n_outer, outer, tuple(arrays)))


def _bitonic_rank(rem, tie, valid):
    """Same valid-slot rank contract as :func:`_pairwise_rank` /
    :func:`_lexsort_rank` in O(J log^2 J) compare-exchanges.

    Two network passes: sort ``(key, tie, col)`` triples, then sort the
    resulting column permutation back against a position payload --
    sorting a permutation by value *is* its inverse, i.e. the rank.
    Ranks of invalid slots (all keyed (BIG, BIG)) are an arbitrary
    permutation of the tail positions -- unused by every consumer, but
    note they differ from the other two implementations' tail ranks.
    Requires a power-of-two J (the wrappers pad).
    """
    key = jnp.where(valid, rem, BIG)
    tkey = jnp.where(valid, tie, BIG)
    col = jax.lax.broadcasted_iota(jnp.float32, rem.shape, rem.ndim - 1)
    _, _, scol = _bitonic_sort([key, tkey, col])
    pos = jax.lax.broadcasted_iota(jnp.float32, rem.shape, rem.ndim - 1)
    zero = jnp.zeros_like(scol)
    _, _, rank = _bitonic_sort([scol, zero, pos])
    return rank, key, tkey


def _kernel_rank(rem, tie, valid):
    """Static-shape rank selection for the Pallas kernels: pairwise
    O(J^2) below the crossover, bitonic O(J log^2 J) above it."""
    if rem.shape[-1] > RANK_BITONIC_MIN_J:
        return _bitonic_rank(rem, tie, valid)
    return _pairwise_rank(rem, tie, valid)


def _fig8_rates(rem, rank, valid, g, mips, npe_e, pol):
    """Fig 8 share divisor -> per-slot rate, shared by all variants."""
    k = jnp.floor(g / jnp.maximum(npe_e, 1.0))     # [R,1] min jobs per PE
    extra = g - k * jnp.maximum(npe_e, 1.0)
    msc = (npe_e - extra) * k                      # max-share count
    divisor = k + (rank >= msc).astype(jnp.float32)
    # g <= P_eff: everyone gets a full PE
    divisor = jnp.where(g <= npe_e, 1.0, divisor)
    # space-shared rows: every resident job owns a whole PE
    divisor = jnp.where(pol > 0.5, 1.0, divisor)
    return jnp.where(valid, mips / jnp.maximum(divisor, 1.0), 0.0)


def _kernel(remaining_ref, tie_ref, mips_ref, pe_ref, policy_ref,
            blocked_ref, ok_ref, rate_ref, tmin_ref, amin_ref, occ_ref,
            *maybe_rank_ref):
    rem = remaining_ref[...]                       # [R, J] f32
    tie = tie_ref[...]                             # [R, J] f32
    mips = mips_ref[...]                           # [R, 1]
    npe = pe_ref[...]                              # [R, 1] f32
    pol = policy_ref[...]                          # [R, 1] f32 (1 = space)
    blk = blocked_ref[...]                         # [R, 1] f32 reserved PEs
    ok = ok_ref[...]                               # [R, 1] f32 (1 = up)
    r, j = rem.shape

    npe_e, valid, g = _row_masks(rem, npe, pol, blk, ok)
    rank, key, tkey = _kernel_rank(rem, tie, valid)
    rate = _fig8_rates(rem, rank, valid, g, mips, npe_e, pol)
    rate_ref[...] = rate

    t = jnp.where(valid, rem / jnp.maximum(rate, 1e-30), BIG)
    tmin = jnp.min(t, axis=1, keepdims=True)
    tmin_ref[...] = tmin

    # per-row argmin col, FIFO ties broken by the tie key
    at_min = (t <= tmin) & valid
    cand = jnp.where(at_min, tkey, BIG)
    tie_min = jnp.min(cand, axis=1, keepdims=True)
    col = jax.lax.broadcasted_iota(jnp.int32, (r, j), 1)
    amin_ref[...] = jnp.min(
        jnp.where(at_min & (cand <= tie_min), col, j),
        axis=1, keepdims=True)
    occ_ref[...] = g.astype(jnp.int32)
    if maybe_rank_ref:
        maybe_rank_ref[0][...] = rank


def _default_inputs(remaining, tie, policy, pe_blocked, row_ok):
    r, j = remaining.shape
    if tie is None:
        tie = jnp.broadcast_to(
            jnp.arange(j, dtype=jnp.float32)[None, :], (r, j))
    if policy is None:
        policy = jnp.zeros((r,), jnp.float32)
    if pe_blocked is None:
        pe_blocked = jnp.zeros((r,), jnp.float32)
    if row_ok is None:
        row_ok = jnp.ones((r,), jnp.float32)
    return (remaining.astype(jnp.float32), jnp.asarray(tie, jnp.float32),
            jnp.asarray(policy, jnp.float32).reshape(r),
            jnp.asarray(pe_blocked, jnp.float32).reshape(r),
            jnp.asarray(row_ok, jnp.float32).reshape(r))


def _lane_pad(remaining, tie, j: int):
    """Pad the job-slot axis for the Pallas path (see module docstring);
    padded slots are empty (remaining 0) with BIG tie keys."""
    j_pad = _pad_j_for_kernel(j)
    if j_pad == j:
        return remaining, tie, j_pad
    pad = ((0, 0), (0, j_pad - j))
    return (jnp.pad(remaining, pad),
            jnp.pad(tie, pad, constant_values=BIG), j_pad)


def event_scan(remaining, mips_eff, num_pe, tie=None, policy=None,
               pe_blocked=None, row_ok=None, *,
               block_r: int = 8, interpret: bool = False,
               with_rank: bool = False):
    """remaining: [R, J] (<=0 or >=BIG marks empty slots); tie: [R, J]
    FIFO tie-break priority (defaults to the col index); mips_eff,
    num_pe, policy: [R] (policy 0 = time-shared, 1 = space-shared);
    pe_blocked: [R] reservation-held PEs (default 0); row_ok: [R]
    up-mask (default all-up).  Returns (rate [R, J], t_min [R],
    argmin_col [R] i32, occupancy [R] i32); argmin_col is J for empty
    (or dead) rows.  ``with_rank=True`` appends the per-row (remaining,
    tie) rank table f32[R, J] (ranks of empty slots are arbitrary).

    The job-slot axis is lane-tiled internally (padded to LANE
    multiples, pow2 once the bitonic rank engages) and outputs sliced
    back, so callers never see the padding.
    """
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    remaining, tie, j_pad = _lane_pad(remaining, tie, j)
    block_r = min(block_r, r)
    assert r % block_r == 0, "pad the resource axis upstream"

    out_specs = [
        pl.BlockSpec((block_r, j_pad), lambda i: (i, 0)),
        pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((r, j_pad), jnp.float32),
        jax.ShapeDtypeStruct((r, 1), jnp.float32),
        jax.ShapeDtypeStruct((r, 1), jnp.int32),
        jax.ShapeDtypeStruct((r, 1), jnp.int32),
    ]
    if with_rank:
        out_specs.append(pl.BlockSpec((block_r, j_pad), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((r, j_pad), jnp.float32))
    out = pl.pallas_call(
        _kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, j_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, j_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(remaining, tie,
      mips_eff.astype(jnp.float32).reshape(r, 1),
      num_pe.astype(jnp.float32).reshape(r, 1),
      policy.reshape(r, 1),
      pe_blocked.reshape(r, 1),
      row_ok.reshape(r, 1))
    rate, tmin, amin, occ = out[:4]
    # un-pad: padded slots never win the argmin, so the only out-of-J
    # value is the empty/dead-row sentinel j_pad -> remap to J.
    amin = jnp.minimum(amin[:, 0], j)
    res = (rate[:, :j], tmin[:, 0], amin, occ[:, 0])
    if with_rank:
        res = res + (out[4][:, :j],)
    return res


def event_scan_xla(remaining, mips_eff, num_pe, tie=None, policy=None,
                   pe_blocked=None, row_ok=None, *, with_rank=False,
                   rank=None):
    """Vectorised jnp fallback with identical semantics to the kernel.

    The per-row O(J log J) lexsort replaces the kernel's O(J^2) pairwise
    rank, which makes it the right path for CPU hosts where Pallas would
    run interpreted.  Bitwise-identical share arithmetic to ``_kernel``.

    ``with_rank=True`` appends the rank table to the outputs.  ``rank``
    (f32[R, J]) injects a precomputed rank and skips the lexsort
    entirely -- the engine's slab-fed speculative micro-steps pass the
    committing superstep's rank (shifted by the departed heads), making
    the whole scan sort-free.  The caller owns the proof that the
    injected rank equals the fresh lexsort rank on every valid slot
    (engine._partition_ok); everything downstream of the rank is the
    identical arithmetic either way.
    """
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    mips = mips_eff.astype(jnp.float32)[:, None]
    npe = num_pe.astype(jnp.float32)[:, None]
    pol = policy[:, None]
    blk = pe_blocked[:, None]
    ok = row_ok[:, None]

    npe_e, valid, g = _row_masks(remaining, npe, pol, blk, ok)
    if rank is None:
        rank, key, tkey = _lexsort_rank(remaining, tie, valid)
    else:
        rank = jnp.asarray(rank, jnp.float32)
        tkey = jnp.where(valid, tie, BIG)
    rate = _fig8_rates(remaining, rank, valid, g, mips, npe_e, pol)

    t = jnp.where(valid, remaining / jnp.maximum(rate, 1e-30), BIG)
    tmin = jnp.min(t, axis=1, keepdims=True)
    at_min = (t <= tmin) & valid
    cand = jnp.where(at_min, tkey, BIG)
    tie_min = jnp.min(cand, axis=1, keepdims=True)
    col = jnp.broadcast_to(jnp.arange(j, dtype=jnp.int32)[None, :], (r, j))
    amin = jnp.min(jnp.where(at_min & (cand <= tie_min), col, j), axis=1)
    res = (rate, tmin[:, 0], amin,
           jnp.sum(valid, axis=1, dtype=jnp.int32))
    if with_rank:
        res = res + (rank,)
    return res


# ----------------------------------------------------------------------
# k-wave time-slab forecast: the next k completions per row in ONE pass.
# ----------------------------------------------------------------------
#
# The key fact making a whole slab computable from a single rank pass:
# within a row evolving under uninterrupted Fig 8 dynamics, jobs finish
# exactly in (remaining, tie) sort order.  The rank-0 job holds MaxShare
# and the smallest remaining, so it finishes first; after it leaves, the
# order among the survivors is preserved (smaller-remaining jobs always
# hold a rate at least as high, so gaps never close).  Ranks therefore
# never need re-sorting between waves -- wave w completes the rank-w job
# -- and the per-superstep cost of 3 segmented sorts collapses into one
# rank pass followed by k cheap analytic advance steps.

def _slab_waves(rem, rank, valid, g, mips, npe_e, pol, col, k):
    """Shared wave recurrence of the slab forecast (jnp ops only, so the
    Pallas kernel body and the XLA fallback run the same arithmetic).

    rem/rank [R, J] f32, valid [R, J] bool, col [R, J] i32 (col index);
    g/mips/npe_e/pol [R, 1] f32.  Returns (t_wave f32[R, k] -- time from
    now of the row's w-th completion, BIG-padded; col_wave i32[R, k] --
    completing column, J-padded).  Wave 0 equals event_scan's
    (t_min, argmin_col).
    """
    r, j = rem.shape
    t_acc = jnp.zeros((r, 1), jnp.float32)
    ts, cols = [], []
    for w in range(k):
        # wave w = the single-scan share formula over the survivors,
        # with job count and ranks shifted by the w departed heads
        active = valid & (rank >= w)
        rate = _fig8_rates(rem, rank - w, active, g - w, mips, npe_e,
                           pol)
        head = valid & (rank == w)
        has = jnp.sum(head.astype(jnp.float32), axis=1, keepdims=True) > 0
        dt = jnp.sum(jnp.where(head, rem / jnp.maximum(rate, 1e-30), 0.0),
                     axis=1, keepdims=True)
        t_acc = t_acc + jnp.where(has, dt, 0.0)
        ts.append(jnp.where(has, t_acc, BIG))
        cols.append(jnp.where(
            has, jnp.sum(jnp.where(head, col, 0), axis=1, keepdims=True),
            j).astype(jnp.int32))
        # advance the survivors; the head leaves the table (a tied
        # neighbour may round below 0 -- clamped, it emits a dt=0 wave)
        rem = jnp.where(head, 0.0, jnp.where(
            active, jnp.maximum(rem - rate * dt, 0.0), rem))
    return jnp.concatenate(ts, axis=1), jnp.concatenate(cols, axis=1)


# --- associative-scan formulation of the same slab -------------------
#
# The sequential recurrence above has a hidden linear structure: the
# Fig 8 rate of a job depends only on its *rank*, the wave index and
# the row statics -- never on the remaining work.  So the whole slab is
# a lower-triangular linear system.  Let A[w, p] be the rate the rank-p
# job runs at during wave w (zero once p < w or p >= g), and srem[p]
# the remaining MI of the rank-p job at wave 0.  The wave-p head
# interval then satisfies the forward substitution
#
#   dt_p = (srem_p - sum_{v<p} A[v, p] * dt_v) / A[p, p]
#
# and each wave is one homogeneous (k+1)x(k+1) matrix acting on the
# state vector (dt_0 .. dt_{k-1}, 1): identity everywhere except row p,
# which holds (-A[v, p]/A[p, p] for v < p, 0, srem_p/A[p, p]).  Matrix
# product is associative, so the composite of all k waves -- whose last
# column IS the dt vector -- evaluates in O(log k) dependent steps via
# ``jax.lax.associative_scan`` (XLA path) or a balanced static product
# tree (Pallas path), instead of k dependent wave steps.  Within-row
# completion order never inverts under Fig 8 (see the note above), so
# in exact arithmetic every dt_p is nonnegative and the sequential
# path's per-wave clamp only ever fires on exact ties; one final
# clamp ``max(dt, 0)`` reproduces it to rounding.  Wave 0's row
# composes through untouched identity rows, so t_wave[:, 0] stays
# *bitwise* equal to the sequential path (and to ``event_scan``).

def _mats_mul(b, a):
    """Batched (k+1)x(k+1) matrix product ``b @ a`` written as a
    broadcast-multiply-sum so the Pallas kernel body lowers to plain
    VPU ops (no dot_general on tiny non-tile shapes)."""
    return jnp.sum(b[..., :, :, None] * a[..., None, :, :], axis=-2)


def _compose_waves(a, b):
    """The associative wave-compose operator: ``b`` after ``a``.

    Operands are stacks of homogeneous wave matrices [..., k+1, k+1];
    composing later-wave ``b`` onto earlier-prefix ``a`` is the matrix
    product ``b @ a``, which is associative -- the property test in
    tests/test_kernels.py checks it on random wave matrices.
    """
    return _mats_mul(b, a)


def _slab_assoc_inputs(rem, rank, valid, g, mips, npe_e, pol, col, k):
    """Rank-indexed slab inputs: per-wave rate table A f32[R, k, k]
    (A[:, w, p] = wave-w rate of the rank-p job), head remaining
    srem f32[R, k], head column scol i32[R, k], wave-exists mask
    has bool[R, k] (rank p exists iff p < g)."""
    r, j = rem.shape
    w_i = jax.lax.broadcasted_iota(jnp.float32, (1, k, k), 1)
    p_i = jax.lax.broadcasted_iota(jnp.float32, (1, k, k), 2)
    g3 = g[:, :, None]                                  # [R, 1, 1]
    act = (p_i >= w_i) & (p_i < g3)
    a_mat = _fig8_rates(p_i, p_i - w_i, act, g3 - w_i, mips[:, :, None],
                        npe_e[:, :, None], pol[:, :, None])
    p1 = jax.lax.broadcasted_iota(jnp.float32, (r, k), 1)
    has = p1 < g                                        # [R, k]
    srems, scols = [], []
    for p in range(k):
        head = valid & (rank == p)
        srems.append(jnp.sum(jnp.where(head, rem, 0.0), axis=1,
                             keepdims=True))
        scols.append(jnp.sum(jnp.where(head, col, 0), axis=1,
                             keepdims=True))
    return (a_mat, jnp.concatenate(srems, axis=1),
            jnp.concatenate(scols, axis=1), has)


def _wave_matrices(a_mat, srem, k):
    """The k homogeneous wave matrices as a list of [R, k+1, k+1].

    Entries are clipped to the finite +-BIG range: a zero-rate head
    (mips 0 under full calendar load) divides by the 1e-30 guard like
    the sequential path, and an inf entry would poison unrelated rows
    of the product with 0 * inf = nan.
    """
    row = jax.lax.broadcasted_iota(jnp.int32, (1, k + 1, k + 1), 1)
    colx = jax.lax.broadcasted_iota(jnp.int32, (1, k + 1, k + 1), 2)
    eye = (row == colx).astype(jnp.float32)
    v_i = jax.lax.broadcasted_iota(jnp.float32, (1, k), 1)
    mats = []
    for p in range(k):
        d = jnp.maximum(a_mat[:, p, p], 1e-30)[:, None]      # [R, 1]
        coeff = jnp.where(v_i < p, -a_mat[:, :, p] / d, 0.0)  # [R, k]
        rowvals = jnp.clip(
            jnp.concatenate([coeff, srem[:, p:p + 1] / d], axis=1),
            -BIG, BIG)                                       # [R, k+1]
        mats.append(jnp.where(row == p, rowvals[:, None, :], eye))
    return mats


def _slab_waves_assoc(rem, rank, valid, g, mips, npe_e, pol, col, k,
                      *, tree=False):
    """Associative-scan evaluation of :func:`_slab_waves` -- same
    signature and (t_wave, col_wave) contract, O(log k) dependent
    steps.  ``tree=True`` composes via a balanced static product tree
    (the Pallas kernel body); the default routes through
    ``jax.lax.associative_scan``.
    """
    r, j = rem.shape
    a_mat, srem, scol, has = _slab_assoc_inputs(
        rem, rank, valid, g, mips, npe_e, pol, col, k)
    mats = _wave_matrices(a_mat, srem, k)
    if tree:
        # balanced static product tree; identity padding keeps pairs
        # whole (built from broadcasted_iota -- Mosaic-safe, no 1D iota)
        row = jax.lax.broadcasted_iota(jnp.int32, (1, k + 1, k + 1), 1)
        colx = jax.lax.broadcasted_iota(jnp.int32, (1, k + 1, k + 1), 2)
        eye = (row == colx).astype(jnp.float32)
        while len(mats) > 1:
            if len(mats) % 2:
                mats.append(eye)
            mats = [_compose_waves(mats[i], mats[i + 1])
                    for i in range(0, len(mats), 2)]
        comp = mats[0]
    else:
        stacked = jnp.stack(mats, axis=0)        # [k, R, k+1, k+1]
        comp = jax.lax.associative_scan(_compose_waves, stacked)[-1]
    dt = jnp.maximum(jnp.where(has, comp[:, :k, k], 0.0), 0.0)
    t_wave = jnp.where(has, jnp.cumsum(dt, axis=1), BIG)
    col_wave = jnp.where(has, scol, j).astype(jnp.int32)
    return t_wave, col_wave


def _slab_kernel(remaining_ref, tie_ref, mips_ref, pe_ref, policy_ref,
                 blocked_ref, ok_ref, t_ref, col_ref, *, k, assoc):
    rem = remaining_ref[...]
    tie = tie_ref[...]
    mips = mips_ref[...]
    npe = pe_ref[...]
    pol = policy_ref[...]
    blk = blocked_ref[...]
    ok = ok_ref[...]
    r, j = rem.shape

    npe_e, valid, g = _row_masks(rem, npe, pol, blk, ok)
    # one (remaining, tie) rank pass for the whole slab -- pairwise or
    # bitonic by the static padded width (see _kernel_rank)
    rank, _, _ = _kernel_rank(rem, tie, valid)
    col = jax.lax.broadcasted_iota(jnp.int32, (r, j), 1)
    if assoc:
        t_w, col_w = _slab_waves_assoc(rem, rank, valid, g, mips, npe_e,
                                       pol, col, k, tree=True)
    else:
        t_w, col_w = _slab_waves(rem, rank, valid, g, mips, npe_e, pol,
                                 col, k)
    t_ref[...] = t_w
    col_ref[...] = col_w


def event_scan_slab(remaining, mips_eff, num_pe, k, tie=None, policy=None,
                    pe_blocked=None, row_ok=None, live=None, *,
                    block_r: int = 8, interpret: bool = False,
                    assoc: bool = True):
    """Forecast each row's next ``k`` completions in one kernel call.

    Same inputs/masking as :func:`event_scan` plus the static slab depth
    ``k`` and an optional scalar ``live`` gate: ``live=False`` turns the
    whole call into a masked no-op superstep -- every row is treated as
    masked off, so all k waves come back as the (BIG, J) empty-wave
    sentinel, bitwise identical to passing ``row_ok=False`` everywhere.
    The sweep engine commits slabs unconditionally and relies on this
    (one traced computation, no cond/select pair; see
    engine.step_sweep).  Returns ``(t_wave f32[R, k], col_wave i32[R, k])``: the time
    from now (NOT absolute time) and column of the row's w-th completion
    under uninterrupted Fig 8 dynamics -- shares recomputed in-register
    after every wave -- with BIG / J padding past the row's job count.
    Wave 0 is exactly ``event_scan``'s ``(t_min, argmin_col)``; wave
    ``w`` equals ``event_scan`` re-applied after removing the previous
    heads and advancing the survivors (the oracle iterates exactly
    that).  Space-shared rows free their PE on completion but admit
    nothing (queue admission is engine policy, not kernel math), so for
    them the slab is a forecast, not a commitment, as soon as a queue
    exists.  The [R_pad, J] state stays resident in VMEM across all k
    waves -- one rank pass amortised over the slab, instead of 3
    segmented sorts per superstep.

    ``assoc`` (static, default True) evaluates the waves through the
    associative wave-compose operator (O(log k) dependent steps, a
    balanced product tree in-kernel); ``assoc=False`` keeps the
    sequential k-step recurrence as the reference path.  Wave 0 is
    bitwise identical between the two; later waves agree to rounding
    (the same final values through a different summation order).
    """
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    if live is not None:
        row_ok = jnp.where(jnp.asarray(live, bool), row_ok, 0.0)
    remaining, tie, j_pad = _lane_pad(remaining, tie, j)
    block_r = min(block_r, r)
    assert r % block_r == 0, "pad the resource axis upstream"
    assert k >= 1

    t_w, col_w = pl.pallas_call(
        functools.partial(_slab_kernel, k=k, assoc=assoc),
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, j_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, j_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
        ],
        interpret=interpret,
    )(remaining, tie,
      mips_eff.astype(jnp.float32).reshape(r, 1),
      num_pe.astype(jnp.float32).reshape(r, 1),
      policy.reshape(r, 1),
      pe_blocked.reshape(r, 1),
      row_ok.reshape(r, 1))
    # un-pad the wave columns: the only out-of-J value is the padded
    # empty-wave sentinel j_pad -> remap to the caller's J.
    return t_w, jnp.minimum(col_w, j)


def event_scan_slab_xla(remaining, mips_eff, num_pe, k, tie=None,
                        policy=None, pe_blocked=None, row_ok=None,
                        live=None, *, assoc: bool = True):
    """Vectorised jnp fallback for :func:`event_scan_slab` -- identical
    wave arithmetic, with the kernel's O(J^2) pairwise rank replaced by
    one O(J log J) lexsort.  ``assoc`` (default True) evaluates the
    waves through ``jax.lax.associative_scan`` over the homogeneous
    wave matrices; ``assoc=False`` runs the sequential recurrence
    (shared ``_slab_waves``)."""
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    if live is not None:
        row_ok = jnp.where(jnp.asarray(live, bool), row_ok, 0.0)
    mips = mips_eff.astype(jnp.float32)[:, None]
    npe = num_pe.astype(jnp.float32)[:, None]
    pol = policy[:, None]
    blk = pe_blocked[:, None]
    ok = row_ok[:, None]

    npe_e, valid, g = _row_masks(remaining, npe, pol, blk, ok)
    rank, _, _ = _lexsort_rank(remaining, tie, valid)
    col = jnp.broadcast_to(jnp.arange(j, dtype=jnp.int32)[None, :], (r, j))
    waves = _slab_waves_assoc if assoc else _slab_waves
    return waves(remaining, rank, valid, g, mips, npe_e, pol, col, k)


# ----------------------------------------------------------------------
# Link scan: fair-share transfer forecast per link row, the network
# analogue of the Fig 8 event scan.
# ----------------------------------------------------------------------
#
# The network subsystem (repro.core.network / the engine's NETWORK event
# source) keeps in-flight transfers in a resource-major ``[L, T]``
# transfer-slot table exactly mirroring the ``[R, J]`` job-slot table:
# ``remaining`` holds bytes instead of MI, and the per-row "policy" is
# fixed -- every concurrent transfer on a link receives an equal
# **fair share** of the link's baud rate.  With ``m`` active transfers
# and ``bg`` phantom background flows riding the same link:
#
#   rate_i = baud / (m + bg)        for every active transfer i
#   t_i    = remaining_i / rate_i
#   t_min  = min_i t_i              (the link's next transfer completion)
#
# which is Fig 8 with P = 1 PE (min_jobs = g, everyone in the MaxShare
# set) plus the background-traffic offset on the divisor.  Because the
# share is uniform there is no rank to compute, so the scan is sort-free
# by construction on every backend -- the engine's piecewise-constant
# transfer integration needs no slab carry on the link side.
#
# Three-way split like event_scan: Pallas kernel (job/transfer axis
# lane-tiled to LANE multiples), vectorised XLA fallback, numpy oracle
# (ref.link_scan_ref); all share _link_math for bitwise-identical
# arithmetic.

def _link_math(rem, baud, bg, tie, cap=None):
    """Shared fair-share arithmetic (jnp only -- runs inside the Pallas
    kernel body and as the XLA fallback).

    rem/tie [L, T] f32 (rem <= 0 or >= BIG marks a free slot);
    baud/bg [L, 1] f32.  A link with non-positive or non-finite baud is
    dead: the engine's ``network.link_tabled`` predicate never routes a
    transfer onto one, but the row is masked here too so the outputs
    stay well-defined.  ``cap`` [L, 1] f32 is an optional per-row
    fair-share rate ceiling -- the shared-trunk divisor: rows behind a
    common WAN trunk get ``trunk_baud / (M + trunk_bg)`` with M the
    trunk-wide occupancy (computed by the caller across rows, since a
    row-blocked kernel grid cannot gather cross-row; see
    core/network.trunk_rate_cap).  ``cap=None`` is the private-link
    topology, bitwise-identical to the pre-trunk kernel.  Returns
    (rate [L, T], t_min [L, 1], argmin_col [L, 1] i32, occupancy
    [L, 1] i32).
    """
    l, t_n = rem.shape
    live = (baud > 0.0) & (baud < BIG)
    valid = (rem > 0.0) & (rem < BIG) & live
    m = jnp.sum(valid.astype(jnp.float32), axis=1, keepdims=True)
    rate = jnp.where(valid, baud / jnp.maximum(m + bg, 1.0), 0.0)
    if cap is not None:
        rate = jnp.where(valid, jnp.minimum(rate, cap), 0.0)
    t = jnp.where(valid, rem / jnp.maximum(rate, 1e-30), BIG)
    tmin = jnp.min(t, axis=1, keepdims=True)
    tkey = jnp.where(valid, tie, BIG)
    at_min = (t <= tmin) & valid
    cand = jnp.where(at_min, tkey, BIG)
    tie_min = jnp.min(cand, axis=1, keepdims=True)
    col = jax.lax.broadcasted_iota(jnp.int32, (l, t_n), 1)
    amin = jnp.min(jnp.where(at_min & (cand <= tie_min), col, t_n),
                   axis=1, keepdims=True)
    return rate, tmin, amin, m.astype(jnp.int32)


def _link_kernel(rem_ref, tie_ref, baud_ref, bg_ref, rate_ref,
                 tmin_ref, amin_ref, occ_ref):
    rate, tmin, amin, occ = _link_math(rem_ref[...], baud_ref[...],
                                       bg_ref[...], tie_ref[...])
    rate_ref[...] = rate
    tmin_ref[...] = tmin
    amin_ref[...] = amin
    occ_ref[...] = occ


def _link_kernel_cap(rem_ref, tie_ref, baud_ref, bg_ref, cap_ref,
                     rate_ref, tmin_ref, amin_ref, occ_ref):
    rate, tmin, amin, occ = _link_math(rem_ref[...], baud_ref[...],
                                       bg_ref[...], tie_ref[...],
                                       cap=cap_ref[...])
    rate_ref[...] = rate
    tmin_ref[...] = tmin
    amin_ref[...] = amin
    occ_ref[...] = occ


def _link_defaults(remaining, tie, bg):
    l, t_n = remaining.shape
    if tie is None:
        tie = jnp.broadcast_to(
            jnp.arange(t_n, dtype=jnp.float32)[None, :], (l, t_n))
    if bg is None:
        bg = jnp.zeros((l,), jnp.float32)
    return (remaining.astype(jnp.float32), jnp.asarray(tie, jnp.float32),
            jnp.asarray(bg, jnp.float32).reshape(l))


def link_scan(remaining, baud, bg=None, tie=None, cap=None, *,
              block_l: int = 8, interpret: bool = False):
    """Fair-share link scan over the [L, T] transfer-slot table.

    remaining: [L, T] bytes still to move (<= 0 or >= BIG marks a free
    slot); baud: [L] link capacity in bytes/time-unit; bg: [L] phantom
    background flows sharing each link (default 0; may be fractional);
    tie: [L, T] FIFO tie-break key for the argmin (defaults to the col
    index; the engine passes the flat gridlet index); cap: optional
    [L] per-row fair-share rate ceiling -- the shared-trunk divisor
    (see ``_link_math``; None = private-link topology, bitwise-frozen
    legacy kernel).  Returns (rate [L, T], t_min [L], argmin_col [L]
    i32, occupancy [L] i32); argmin_col is T for empty (or dead) rows.
    The transfer axis is lane-tiled internally (padded to LANE
    multiples, outputs sliced back) -- no power-of-two bump: fair
    shares need no rank network.
    """
    l, t_n = remaining.shape
    remaining, tie, bg = _link_defaults(remaining, tie, bg)
    t_pad = max(-(-t_n // LANE) * LANE, LANE)
    if t_pad != t_n:
        pad = ((0, 0), (0, t_pad - t_n))
        remaining = jnp.pad(remaining, pad)
        tie = jnp.pad(tie, pad, constant_values=BIG)
    block_l = min(block_l, l)
    assert l % block_l == 0, "pad the link axis upstream"

    row_spec = pl.BlockSpec((block_l, t_pad), lambda i: (i, 0))
    col_spec = pl.BlockSpec((block_l, 1), lambda i: (i, 0))
    in_specs = [row_spec, row_spec, col_spec, col_spec]
    inputs = [remaining, tie,
              jnp.asarray(baud, jnp.float32).reshape(l, 1),
              bg.reshape(l, 1)]
    kernel = _link_kernel
    if cap is not None:
        kernel = _link_kernel_cap
        in_specs = in_specs + [col_spec]
        inputs = inputs + [jnp.asarray(cap, jnp.float32).reshape(l, 1)]

    rate, tmin, amin, occ = pl.pallas_call(
        kernel,
        grid=(l // block_l,),
        in_specs=in_specs,
        out_specs=[
            row_spec,
            col_spec,
            col_spec,
            col_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, t_pad), jnp.float32),
            jax.ShapeDtypeStruct((l, 1), jnp.float32),
            jax.ShapeDtypeStruct((l, 1), jnp.int32),
            jax.ShapeDtypeStruct((l, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    # un-pad: the only out-of-T value is the empty/dead-row sentinel
    # t_pad -> remap to the caller's T.
    return (rate[:, :t_n], tmin[:, 0], jnp.minimum(amin[:, 0], t_n),
            occ[:, 0])


def link_scan_xla(remaining, baud, bg=None, tie=None, cap=None):
    """Vectorised jnp fallback with identical semantics to the link
    kernel (shared ``_link_math``) -- the CPU hot path the engine's
    NETWORK source routes through off-TPU."""
    l, t_n = remaining.shape
    remaining, tie, bg = _link_defaults(remaining, tie, bg)
    cap = (None if cap is None
           else jnp.asarray(cap, jnp.float32).reshape(l, 1))
    rate, tmin, amin, occ = _link_math(
        remaining, jnp.asarray(baud, jnp.float32).reshape(l, 1),
        bg.reshape(l, 1), tie, cap=cap)
    return rate, tmin[:, 0], amin[:, 0], occ[:, 0]


# ----------------------------------------------------------------------
# Fused event frontier: the superstep engine's whole source fan-in in ONE
# min/mask pass.
# ----------------------------------------------------------------------
#
# Every event source exposes its pending instants as an f32 candidate
# vector (+inf = nothing pending; see repro.core.des).  The engine used
# to reduce each source separately and jnp.stack the 8 scalars -- twice
# per committing superstep (once for t*, once for the speculation
# horizon).  The frontier op takes the *concatenated* candidate vector
# plus a static segment layout and answers everything at once.  min is
# exactly associative, so the fused reductions are bitwise-identical to
# the stacked per-source ones.

def _frontier_math(cand, seg, cuts):
    """Shared frontier arithmetic (jnp only -- runs inside the Pallas
    kernel body and as the XLA fallback).

    cand [1, C] f32 candidate instants; seg [S, C] f32 0/1 membership;
    cuts [1, C] f32 0/1 horizon-cut mask.  Returns (mins [S, 1] f32
    per-source earliest instant, counts [S, 1] i32 candidates due at
    t*, safe [S, 1] f32 per-source earliest *horizon-cutting* instant).
    """
    member = seg > 0.5
    mins = jnp.min(jnp.where(member, cand, INF), axis=1, keepdims=True)
    t_star = jnp.min(mins)
    due = (cand <= t_star) & (cand < INF)
    counts = jnp.sum(jnp.where(member & due, 1.0, 0.0), axis=1,
                     keepdims=True).astype(jnp.int32)
    safe = jnp.min(jnp.where(member & (cuts > 0.5), cand, INF),
                   axis=1, keepdims=True)
    return mins, counts, safe


def _frontier_kernel(cand_ref, seg_ref, cuts_ref, mins_ref, counts_ref,
                     safe_ref):
    mins, counts, safe = _frontier_math(cand_ref[...], seg_ref[...],
                                        cuts_ref[...])
    mins_ref[...] = mins
    counts_ref[...] = counts
    safe_ref[...] = safe


def _frontier_layout(sizes, s_pad, c_pad):
    """Static [S_pad, C_pad] 0/1 membership matrix for a segment layout
    (baked as a compile-time constant)."""
    import numpy as np
    seg = np.zeros((s_pad, c_pad), np.float32)
    off = 0
    for i, n in enumerate(sizes):
        seg[i, off:off + n] = 1.0
        off += n
    return jnp.asarray(seg)


def _frontier_finish(mins, counts, safe, n_src):
    mins = mins[:n_src, 0]
    t_star = mins.min() if n_src else INF
    fired = jnp.isfinite(mins) & (mins <= t_star)
    t_safe = safe[:n_src, 0].min() if n_src else INF
    return t_star, fired, counts[:n_src, 0], t_safe, mins


def event_frontier(cand, sizes, cuts=None, *, interpret: bool = False):
    """Fused event frontier over per-source candidate instants.

    cand: f32[C] -- concatenation of every source's candidate-time
        vector (absolute instants, +inf where nothing is pending);
    sizes: static tuple of per-source segment lengths (sum == C; zero
        lengths allowed -- e.g. an empty reservation table);
    cuts: bool/f32[C] -- True where the candidate cuts the k-step
        speculation horizon (defaults to all True).  This is the
        op-level **source-aware horizon** input for callers that mix
        cut and uncut candidates in one pass; the engine instead
        expresses safety by *selection* -- its horizon frontier is fed
        only `horizon_candidates` (speculation-safe sources contribute
        none; never-firing streams are +inf) with cuts left all-True,
        which is the authoritative mechanism there.

    Returns ``(t_star f32[], fired bool[S], counts i32[S], t_safe
    f32[], per_source_min f32[S])``: the earliest pending instant
    across all sources, which sources have a candidate due at it, how
    many candidates per source are due, and the earliest
    horizon-cutting instant.  All reductions are pure mins/sums, so the
    Pallas, XLA and oracle paths agree bitwise.
    """
    n_src = len(sizes)
    c = cand.shape[0]
    assert sum(sizes) == c, "segment layout out of sync with candidates"
    if cuts is None:
        cuts = jnp.ones((c,), jnp.float32)
    s_pad = max(-(-n_src // 8) * 8, 8)
    c_pad = max(-(-c // LANE) * LANE, LANE)
    seg = _frontier_layout(sizes, s_pad, c_pad)
    cand2 = jnp.full((1, c_pad), INF).at[0, :c].set(
        cand.astype(jnp.float32))
    cuts2 = jnp.zeros((1, c_pad)).at[0, :c].set(
        jnp.asarray(cuts, jnp.float32))

    mins, counts, safe = pl.pallas_call(
        _frontier_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, c_pad), lambda i: (0, 0)),
            pl.BlockSpec((s_pad, c_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, c_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((s_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((s_pad, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((s_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((s_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(cand2, seg, cuts2)
    return _frontier_finish(mins, counts, safe, n_src)


def event_frontier_xla(cand, sizes, cuts=None):
    """Vectorised jnp fallback for :func:`event_frontier` (identical
    arithmetic via the shared ``_frontier_math``)."""
    n_src = len(sizes)
    c = cand.shape[0]
    assert sum(sizes) == c, "segment layout out of sync with candidates"
    if cuts is None:
        cuts = jnp.ones((c,), jnp.float32)
    seg = _frontier_layout(sizes, max(n_src, 1), max(c, 1))
    cand2 = jnp.full((1, max(c, 1)), INF).at[0, :c].set(
        cand.astype(jnp.float32))
    cuts2 = jnp.zeros((1, max(c, 1))).at[0, :c].set(
        jnp.asarray(cuts, jnp.float32))
    mins, counts, safe = _frontier_math(cand2, seg, cuts2)
    return _frontier_finish(mins, counts, safe, n_src)
