"""Pallas TPU kernel for the GridSim inner loop: Fig 8 PE-share
allocation + earliest-completion forecast, batched over resources.

This is the simulator's hot spot at fleet scale: the superstep engine
(repro.core.engine) evaluates it once per while-loop iteration over the
resource-major ``[R, J]`` job-slot table.  Per resource row:

  rank_j  = |{j' : (rem_j', tie_j') < (rem_j, tie_j)}|  (within the row)
  P_eff   = num_pe - pe_blocked                      (reservation windows)
  k       = g // P_eff,  extra = g % P_eff,  msc = (P_eff - extra) * k
  rate_j  = eff_mips / (k + [rank_j >= msc])        (Fig 8 shares; a
            space-shared row instead grants every job a whole PE)
  t_j     = remaining_j / rate_j
  t_min   = min_j t_j                               (forecast event)
  argmin  = col of the earliest completion, ties broken by tie key
  occ     = number of occupied job slots (space-shared PE occupancy)

Shape/dtype conventions: ``remaining``/``tie``/``rate`` are f32[R, J]
(J = job slots per resource, R padded to the block size); ``mips_eff``,
``num_pe``, ``policy``, ``pe_blocked``, ``row_ok`` are per-row [R]
vectors; ``t_min`` is f32[R], ``argmin_col``/``occupancy`` i32[R].

Masking inputs (both optional, identity when omitted):

  ``pe_blocked`` [R] f32 -- PEs held by advance-reservation windows.
      Time-shared rows compute Fig 8 shares over the remaining
      ``num_pe - pe_blocked`` PEs; a fully-reserved time-shared row
      contributes nothing (rate 0, excluded from argmin/occupancy).
      Space-shared rows are unaffected here: the engine enforces
      reservations at admission and never preempts residents.
  ``row_ok``     [R] bool -- resource up/registered mask (failures).
      A down row's slots are masked out of the rate, argmin and
      occupancy outputs entirely.

The per-row argmin and occupancy outputs exist so the engine needs no
second pass over the state to locate the completing job or to count busy
PEs for queue admission.

The ``tie`` input carries the engine's FIFO tie-break priority (the flat
gridlet index): equal-remaining jobs must receive MaxShare in submission
order for the Fig 9 / Table 1 trace to be reproduced exactly.  (Across
event *kinds* the engine orders same-time batches COMPLETION > FAILURE >
RECOVERY > RESERVATION > RETURN > ARRIVAL > CALENDAR_STEP > BROKER; this
kernel only produces the COMPLETION forecasts.)

Tiling: grid over resource blocks; each block holds [block_r, J] state in
VMEM (J <= 256 -> <=256 KB fp32).  Ranking uses an explicit [J, J]
comparison per row -- O(J^2) VPU work that is fully data-parallel; J is
the per-resource job-slot bound, so keep it small on TPU.  On CPU hosts
the engine routes through :func:`event_scan_xla`, an equivalent
vectorised jnp implementation whose per-row sort is O(J log J) (the
"reference fallback" -- the Pallas path in interpret mode is reserved
for kernel tests).  Oracle: repro.kernels.ref.event_scan_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38


def _row_masks(rem, npe, pol, blk, ok):
    """Shared masking prologue of every scan variant.

    Reservation windows shrink the PE pool of time-shared rows; a down
    (row_ok == 0) row, or a fully-reserved time-shared row, is dead:
    every slot masked out of all outputs.  Returns (npe_e [R,1] f32
    effective PE pool, valid [R,J] bool, g [R,1] f32 job count).
    """
    npe_e = jnp.maximum(npe - blk, 0.0)
    dead = (ok < 0.5) | ((pol < 0.5) & (npe_e < 0.5))
    valid = (rem > 0.0) & (rem < BIG) & ~dead
    g = jnp.sum(valid.astype(jnp.float32), axis=1, keepdims=True)
    return npe_e, valid, g


def _pairwise_rank(rem, tie, valid):
    """Within-row (remaining, tie) rank via the [J, J] comparison matrix
    -- the Pallas-side ranking (O(J^2) VPU work, fully data-parallel).
    Returns (rank [R,J] f32, key, tkey) with invalid slots keyed BIG."""
    key = jnp.where(valid, rem, BIG)
    tkey = jnp.where(valid, tie, BIG)
    lt = key[:, :, None] > key[:, None, :]         # j strictly after j'
    tie_lt = (key[:, :, None] == key[:, None, :]) & \
        (tkey[:, :, None] > tkey[:, None, :])
    rank = jnp.sum((lt | tie_lt) & valid[:, None, :],
                   axis=2).astype(jnp.float32)
    return rank, key, tkey


def _lexsort_rank(rem, tie, valid):
    """Same rank contract as :func:`_pairwise_rank` via one stable
    O(J log J) lexsort -- the XLA-fallback ranking."""
    key = jnp.where(valid, rem, BIG)
    tkey = jnp.where(valid, tie, BIG)
    order = jnp.lexsort((tkey, key), axis=-1)       # cols by (rem, tie)
    rank = jnp.argsort(order, axis=-1).astype(jnp.float32)  # inverse perm
    return rank, key, tkey


def _fig8_rates(rem, rank, valid, g, mips, npe_e, pol):
    """Fig 8 share divisor -> per-slot rate, shared by all variants."""
    k = jnp.floor(g / jnp.maximum(npe_e, 1.0))     # [R,1] min jobs per PE
    extra = g - k * jnp.maximum(npe_e, 1.0)
    msc = (npe_e - extra) * k                      # max-share count
    divisor = k + (rank >= msc).astype(jnp.float32)
    # g <= P_eff: everyone gets a full PE
    divisor = jnp.where(g <= npe_e, 1.0, divisor)
    # space-shared rows: every resident job owns a whole PE
    divisor = jnp.where(pol > 0.5, 1.0, divisor)
    return jnp.where(valid, mips / jnp.maximum(divisor, 1.0), 0.0)


def _kernel(remaining_ref, tie_ref, mips_ref, pe_ref, policy_ref,
            blocked_ref, ok_ref, rate_ref, tmin_ref, amin_ref, occ_ref):
    rem = remaining_ref[...]                       # [R, J] f32
    tie = tie_ref[...]                             # [R, J] f32
    mips = mips_ref[...]                           # [R, 1]
    npe = pe_ref[...]                              # [R, 1] f32
    pol = policy_ref[...]                          # [R, 1] f32 (1 = space)
    blk = blocked_ref[...]                         # [R, 1] f32 reserved PEs
    ok = ok_ref[...]                               # [R, 1] f32 (1 = up)
    r, j = rem.shape

    npe_e, valid, g = _row_masks(rem, npe, pol, blk, ok)
    rank, key, tkey = _pairwise_rank(rem, tie, valid)
    rate = _fig8_rates(rem, rank, valid, g, mips, npe_e, pol)
    rate_ref[...] = rate

    t = jnp.where(valid, rem / jnp.maximum(rate, 1e-30), BIG)
    tmin = jnp.min(t, axis=1, keepdims=True)
    tmin_ref[...] = tmin

    # per-row argmin col, FIFO ties broken by the tie key
    at_min = (t <= tmin) & valid
    cand = jnp.where(at_min, tkey, BIG)
    tie_min = jnp.min(cand, axis=1, keepdims=True)
    col = jax.lax.broadcasted_iota(jnp.int32, (r, j), 1)
    amin_ref[...] = jnp.min(
        jnp.where(at_min & (cand <= tie_min), col, j),
        axis=1, keepdims=True)
    occ_ref[...] = g.astype(jnp.int32)


def _default_inputs(remaining, tie, policy, pe_blocked, row_ok):
    r, j = remaining.shape
    if tie is None:
        tie = jnp.broadcast_to(
            jnp.arange(j, dtype=jnp.float32)[None, :], (r, j))
    if policy is None:
        policy = jnp.zeros((r,), jnp.float32)
    if pe_blocked is None:
        pe_blocked = jnp.zeros((r,), jnp.float32)
    if row_ok is None:
        row_ok = jnp.ones((r,), jnp.float32)
    return (remaining.astype(jnp.float32), jnp.asarray(tie, jnp.float32),
            jnp.asarray(policy, jnp.float32).reshape(r),
            jnp.asarray(pe_blocked, jnp.float32).reshape(r),
            jnp.asarray(row_ok, jnp.float32).reshape(r))


def event_scan(remaining, mips_eff, num_pe, tie=None, policy=None,
               pe_blocked=None, row_ok=None, *,
               block_r: int = 8, interpret: bool = False):
    """remaining: [R, J] (<=0 or >=BIG marks empty slots); tie: [R, J]
    FIFO tie-break priority (defaults to the col index); mips_eff,
    num_pe, policy: [R] (policy 0 = time-shared, 1 = space-shared);
    pe_blocked: [R] reservation-held PEs (default 0); row_ok: [R]
    up-mask (default all-up).  Returns (rate [R, J], t_min [R],
    argmin_col [R] i32, occupancy [R] i32); argmin_col is J for empty
    (or dead) rows.
    """
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    block_r = min(block_r, r)
    assert r % block_r == 0, "pad the resource axis upstream"

    rate, tmin, amin, occ = pl.pallas_call(
        _kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, j), lambda i: (i, 0)),
            pl.BlockSpec((block_r, j), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, j), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, j), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(remaining, tie,
      mips_eff.astype(jnp.float32).reshape(r, 1),
      num_pe.astype(jnp.float32).reshape(r, 1),
      policy.reshape(r, 1),
      pe_blocked.reshape(r, 1),
      row_ok.reshape(r, 1))
    return rate, tmin[:, 0], amin[:, 0], occ[:, 0]


def event_scan_xla(remaining, mips_eff, num_pe, tie=None, policy=None,
                   pe_blocked=None, row_ok=None):
    """Vectorised jnp fallback with identical semantics to the kernel.

    The per-row O(J log J) lexsort replaces the kernel's O(J^2) pairwise
    rank, which makes it the right path for CPU hosts where Pallas would
    run interpreted.  Bitwise-identical share arithmetic to ``_kernel``.
    """
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    mips = mips_eff.astype(jnp.float32)[:, None]
    npe = num_pe.astype(jnp.float32)[:, None]
    pol = policy[:, None]
    blk = pe_blocked[:, None]
    ok = row_ok[:, None]

    npe_e, valid, g = _row_masks(remaining, npe, pol, blk, ok)
    rank, key, tkey = _lexsort_rank(remaining, tie, valid)
    rate = _fig8_rates(remaining, rank, valid, g, mips, npe_e, pol)

    t = jnp.where(valid, remaining / jnp.maximum(rate, 1e-30), BIG)
    tmin = jnp.min(t, axis=1, keepdims=True)
    at_min = (t <= tmin) & valid
    cand = jnp.where(at_min, tkey, BIG)
    tie_min = jnp.min(cand, axis=1, keepdims=True)
    col = jnp.broadcast_to(jnp.arange(j, dtype=jnp.int32)[None, :], (r, j))
    amin = jnp.min(jnp.where(at_min & (cand <= tie_min), col, j), axis=1)
    return rate, tmin[:, 0], amin, jnp.sum(valid, axis=1, dtype=jnp.int32)


# ----------------------------------------------------------------------
# k-wave time-slab forecast: the next k completions per row in ONE pass.
# ----------------------------------------------------------------------
#
# The key fact making a whole slab computable from a single rank pass:
# within a row evolving under uninterrupted Fig 8 dynamics, jobs finish
# exactly in (remaining, tie) sort order.  The rank-0 job holds MaxShare
# and the smallest remaining, so it finishes first; after it leaves, the
# order among the survivors is preserved (smaller-remaining jobs always
# hold a rate at least as high, so gaps never close).  Ranks therefore
# never need re-sorting between waves -- wave w completes the rank-w job
# -- and the per-superstep cost of 3 segmented sorts collapses into one
# rank pass followed by k cheap analytic advance steps.

def _slab_waves(rem, rank, valid, g, mips, npe_e, pol, col, k):
    """Shared wave recurrence of the slab forecast (jnp ops only, so the
    Pallas kernel body and the XLA fallback run the same arithmetic).

    rem/rank [R, J] f32, valid [R, J] bool, col [R, J] i32 (col index);
    g/mips/npe_e/pol [R, 1] f32.  Returns (t_wave f32[R, k] -- time from
    now of the row's w-th completion, BIG-padded; col_wave i32[R, k] --
    completing column, J-padded).  Wave 0 equals event_scan's
    (t_min, argmin_col).
    """
    r, j = rem.shape
    t_acc = jnp.zeros((r, 1), jnp.float32)
    ts, cols = [], []
    for w in range(k):
        # wave w = the single-scan share formula over the survivors,
        # with job count and ranks shifted by the w departed heads
        active = valid & (rank >= w)
        rate = _fig8_rates(rem, rank - w, active, g - w, mips, npe_e,
                           pol)
        head = valid & (rank == w)
        has = jnp.sum(head.astype(jnp.float32), axis=1, keepdims=True) > 0
        dt = jnp.sum(jnp.where(head, rem / jnp.maximum(rate, 1e-30), 0.0),
                     axis=1, keepdims=True)
        t_acc = t_acc + jnp.where(has, dt, 0.0)
        ts.append(jnp.where(has, t_acc, BIG))
        cols.append(jnp.where(
            has, jnp.sum(jnp.where(head, col, 0), axis=1, keepdims=True),
            j).astype(jnp.int32))
        # advance the survivors; the head leaves the table (a tied
        # neighbour may round below 0 -- clamped, it emits a dt=0 wave)
        rem = jnp.where(head, 0.0, jnp.where(
            active, jnp.maximum(rem - rate * dt, 0.0), rem))
    return jnp.concatenate(ts, axis=1), jnp.concatenate(cols, axis=1)


def _slab_kernel(remaining_ref, tie_ref, mips_ref, pe_ref, policy_ref,
                 blocked_ref, ok_ref, t_ref, col_ref, *, k):
    rem = remaining_ref[...]
    tie = tie_ref[...]
    mips = mips_ref[...]
    npe = pe_ref[...]
    pol = policy_ref[...]
    blk = blocked_ref[...]
    ok = ok_ref[...]
    r, j = rem.shape

    npe_e, valid, g = _row_masks(rem, npe, pol, blk, ok)
    # one pairwise (remaining, tie) rank pass for the whole slab
    rank, _, _ = _pairwise_rank(rem, tie, valid)
    col = jax.lax.broadcasted_iota(jnp.int32, (r, j), 1)
    t_w, col_w = _slab_waves(rem, rank, valid, g, mips, npe_e, pol, col, k)
    t_ref[...] = t_w
    col_ref[...] = col_w


def event_scan_slab(remaining, mips_eff, num_pe, k, tie=None, policy=None,
                    pe_blocked=None, row_ok=None, *,
                    block_r: int = 8, interpret: bool = False):
    """Forecast each row's next ``k`` completions in one kernel call.

    Same inputs/masking as :func:`event_scan` plus the static slab depth
    ``k``.  Returns ``(t_wave f32[R, k], col_wave i32[R, k])``: the time
    from now (NOT absolute time) and column of the row's w-th completion
    under uninterrupted Fig 8 dynamics -- shares recomputed in-register
    after every wave -- with BIG / J padding past the row's job count.
    Wave 0 is exactly ``event_scan``'s ``(t_min, argmin_col)``; wave
    ``w`` equals ``event_scan`` re-applied after removing the previous
    heads and advancing the survivors (the oracle iterates exactly
    that).  Space-shared rows free their PE on completion but admit
    nothing (queue admission is engine policy, not kernel math), so for
    them the slab is a forecast, not a commitment, as soon as a queue
    exists.  The [R_pad, J] state stays resident in VMEM across all k
    waves -- one rank pass amortised over the slab, instead of 3
    segmented sorts per superstep.
    """
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    block_r = min(block_r, r)
    assert r % block_r == 0, "pad the resource axis upstream"
    assert k >= 1

    t_w, col_w = pl.pallas_call(
        functools.partial(_slab_kernel, k=k),
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, j), lambda i: (i, 0)),
            pl.BlockSpec((block_r, j), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
        ],
        interpret=interpret,
    )(remaining, tie,
      mips_eff.astype(jnp.float32).reshape(r, 1),
      num_pe.astype(jnp.float32).reshape(r, 1),
      policy.reshape(r, 1),
      pe_blocked.reshape(r, 1),
      row_ok.reshape(r, 1))
    return t_w, col_w


def event_scan_slab_xla(remaining, mips_eff, num_pe, k, tie=None,
                        policy=None, pe_blocked=None, row_ok=None):
    """Vectorised jnp fallback for :func:`event_scan_slab` -- identical
    wave arithmetic (shared ``_slab_waves``), with the kernel's O(J^2)
    pairwise rank replaced by one O(J log J) lexsort."""
    r, j = remaining.shape
    remaining, tie, policy, pe_blocked, row_ok = _default_inputs(
        remaining, tie, policy, pe_blocked, row_ok)
    mips = mips_eff.astype(jnp.float32)[:, None]
    npe = num_pe.astype(jnp.float32)[:, None]
    pol = policy[:, None]
    blk = pe_blocked[:, None]
    ok = row_ok[:, None]

    npe_e, valid, g = _row_masks(remaining, npe, pol, blk, ok)
    rank, _, _ = _lexsort_rank(remaining, tie, valid)
    col = jnp.broadcast_to(jnp.arange(j, dtype=jnp.int32)[None, :], (r, j))
    return _slab_waves(remaining, rank, valid, g, mips, npe_e, pol, col, k)
