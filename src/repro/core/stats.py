"""``gridsim.GridStatistics`` / ``gridsim.Accumulator`` analogues.

Accumulator keeps (count, sum, sum of squares, min, max) so mean/std/
extrema queries are O(1); it is a pytree so it can be threaded through jit
and updated inside lax loops (the RECORD_STATISTICS event of Fig 14).

Shape/dtype conventions
-----------------------
Every Accumulator field is a scalar f32 (``count`` is a float weight sum
so weighted inserts stay exact under jit; ``vmin``/``vmax`` start at
+/-inf).  ``add`` takes scalar ``value``/``weight``; ``add_many`` takes
``values`` f32[N] with an optional ``mask`` (bool[N] or f32[N] weights)
and performs one fused update -- the natural companion of the engine's
batched supersteps, which retire whole event cohorts per iteration.
Accumulators broadcast like any pytree: vmapping a sweep yields [D, B]
leaves that ``mean``/``std`` reduce elementwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import pytree_dataclass


@pytree_dataclass
class Accumulator:
    count: jax.Array
    total: jax.Array
    total_sq: jax.Array
    vmin: jax.Array
    vmax: jax.Array


def accumulator() -> Accumulator:
    z = jnp.zeros((), jnp.float32)
    return Accumulator(count=z, total=z, total_sq=z,
                       vmin=jnp.asarray(jnp.inf, jnp.float32),
                       vmax=jnp.asarray(-jnp.inf, jnp.float32))


def add(acc: Accumulator, value, weight=1.0) -> Accumulator:
    v = jnp.asarray(value, jnp.float32)
    w = jnp.asarray(weight, jnp.float32)
    return Accumulator(
        count=acc.count + w,
        total=acc.total + v * w,
        total_sq=acc.total_sq + v * v * w,
        vmin=jnp.minimum(acc.vmin, jnp.where(w > 0, v, jnp.inf)),
        vmax=jnp.maximum(acc.vmax, jnp.where(w > 0, v, -jnp.inf)),
    )


def add_many(acc: Accumulator, values, mask=None) -> Accumulator:
    """Bulk insert of a vector, optionally masked -- one fused update."""
    v = jnp.asarray(values, jnp.float32)
    m = jnp.ones_like(v) if mask is None else jnp.asarray(mask, jnp.float32)
    return Accumulator(
        count=acc.count + m.sum(),
        total=acc.total + (v * m).sum(),
        total_sq=acc.total_sq + (v * v * m).sum(),
        vmin=jnp.minimum(acc.vmin, jnp.where(m > 0, v, jnp.inf).min()),
        vmax=jnp.maximum(acc.vmax, jnp.where(m > 0, v, -jnp.inf).max()),
    )


def mean(acc: Accumulator) -> jax.Array:
    return acc.total / jnp.maximum(acc.count, 1.0)


def std(acc: Accumulator) -> jax.Array:
    m = mean(acc)
    var = acc.total_sq / jnp.maximum(acc.count, 1.0) - m * m
    return jnp.sqrt(jnp.maximum(var, 0.0))
