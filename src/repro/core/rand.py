"""``gridsim.GridSimRandom`` reimplemented on jax.random.

The paper defines ``real(d, f_L, f_M)`` mapping a predicted value ``d`` to a
random real-world value in ``[(1-f_L)*d, (1+f_M)*d]`` via

    d * (1 - f_L + (f_L + f_M) * rd),   rd ~ U[0, 1).

Determinism is by explicit key threading (strictly stronger repeatability
than the Java RNG the paper used, which is the point of the toolkit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Default I/O uncertainty factors mirroring GridSimRandom's situation table.
FACTORS = {
    "exec": (0.0, 0.10),       # paper section 5.2: 0..10% on the positive side
    "net_io": (0.05, 0.05),
    "none": (0.0, 0.0),
}


def real(key: jax.Array, d, f_low, f_more):
    """Vectorised GridSimRandom.real; ``d`` may be any shaped array."""
    d = jnp.asarray(d, jnp.float32)
    rd = jax.random.uniform(key, d.shape, jnp.float32)
    return d * (1.0 - f_low + (f_low + f_more) * rd)


def real_named(key: jax.Array, d, situation: str = "exec"):
    f_low, f_more = FACTORS[situation]
    return real(key, d, f_low, f_more)


def exponential(key: jax.Array, mean):
    """Memoryless interval stream: one draw per element of ``mean``.

    The engine's failure/recovery event source models per-resource
    uptime (MTBF) and repair time (MTTR) as exponential holding times,
    the standard renewal model the paper's "resources are dynamic"
    scenarios call for.  ``mean`` may be any shaped array; a
    non-positive mean yields +inf (the stream is disabled), which is how
    zero-rate scenarios stay bit-for-bit identical to runs without the
    source registered.
    """
    mean = jnp.asarray(mean, jnp.float32)
    draw = mean * jax.random.exponential(key, mean.shape, jnp.float32)
    return jnp.where(mean > 0.0, draw, jnp.inf)
