"""Segmented (grouped) array utilities used by the vectorised engine.

The paper's per-entity loops ("for each Gridlet on this resource ...")
become segmented ranks / prefix sums over one global table.  All helpers
are O(N log N) via one stable lexsort -- the TPU-friendly replacement for
pointer-chasing per-resource job lists.  (The engine's k-step batched
hot path goes further still: ``kernels.event_scan_slab`` amortises one
rank pass over a whole slab of supersteps; these helpers remain the
general-purpose primitive for broker-side grouping.)

Shape/dtype conventions
-----------------------
All inputs are flat per-element arrays over one global table of ``N``
elements partitioned into ``n_groups`` segments:

  ``group_key``   -- i32[N] (any int dtype; cast to i32) segment id of
                     each element, values in ``[0, n_groups)``,
  ``member_mask`` -- bool[N]; non-members never perturb member results,
  ``order_key``   -- [N] any sortable dtype; ordering inside a segment
                     is (order_key, index) -- index breaks ties FIFO,
  ``values``      -- f32[N] (``group_prefix_sum`` only), must be >= 0.

Returns: ``group_rank`` -> (rank i32[N] -- BIG for non-members,
counts i32[n_groups]); ``group_prefix_sum`` -> f32[N] exclusive prefix
sums (0 for non-members).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = jnp.int32(2**30)


def group_rank(group_key, member_mask, order_key, n_groups):
    """Rank of each member within its group, ordered by (order_key, index).

    Non-members receive rank BIG and do not perturb member ranks.
    Returns (rank[N] i32, counts[n_groups] i32).
    """
    n = group_key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    gk = jnp.where(member_mask, group_key, n_groups).astype(jnp.int32)
    order = jnp.lexsort((idx, jnp.asarray(order_key), gk))
    sorted_g = gk[order]
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_g[1:] != sorted_g[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    rank_sorted = idx - seg_start
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    rank = jnp.where(member_mask, rank, BIG)
    counts = jax.ops.segment_sum(member_mask.astype(jnp.int32), gk,
                                 num_segments=n_groups + 1)[:n_groups]
    return rank, counts


def group_prefix_sum(group_key, member_mask, order_key, values, n_groups):
    """Exclusive prefix sum of ``values`` within each group in
    (order_key, index) order.  Non-members get 0.  values must be >= 0.
    """
    n = group_key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    gk = jnp.where(member_mask, group_key, n_groups).astype(jnp.int32)
    v = jnp.where(member_mask, jnp.asarray(values, jnp.float32), 0.0)
    order = jnp.lexsort((idx, jnp.asarray(order_key), gk))
    sv = v[order]
    sg = gk[order]
    cs = jnp.cumsum(sv)                       # inclusive, global
    is_start = jnp.concatenate([jnp.ones((1,), bool), sg[1:] != sg[:-1]])
    # value of (cs - sv) at each segment's first element, carried forward.
    base = jax.lax.cummax(jnp.where(is_start, cs - sv, -jnp.inf))
    excl_sorted = cs - sv - base              # exclusive within segment
    out = jnp.zeros((n,), jnp.float32).at[order].set(excl_sorted)
    return jnp.where(member_mask, out, 0.0)
