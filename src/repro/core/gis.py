"""Grid Information Service (``gridsim.GridInformationService``).

Resources register at simulation start; brokers query for the list of
registered, currently-available resources and their characteristics
(REGISTER_RESOURCE / RESOURCE_LIST / RESOURCE_CHARACTERISTICS /
RESOURCE_DYNAMICS tags in paper Fig 14).

Vectorised adaptation: the registry is a boolean availability mask over the
fleet table; "querying" is masked reads.  Dynamic behaviour (resources
joining/failing mid-run -- the fault-tolerance hook) flips mask entries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .calendar import effective_mips
from .types import pytree_dataclass


@pytree_dataclass
class GIS:
    registered: jax.Array  # bool[R]


def init(fleet) -> GIS:
    """All fleet resources register themselves at start-up (paper 3.4)."""
    return GIS(registered=jnp.ones((fleet.r,), bool))


def register(gis: GIS, idx) -> GIS:
    return GIS(registered=gis.registered.at[idx].set(True))


def deregister(gis: GIS, idx) -> GIS:
    """Resource failure / administrative removal."""
    return GIS(registered=gis.registered.at[idx].set(False))


def resource_list(gis: GIS) -> jax.Array:
    """RESOURCE_LIST: availability mask the broker iterates over."""
    return gis.registered


def dynamics(gis: GIS, fleet, t):
    """RESOURCE_DYNAMICS: advertised aggregate rate + price per resource.

    Unregistered resources advertise zero capacity, so broker code needs no
    special-casing.
    """
    rate = effective_mips(fleet, t) * fleet.num_pe.astype(jnp.float32)
    rate = jnp.where(gis.registered, rate, 0.0)
    return rate, fleet.cost_per_sec
