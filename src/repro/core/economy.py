"""Deadline / budget determination from D- and B-factors (paper 4.2.3).

    Deadline = T_MIN + D_FACTOR * (T_MAX - T_MIN)        (Eq 1)
    Budget   = C_MIN + B_FACTOR * (C_MAX - C_MIN)        (Eq 2)

Interpretations (documented because the paper defines the terms in prose):
  T_MIN: all jobs processed in parallel with the fastest resources given
         priority == ideal makespan lower bound total_MI / sum(peak rates).
  T_MAX: all jobs processed serially on the slowest resource
         == total_MI / min(per-PE MIPS).
  C_MIN: every job on the cheapest G$-per-MI resource.
  C_MAX: every job on the costliest G$-per-MI resource.

D<0 / B<0 never complete; D>=1 / B>=1 always complete while resources
remain available -- both properties are asserted in tests.
"""
from __future__ import annotations

import jax.numpy as jnp


def t_min(fleet, total_mi, registered=None):
    rate = fleet.peak_rate()
    if registered is not None:
        rate = jnp.where(registered, rate, 0.0)
    return total_mi / jnp.maximum(rate.sum(), 1e-30)


def t_max(fleet, total_mi, registered=None):
    mips = fleet.mips_per_pe
    if registered is not None:
        mips = jnp.where(registered, mips, jnp.inf)
    return total_mi / jnp.maximum(mips.min(), 1e-30)


def c_min(fleet, total_mi, registered=None):
    cpm = fleet.cost_per_mi()
    if registered is not None:
        cpm = jnp.where(registered, cpm, jnp.inf)
    return total_mi * cpm.min()


def c_max(fleet, total_mi, registered=None):
    cpm = fleet.cost_per_mi()
    if registered is not None:
        cpm = jnp.where(registered, cpm, -jnp.inf)
    return total_mi * cpm.max()


def deadline_from_factor(fleet, total_mi, d_factor, registered=None):
    lo = t_min(fleet, total_mi, registered)
    hi = t_max(fleet, total_mi, registered)
    return lo + d_factor * (hi - lo)


def budget_from_factor(fleet, total_mi, b_factor, registered=None):
    lo = c_min(fleet, total_mi, registered)
    hi = c_max(fleet, total_mi, registered)
    return lo + b_factor * (hi - lo)
