"""The economy layer: deadline/budget determination (paper 4.2.3) and
the dynamic pricing models of the Buyya thesis (cs/0204048, ch. 4).

Deadline / budget from D- and B-factors:

    Deadline = T_MIN + D_FACTOR * (T_MAX - T_MIN)        (Eq 1)
    Budget   = C_MIN + B_FACTOR * (C_MAX - C_MIN)        (Eq 2)

Interpretations (documented because the paper defines the terms in prose):
  T_MIN: all jobs processed in parallel with the fastest resources given
         priority == ideal makespan lower bound total_MI / sum(peak rates).
  T_MAX: all jobs processed serially on the slowest resource
         == total_MI / min(per-PE MIPS).
  C_MIN: every job on the cheapest G$-per-MI resource.
  C_MAX: every job on the costliest G$-per-MI resource.

D<0 / B<0 never complete; D>=1 / B>=1 always complete while resources
remain available -- both properties are asserted in tests.

Pricing models
--------------
``fleet.cost_per_mi()`` (the Table 2 G$/MI trading metric) is the
*base* (advertised) price; the engine carries the *posted* per-MI price
in ``SimState.price`` and the MARKET / AUCTION event sources
(engine._make_sources) move it.  Prices live in per-MI units so the
broker reads them directly -- re-deriving the metric in-loop from a
carried cost_per_sec would divide a loop-carried array by an invariant,
which XLA may compile differently per execution path (reciprocal
rewrites), breaking the engine's bitwise cross-path contract:

  * :func:`commodity_reprice` -- the commodity-market model: a
    posted-price adjustment driven by excess demand (resident jobs vs
    PE capacity), clamped to ``[floor, cap] * base``.  Deterministic:
    no RNG, so the source is naturally maskable.
  * :func:`auction_round` -- one sealed-bid tender round: every
    resource owner submits an asking-price factor drawn from its PRNG
    stream and the posted price becomes ``base * bid``.  Rounds are
    deterministic given the key (the engine consumes one split per
    fired round, with the masked-contract select-back on declined
    lanes -- see docs/ARCHITECTURE.md).

The broker prices everything off the posted price (``state.price`` IS
the G$/MI trading metric), so a repriced grid shifts which resources
the DBC strategies buy without touching the Fig 8 rate arithmetic --
pricing rounds therefore carry NO slab-invalidation duty.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# SimParams.pricing_model codes (kept here: pricing is economy policy,
# the engine only routes them).
PRICE_STATIC = 0     # fleet.cost_per_sec, never repriced (the default)
PRICE_COMMODITY = 1  # commodity-market posted-price adjustment
PRICE_AUCTION = 2    # periodic sealed-bid auction/tender rounds

_PRICING_NAMES = {"static": PRICE_STATIC, "commodity": PRICE_COMMODITY,
                  "auction": PRICE_AUCTION}


def as_pricing_model(model) -> int:
    """Normalise a Scenario pricing knob ("commodity", "auction",
    "static", an int code, or None) to a PRICE_* int."""
    if model is None:
        return PRICE_STATIC
    if isinstance(model, str):
        return _PRICING_NAMES[model]
    return int(model)


def commodity_reprice(price, base, demand, gain, floor, cap):
    """One commodity-market posted-price adjustment.

    ``demand`` is resident jobs per PE (1.0 = exactly subscribed);
    excess demand raises the posted price by ``gain`` per unit, idle
    capacity lowers it, and the result is clamped to
    ``[floor * base, cap * base]`` -- which also keeps every repriced
    cost positive and finite for any finite inputs (property-tested).
    """
    newp = price * (1.0 + gain * (demand - 1.0))
    return jnp.clip(newp, base * floor, base * cap)


def auction_round(key, base, floor, cap):
    """One sealed-bid auction/tender round: per-resource asking-price
    factors drawn uniformly from ``[floor, cap)``; the posted price
    becomes ``base * bid``.  Deterministic given ``key``."""
    bids = jax.random.uniform(key, base.shape, minval=floor, maxval=cap)
    return base * bids


def t_min(fleet, total_mi, registered=None):
    rate = fleet.peak_rate()
    if registered is not None:
        rate = jnp.where(registered, rate, 0.0)
    return total_mi / jnp.maximum(rate.sum(), 1e-30)


def t_max(fleet, total_mi, registered=None):
    mips = fleet.mips_per_pe
    if registered is not None:
        mips = jnp.where(registered, mips, jnp.inf)
    return total_mi / jnp.maximum(mips.min(), 1e-30)


def c_min(fleet, total_mi, registered=None):
    cpm = fleet.cost_per_mi()
    if registered is not None:
        cpm = jnp.where(registered, cpm, jnp.inf)
    return total_mi * cpm.min()


def c_max(fleet, total_mi, registered=None):
    cpm = fleet.cost_per_mi()
    if registered is not None:
        cpm = jnp.where(registered, cpm, -jnp.inf)
    return total_mi * cpm.max()


def deadline_from_factor(fleet, total_mi, d_factor, registered=None):
    lo = t_min(fleet, total_mi, registered)
    hi = t_max(fleet, total_mi, registered)
    return lo + d_factor * (hi - lo)


def budget_from_factor(fleet, total_mi, b_factor, registered=None):
    lo = c_min(fleet, total_mi, registered)
    hi = c_max(fleet, total_mi, registered)
    return lo + b_factor * (hi - lo)
