"""Economic grid resource broker (paper section 4.2, Figs 18-20).

Each user owns a broker; a BROKER engine event runs every broker at once
(vectorised over users).  All per-gridlet arrays are [N] (flat over
every user's Gridlets), per-user [U], per-resource [R], and the
measurement/capacity tables [U, R].  One event performs the full Fig 20
cycle, split into the helper per step so each stage can be tested and
profiled on its own:

  ``_measure``  -- 1. resource discovery (GIS mask, intersected with the
                   engine's ``res_up`` so failed resources drop out until
                   they re-register) + trading (cost per MI, Table 2
                   metric), 2. measure-and-extrapolate the per-resource
                   job consumption rate, 3. predict per-resource job
                   capacity by the deadline,
  ``_release``  -- 4. release over-committed jobs back to the
                   unassigned queue,
  ``_assign``   -- 5. assign unassigned jobs to resources in policy
                   order (cost / time / cost-time / none optimisation)
                   under the budget constraint.  FAILED Gridlets (their
                   resource went down mid-flight; the engine refunded
                   their committed cost) re-enter here exactly like
                   CREATED ones -- this is the resubmission path,
  ``_dispatch`` -- 6. dispatch up to MaxGridletPerPE * num_pe staged
                   jobs per resource, committing their exact processing
                   cost against the budget (a resubmitted Gridlet is
                   billed again only here, so a failure never double
                   bills; ``SimState.n_resubmits`` counts these).

The broker reads only the flat GridletBatch arrays plus the engine's
``done_on`` counters; it never touches the engine's resource-major
job-slot table (a Gridlet's slot column is an engine implementation
detail), which is what lets one broker event run inside a superstep at
any point after completions and returns have been applied.  BROKER is
the lowest-priority event kind in the engine's COMPLETION > FAILURE >
RECOVERY > RESERVATION > MARKET > AUCTION > NETWORK > RETURN > ARRIVAL >
CALENDAR_STEP > BROKER tie-break: at an equal timestamp the broker
observes every other batch's effects -- including same-instant pricing
rounds, so the trading metric below always reads fresh posted prices.

The measurement in step 2 counts fractional progress of in-flight jobs so
the estimate ramps smoothly from the advertised rate to the observed share
(the paper's "recalibration"; Fig 34 discusses the stale-first-estimate
overshoot this produces under competition, which this model reproduces).

A broker stays active only while its cheapest possible purchase -- the
user's smallest still-undispatched Gridlet priced at the best G$/MI on
the grid -- fits in the remaining budget (mirrors
``engine._user_flags``); a broker with nothing left to dispatch is
inactive, because every further poll would be a no-op.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .segments import group_rank, group_prefix_sum
from .types import (CREATED, DONE, FAILED, IN_TRANSIT, INF, OPT_COST,
                    OPT_COST_TIME, OPT_NONE, OPT_TIME, QUEUED, RETURNING,
                    RUNNING, replace)
from . import calendar, network
from . import reservation as resv_mod


def _policy_keys(opt, cost_per_mi, est_rate, r_index, plan_ahead=False):
    """Composite per-resource ordering key for each optimisation mode.

    cost: cheapest G$/MI first (ties by index, paper Fig 20 step 4);
    time: fastest estimated consumption rate first;
    cost-time: cheapest first, equal-cost resources ordered fastest-first
               (the [23] variant -- same-cost pools scheduled for time);
    none: resource index order.

    ``plan_ahead`` switches cost-time to the full cs/0203020 algorithm:
    resources are partitioned into *exact* equal-cost groups (a dense
    rank of the G$/MI metric, so two resources share a group iff their
    costs are bit-equal) and each group is ordered fastest-first.  The
    legacy key approximates the same ordering with a fixed 1e-4 rate
    nudge, which can jump a near-tie cost gap; the grouped key cannot
    -- group ranks differ by >= 1 and the within-group term is < 1.
    """
    shape = est_rate.shape
    est_norm = est_rate / jnp.maximum(est_rate.max(axis=-1, keepdims=True),
                                      1e-30)
    key_cost = jnp.broadcast_to(cost_per_mi + 1e-7 * r_index, shape)
    key_time = -est_rate + 1e-7 * r_index
    key_ct_legacy = jnp.broadcast_to(cost_per_mi, shape) \
        - 1e-4 * est_norm + 1e-7 * r_index
    # Dense cost rank: #resources strictly cheaper == group id; the
    # within-group term spans [0, 0.5] + eps so it never crosses the
    # unit gap between adjacent groups.
    cost = jnp.broadcast_to(cost_per_mi, shape)
    grp = jnp.sum((cost[..., None, :] < cost[..., :, None]),
                  axis=-1).astype(jnp.float32)
    key_ct_plan = grp + (1.0 - est_norm) * 0.5 + 1e-7 * r_index
    key_cost_time = jnp.where(plan_ahead, key_ct_plan, key_ct_legacy)
    key_none = jnp.broadcast_to(r_index * 1.0, shape)
    return jnp.select(
        [opt[:, None] == OPT_COST, opt[:, None] == OPT_TIME,
         opt[:, None] == OPT_COST_TIME, opt[:, None] == OPT_NONE],
        [key_cost, key_time, key_cost_time, key_none])


def _retryable(g, params, t):
    """Dispatchable-now mask: CREATED, or FAILED with retries left in
    its budget (``params.retry_limit``) whose exponential-backoff
    instant (``g.retry_at``, stamped by engine._fail_gridlets) has
    passed.  At the default knobs (unbounded limit, zero backoff base)
    this is exactly the legacy ``CREATED | FAILED`` mask, bit for
    bit."""
    ok = (g.n_retries <= params.retry_limit) & (t >= g.retry_at)
    return (g.status == CREATED) | ((g.status == FAILED) & ok)


def _not_abandoned(g, params):
    """CREATED, or FAILED still inside its retry budget -- including
    gridlets merely *waiting out* a backoff window.  This is the
    activity mask: a backoff wait must keep the broker polling (the
    retry fires at the first poll past ``retry_at``), whereas a
    gridlet beyond ``retry_limit`` is abandoned for good and must stop
    propping the broker's activity, or the run would poll until the
    deadline."""
    within = g.n_retries <= params.retry_limit
    return (g.status == CREATED) | ((g.status == FAILED) & within)


def min_affordable_cost(g, fleet, n_users: int, price=None,
                        params=None):
    """Cheapest possible next purchase per user: the smallest
    still-undispatched (CREATED, or FAILED awaiting resubmission)
    Gridlet priced at the best G$/MI.  +inf when nothing is left to
    dispatch.  ``price`` overrides the advertised G$/MI metric with the
    grid's posted per-MI prices (SimState.price) under dynamic
    pricing.  ``params`` enables the retry budget: gridlets beyond
    ``params.retry_limit`` are abandoned and no longer count as a
    possible purchase (None keeps the legacy unbounded mask)."""
    if params is None:
        undispatched = (g.status == CREATED) | (g.status == FAILED)
    else:
        undispatched = _not_abandoned(g, params)
    min_mi = jax.ops.segment_min(
        jnp.where(undispatched, g.length_mi, INF), g.user,
        num_segments=n_users)
    per_mi = fleet.cost_per_mi() if price is None else price
    return min_mi * per_mi.min()


def _measure(state, fleet, params, n_users: int):
    """Fig 20 steps 1-3: trading metrics, measured consumption rate,
    capacity by deadline.  Returns the per-event context dict."""
    g = state.g
    t = state.t
    R = fleet.r
    u_idx = g.user

    # Cooldown blacklist: a resource that recovered less than
    # ``blacklist_cooldown`` ago is dark to discovery/pricing -- a
    # flapping resource must re-earn trust before the broker commits
    # new work to it.  recovered_at inits to -inf, so at the default
    # cooldown of 0.0 no resource is ever blacklisted (bitwise-frozen
    # legacy discovery).
    blacklisted = (t - state.recovered_at) < params.blacklist_cooldown
    registered = params.registered & state.res_up & ~blacklisted
    reserved = resv_mod.active_pes(params.resv_res, params.resv_pes,
                                   params.resv_start, params.resv_end,
                                   t, R)
    eff = calendar.effective_mips(fleet, t)                      # [R]
    # Plan-ahead (cs/0203020) advertises the FULL PE count here and
    # prices the reservation windows into the capacity integral below
    # instead; the legacy reactive broker subtracts currently-reserved
    # PEs from the advertised rate (and so re-discovers each window
    # only while it is open).
    plan = params.plan_ahead
    adv_rate = eff * jnp.maximum(
        fleet.num_pe - jnp.where(plan, 0, reserved),
        0).astype(jnp.float32)                                   # MIPS
    # Trading (Table 2 metric) off the POSTED per-MI price: bitwise
    # fleet.cost_per_mi() until a pricing round moves it.
    cost_per_mi = state.price                                    # [R]

    ones = jnp.ones((g.n,), jnp.float32)
    cnt_per_user = jax.ops.segment_sum(ones, u_idx, num_segments=n_users)
    mi_per_user = jax.ops.segment_sum(g.length_mi, u_idx,
                                      num_segments=n_users)
    avg_mi = mi_per_user / jnp.maximum(cnt_per_user, 1.0)        # [U]

    inflight = ((g.status == IN_TRANSIT) | (g.status == QUEUED) |
                (g.status == RUNNING) | (g.status == RETURNING))
    on_res = jnp.clip(g.resource, 0, R - 1)
    ur_res_key = u_idx * R + on_res
    frac = jnp.where(inflight, 1.0 - g.remaining / g.length_mi, 0.0)
    progress = jax.ops.segment_sum(frac, ur_res_key,
                                   num_segments=n_users * R)
    progress = progress.reshape(n_users, R) + state.done_on      # jobs-equiv

    elapsed = jnp.maximum(t - state.first_dispatch, 1e-6)        # [U,R]
    adv_jobs = adv_rate[None, :] / jnp.maximum(avg_mi[:, None], 1e-30)
    measured = progress / elapsed
    started = jnp.isfinite(state.first_dispatch) & \
        (t > state.first_dispatch + 1e-9)
    est_jobs = jnp.where(started, jnp.minimum(measured, adv_jobs), adv_jobs)
    est_jobs = jnp.where(registered[None, :], est_jobs, 0.0)     # [U,R]

    time_left = jnp.maximum(params.deadline - t, 0.0)            # [U]
    cap_legacy = jnp.floor(est_jobs * time_left[:, None]).astype(jnp.int32)

    # ---- plan-ahead capacity (cs/0203020) ----------------------------
    # (a) Reservation windows: integrate the PE-time each window blocks
    # over [t, deadline_u] and convert it to jobs-equivalent at the
    # current calendar rate -- the capacity those windows will remove
    # before the deadline, charged NOW rather than rediscovered when
    # the window opens.
    dl = params.deadline                                         # [U]
    ov = jnp.clip(jnp.minimum(params.resv_end[None, :], dl[:, None]) -
                  jnp.maximum(params.resv_start[None, :], t),
                  0.0, None)                                     # [U,K]
    onehot = (params.resv_res[None, :] ==
              jnp.arange(R, dtype=params.resv_res.dtype)[:, None])
    blocked_pe_time = jnp.einsum(
        "uk,rk->ur", params.resv_pes.astype(jnp.float32)[None, :] * ov,
        onehot.astype(jnp.float32))                              # [U,R]
    blocked_jobs = blocked_pe_time * eff[None, :] / \
        jnp.maximum(avg_mi[:, None], 1e-30)
    # (b) Link queueing: bytes already queued on each resource's link
    # bound the earliest a fresh dispatch can even START computing
    # (fastest_drain is the membership-invariant per-transfer bound),
    # so plan-ahead buys capacity only over the post-drain window.
    if state.link_rem.shape[1] > 0:
        link_delay = network.fastest_drain(
            state.link_rem[:R].sum(axis=1), params.link_baud,
            params.bg_flows)                                     # [R]
    else:
        link_delay = jnp.zeros((R,), jnp.float32)
    cap_plan = jnp.floor(jnp.maximum(
        est_jobs * jnp.maximum(time_left[:, None] - link_delay[None, :],
                               0.0) - blocked_jobs,
        0.0)).astype(jnp.int32)
    cap_jobs = jnp.where(plan, cap_plan, cap_legacy)

    active = ((t < params.deadline) &
              (state.spent + min_affordable_cost(g, fleet, n_users,
                                                 price=state.price,
                                                 params=params)
               <= params.budget))

    return dict(registered=registered, cost_per_mi=cost_per_mi,
                est_jobs=est_jobs, cap_jobs=cap_jobs, avg_mi=avg_mi,
                inflight=inflight, ur_res_key=ur_res_key, active=active)


def _release(state, ctx, params, n_users: int, R: int):
    """Fig 20 step 4: release over-committed undispatched jobs."""
    g = state.g
    u_idx = g.user
    idx = jnp.arange(g.n, dtype=jnp.int32)
    ur_key = u_idx * R + jnp.clip(g.assigned, 0, R - 1)

    committed = (g.assigned >= 0) & (g.status != DONE)
    n_committed = jax.ops.segment_sum(
        committed.astype(jnp.int32),
        jnp.where(committed, ur_key, n_users * R),
        num_segments=n_users * R + 1)[:n_users * R].reshape(n_users, R)

    undispatched = _retryable(g, params, state.t) & (g.assigned >= 0)
    rel_rank, n_undisp = group_rank(ur_key, undispatched, -idx,
                                    n_users * R)
    n_release = jnp.clip(n_committed - ctx["cap_jobs"], 0,
                         n_undisp[:n_users * R].reshape(n_users, R))
    n_release = jnp.where(ctx["active"][:, None], n_release, 0)
    release = undispatched & (rel_rank <
                              n_release.reshape(-1)[jnp.clip(ur_key, 0,
                                                             n_users * R - 1)])
    assigned = jnp.where(release, -1, g.assigned)
    return assigned, n_committed - n_release


def _assign(state, ctx, assigned, n_committed, params, n_users: int,
            R: int):
    """Fig 20 step 5: fill per-resource capacity slots with unassigned
    jobs in policy order under the budget constraint."""
    g = state.g
    u_idx = g.user
    idx = jnp.arange(g.n, dtype=jnp.int32)
    cost_per_mi = ctx["cost_per_mi"]
    registered = ctx["registered"]

    exact_cost_now = g.length_mi * cost_per_mi[jnp.clip(assigned, 0, R - 1)]
    planned = (assigned >= 0) & _retryable(g, params, state.t)
    planned_cost = jax.ops.segment_sum(
        jnp.where(planned, exact_cost_now, 0.0), u_idx,
        num_segments=n_users)
    budget_left = jnp.maximum(params.budget - state.spent - planned_cost,
                              0.0)

    keys = _policy_keys(params.opt, cost_per_mi[None, :], ctx["est_jobs"],
                        jnp.arange(R, dtype=jnp.float32)[None, :],
                        plan_ahead=params.plan_ahead)
    keys = jnp.where(registered[None, :], keys, INF)
    order = jnp.argsort(keys, axis=-1)                           # [U,R]
    inv_order = jnp.zeros_like(order).at[
        jnp.arange(n_users)[:, None], order].set(
        jnp.broadcast_to(jnp.arange(R), (n_users, R)))

    slots = jnp.maximum(ctx["cap_jobs"] - n_committed, 0)        # [U,R]
    job_cost_est = ctx["avg_mi"][:, None] * cost_per_mi[None, :]  # [U,R]

    # FAILED gridlets (engine-refunded) resubmit like fresh CREATED
    # ones -- once past their backoff window and within the retry
    # budget (_retryable; vacuous at the default knobs).
    unassigned = _retryable(g, params, state.t) & (assigned < 0)
    n_unassigned = jax.ops.segment_sum(
        unassigned.astype(jnp.int32), u_idx, num_segments=n_users)
    active = ctx["active"]

    def fill(j, carry):
        taken, budget_rem, take_at = carry
        r = order[:, j]                                          # [U]
        rows = jnp.arange(n_users)
        s = slots[rows, r]
        c = job_cost_est[rows, r]
        by_budget = jnp.floor(budget_rem / jnp.maximum(c, 1e-30))
        by_budget = jnp.clip(by_budget, 0, 2**30).astype(jnp.int32)
        n_fit = jnp.minimum(jnp.minimum(s, by_budget),
                            n_unassigned - taken)
        n_fit = jnp.where(active & registered[r], n_fit, 0)
        take_at = take_at.at[:, j].set(n_fit)
        return taken + n_fit, budget_rem - n_fit.astype(jnp.float32) * c, \
            take_at

    taken0 = jnp.zeros((n_users,), jnp.int32)
    take_at0 = jnp.zeros((n_users, R), jnp.int32)
    taken, _, take_at = jax.lax.fori_loop(
        0, R, fill, (taken0, budget_left, take_at0))
    cum_take = jnp.cumsum(take_at, axis=-1)                      # [U,R]

    una_rank, _ = group_rank(u_idx, unassigned, idx, n_users)
    k = una_rank                                                 # [N]
    cum_for_g = cum_take[u_idx]                                  # [N,R]
    j_star = jnp.sum((cum_for_g <= k[:, None]).astype(jnp.int32), axis=-1)
    gets = unassigned & (k < taken[u_idx]) & (j_star < R)
    new_assigned = jnp.where(
        gets, order[u_idx, jnp.clip(j_star, 0, R - 1)], assigned)
    return new_assigned, inv_order


def _dispatch(state, fleet, ctx, params, new_assigned, inv_order,
              n_users: int, R: int):
    """Fig 20 step 6: stage up to MaxGridletPerPE * num_pe jobs per
    resource, committing exact processing cost against the budget."""
    g = state.g
    t = state.t
    u_idx = g.user
    idx = jnp.arange(g.n, dtype=jnp.int32)
    cost_per_mi = ctx["cost_per_mi"]

    ur_key2 = u_idx * R + jnp.clip(new_assigned, 0, R - 1)
    cand = _retryable(g, params, t) & (new_assigned >= 0)
    n_inflight_ur = jax.ops.segment_sum(
        ctx["inflight"].astype(jnp.int32),
        jnp.where(ctx["inflight"], ctx["ur_res_key"], n_users * R),
        num_segments=n_users * R + 1)[:n_users * R].reshape(n_users, R)
    limit = params.max_gridlet_per_pe * fleet.num_pe[None, :]
    disp_slots = jnp.maximum(limit - n_inflight_ur, 0)           # [U,R]
    disp_rank, _ = group_rank(ur_key2, cand, idx, n_users * R)
    eligible = cand & (disp_rank < disp_slots.reshape(-1)[
        jnp.clip(ur_key2, 0, n_users * R - 1)])
    eligible = eligible & ctx["active"][u_idx] & ctx["registered"][
        jnp.clip(new_assigned, 0, R - 1)]

    exact_cost = g.length_mi * cost_per_mi[jnp.clip(new_assigned, 0, R - 1)]
    disp_order_key = (inv_order[u_idx, jnp.clip(new_assigned, 0, R - 1)]
                      .astype(jnp.float32) * (g.n + 1.0) +
                      idx.astype(jnp.float32))
    prefix = group_prefix_sum(u_idx, eligible, disp_order_key, exact_cost,
                              n_users)
    fits = prefix + exact_cost <= (params.budget - state.spent)[u_idx]
    dispatch = eligible & fits

    r_disp = jnp.clip(new_assigned, 0, R - 1)
    in_delay = network.transfer_delay(g.in_bytes, fleet.baud_rate[r_disp])
    g2 = replace(
        g,
        assigned=new_assigned,
        status=jnp.where(dispatch, IN_TRANSIT, g.status),
        resource=jnp.where(dispatch, new_assigned, g.resource),
        t_event=jnp.where(dispatch, t + in_delay, g.t_event),
        cost=jnp.where(dispatch, exact_cost, g.cost),
        # A resubmitted FAILED gridlet restarts from scratch (a no-op
        # for CREATED ones, whose remaining is still the full length).
        remaining=jnp.where(dispatch, g.length_mi, g.remaining),
    )
    spent = state.spent + jax.ops.segment_sum(
        jnp.where(dispatch, exact_cost, 0.0), u_idx, num_segments=n_users)
    fd = jax.ops.segment_min(
        jnp.where(dispatch, t, INF),
        jnp.where(dispatch, ur_key2, n_users * R),
        num_segments=n_users * R + 1)[:n_users * R].reshape(n_users, R)
    first_dispatch = jnp.minimum(state.first_dispatch, fd)
    n_resubmits = state.n_resubmits + jnp.sum(
        dispatch & (g.status == FAILED), dtype=jnp.int32)
    return replace(state, g=g2, spent=spent,
                   first_dispatch=first_dispatch,
                   n_resubmits=n_resubmits)


def broker_event(state, fleet, params, n_users: int):
    """One full Fig 20 cycle for every broker, plus the next poll."""
    R = fleet.r
    ctx = _measure(state, fleet, params, n_users)
    assigned, n_committed = _release(state, ctx, params, n_users, R)
    new_assigned, inv_order = _assign(state, ctx, assigned, n_committed,
                                      params, n_users, R)
    state = _dispatch(state, fleet, ctx, params, new_assigned, inv_order,
                      n_users, R)

    # ---- next scheduling event (paper Fig 17 hold heuristic) ----------
    dl_left = jnp.where(ctx["active"], params.deadline - state.t, 0.0)
    period = jnp.maximum(params.sched_min_period,
                         params.sched_frac * dl_left.max())
    return replace(state, next_sched=state.t + period)
