"""Shared enums / constants for the GridSim-in-JAX core.

Mirrors ``gridsim.GridSimTags`` (paper Fig 14) where the tag has an
observable analogue in the vectorised engine.  Tags that only existed to
route messages between Java threads (RESOURCE_CHARACTERISTICS, ...) are
represented by direct function calls on the fleet arrays instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

# ----------------------------------------------------------------------
# Gridlet lifecycle status (superset of gridsim.Gridlet status codes).
# ----------------------------------------------------------------------
CREATED = 0      # at the broker, not yet dispatched
IN_TRANSIT = 1   # dispatched, network transfer user -> resource
QUEUED = 2       # waiting for a free PE (space-shared only)
RUNNING = 3      # executing on a PE (or PE share)
RETURNING = 4    # finished, network transfer resource -> user
DONE = 5         # returned to originator
FAILED = 6       # resource failure / cancelled

# Resource allocation policy (gridsim.ResourceCharacteristics).
TIME_SHARED = 0
SPACE_SHARED = 1

# Space-shared queue discipline.
FCFS = 0
SJF = 1

# Broker optimisation strategy (paper section 4.2.2).
OPT_COST = 0
OPT_TIME = 1
OPT_COST_TIME = 2
OPT_NONE = 3

# Engine event kinds (the analogue of GridSimTags command tags).
EV_NONE = 0
EV_ARRIVAL = 1      # Gridlet reaches a resource       (GRIDLET_SUBMIT)
EV_COMPLETION = 2   # internal completion forecast      (paper section 3.5)
EV_RETURN = 3       # Gridlet back at the broker        (GRIDLET_RETURN)
EV_BROKER = 4       # periodic scheduling event         (EXPERIMENT)
EV_END = 5          # END_OF_SIMULATION
# The engine's own event kinds (incl. FAILURE/RECOVERY/RESERVATION/
# CALENDAR_STEP) are the des.K_* trace codes -- see core/des.py.

INF = float("inf")


def pytree_dataclass(cls):
    """Register a frozen dataclass as a JAX pytree (all fields are leaves)."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = [f.name for f in dataclasses.fields(cls)]

    def flatten(obj):
        return [getattr(obj, n) for n in fields], None

    def unflatten(_, leaves):
        return cls(**dict(zip(fields, leaves)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


def replace(obj: Any, **kw: Any) -> Any:
    return dataclasses.replace(obj, **kw)
