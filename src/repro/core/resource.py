"""Grid resource fleet (struct-of-arrays form of ``gridsim.GridResource``).

A resource = machines x PEs with a MIPS/SPEC rating, a management policy
(time-shared round-robin or space-shared FCFS/SJF), a price in G$ per
PE-time-unit, a time zone and a local (non-grid) load calendar.

The per-entity Java objects (PE, PEList, Machine, MachineList,
ResourceCharacteristics) flatten into one fleet table: for the allocation
algorithms in paper Figs 7-12 only (num_pe, mips_per_pe, policy) matter;
machine boundaries only matter for space-shared placement, which is
PE-count-equivalent under the paper's FCFS model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import SPACE_SHARED, TIME_SHARED, FCFS, pytree_dataclass


@pytree_dataclass
class Fleet:
    """All per-resource state. Shape [R] everywhere."""

    num_pe: jax.Array        # i32
    mips_per_pe: jax.Array   # f32: SPEC/MIPS rating of one PE
    cost_per_sec: jax.Array  # f32: G$ per PE-time-unit
    policy: jax.Array        # i32: TIME_SHARED | SPACE_SHARED
    queue_policy: jax.Array  # i32: FCFS | SJF (space-shared only)
    time_zone: jax.Array     # f32: hours offset
    base_load: jax.Array     # f32: [0,1) background (non-grid) load factor
    weekend_load: jax.Array  # f32: additional weekend load factor
    baud_rate: jax.Array     # f32: bytes / time-unit to+from this resource

    @property
    def r(self) -> int:
        return self.num_pe.shape[0]

    @property
    def max_pe(self) -> int:
        return int(self.num_pe.max())

    def peak_rate(self) -> jax.Array:
        """Aggregate advertised MIPS per resource."""
        return self.mips_per_pe * self.num_pe.astype(jnp.float32)

    def cost_per_mi(self) -> jax.Array:
        """G$ per MI -- the broker's resource-trading metric (Table 2)."""
        return self.cost_per_sec / self.mips_per_pe


def make_fleet(num_pe, mips_per_pe, cost_per_sec, policy,
               queue_policy=None, time_zone=None, base_load=None,
               weekend_load=None, baud_rate=None) -> Fleet:
    num_pe = jnp.asarray(num_pe, jnp.int32)
    r = num_pe.shape[0]

    def arr(x, default, dtype=jnp.float32):
        if x is None:
            x = default
        return jnp.broadcast_to(jnp.asarray(x, dtype), (r,)).astype(dtype)

    return Fleet(
        num_pe=num_pe,
        mips_per_pe=arr(mips_per_pe, None),
        cost_per_sec=arr(cost_per_sec, None),
        policy=arr(policy, None, jnp.int32),
        queue_policy=arr(queue_policy, FCFS, jnp.int32),
        time_zone=arr(time_zone, 0.0),
        base_load=arr(base_load, 0.0),
        weekend_load=arr(weekend_load, 0.0),
        baud_rate=arr(baud_rate, 9600.0),  # GridSimTags.DEFAULT_BAUD_RATE
    )


# ----------------------------------------------------------------------
# Paper Table 2: the WWG testbed fleet used in every section-5 experiment.
# (name, PEs, SPEC/MIPS rating, manager type, G$/PE-time-unit)
# ----------------------------------------------------------------------
WWG_TABLE2 = [
    ("R0", 4, 515, TIME_SHARED, 8.0),    # Compaq AlphaServer, VPAC Melbourne
    ("R1", 4, 377, TIME_SHARED, 4.0),    # Sun Ultra, AIST Tokyo
    ("R2", 4, 377, TIME_SHARED, 3.0),    # Sun Ultra, AIST Tokyo
    ("R3", 2, 377, TIME_SHARED, 3.0),    # Sun Ultra, AIST Tokyo
    ("R4", 2, 380, TIME_SHARED, 2.0),    # Intel VC820, CNR Pisa
    ("R5", 6, 410, TIME_SHARED, 5.0),    # SGI Origin 3200, ZIB Berlin
    ("R6", 16, 410, TIME_SHARED, 5.0),   # SGI Origin 3200, ZIB Berlin
    ("R7", 16, 410, SPACE_SHARED, 4.0),  # SGI Origin 3200, Charles U Prague
    ("R8", 2, 380, TIME_SHARED, 1.0),    # Intel VC820, Portsmouth UK
    ("R9", 4, 410, TIME_SHARED, 6.0),    # SGI Origin 3200, Manchester UK
    ("R10", 8, 377, TIME_SHARED, 3.0),   # Sun Ultra, ANL Chicago
]

WWG_TIME_ZONES = [10.0, 9.0, 9.0, 9.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0, -6.0]


def wwg_fleet(baud_rate: float = 28000.0) -> Fleet:
    """The simulated WWG testbed of paper Table 2."""
    return make_fleet(
        num_pe=[x[1] for x in WWG_TABLE2],
        mips_per_pe=[float(x[2]) for x in WWG_TABLE2],
        cost_per_sec=[x[4] for x in WWG_TABLE2],
        policy=[x[3] for x in WWG_TABLE2],
        time_zone=WWG_TIME_ZONES,
        baud_rate=baud_rate,
    )


def table1_resource(policy: int) -> Fleet:
    """The 2-PE, 1-MIPS resource of paper Table 1 / Figs 9 and 12."""
    return make_fleet(num_pe=[2], mips_per_pe=1.0, cost_per_sec=1.0,
                      policy=policy, baud_rate=jnp.inf)
