"""The vectorised discrete-event engine (paper sections 3.4-3.5),
refactored around a **resource-major superstep loop**.

State layout
------------
Gridlet state stays in the flat struct-of-arrays ``GridletBatch`` (the
broker's natural layout), but every *executing* Gridlet additionally
occupies one column of a resource-major ``[R_pad, J]`` job-slot table:

  ``SimState.slot[i]``          -- column of Gridlet ``i`` (-1 = none),
  ``SimState.row_gridlet[r,j]`` -- inverse map: flat Gridlet index (-1).

Slots are allocated on admission (RUNNING) and freed on completion, so
the table always holds exactly the running set.  Each while-loop
iteration -- one **superstep** -- gathers ``remaining`` into the table
and evaluates the Fig 8 PE-share + forecast math in a single call to
``kernels.ops.event_scan`` (compiled Pallas on TPU, vectorised XLA
fallback on CPU hosts); the kernel also emits the per-row earliest
completion (argmin) and PE occupancy so no second pass over the state is
needed.

Superstep semantics
-------------------
The paper's engine (section 3.4) pops one timestamp-ordered event per
iteration.  A superstep instead finds the earliest pending time ``t*``
across

  COMPLETION -- forecast finish of the smallest-remaining-share job
                (paper Fig 7 step 2d / Fig 10: internal events),
  RETURN     -- processed Gridlet reaches its broker (GRIDLET_RETURN),
  ARRIVAL    -- dispatched Gridlet reaches its resource (GRIDLET_SUBMIT),
  BROKER     -- periodic scheduling event of the economic broker,

advances all resident jobs analytically by the PE-share algebra of Fig 8
over ``[t, t*)``, then applies **every** event due at ``t*`` in one
vectorised batch per kind, in the priority order COMPLETION > RETURN >
ARRIVAL > BROKER.  Within a kind, ties are FIFO by flat Gridlet index --
exactly the order the one-event-at-a-time loop would have produced, so
the Table 1 / Fig 9 / Fig 12 traces are reproduced bit-for-bit.  Two
event chains that the paper engine spreads over extra zero-dt
iterations are folded into the same superstep because they are
observationally simultaneous: a zero-delay RETURN of a Gridlet that
completed at ``t*``, and the zero-delay ARRIVAL of a Gridlet the broker
dispatched at ``t*`` (arrival application commutes with the broker
event: it changes neither the in-flight set nor any quantity the broker
reads).  Forecasts are recomputed from state every superstep, so the
paper's stale-internal-event discard rule (section 3.4) holds by
construction: a superseded forecast simply never materialises.

Time-shared share allocation (Fig 8): with g jobs on P PEs,
  min_jobs = g // P PEs' worth of jobs run at MaxShare = eff_mips/min_jobs,
  the rest at MinShare = eff_mips/(min_jobs+1); jobs are laid onto PEs so
  the smallest-remaining jobs receive MaxShare -- this is the unique layout
  consistent with the worked trace of Fig 9 / Table 1 (G3 joins G2's PE at
  t=7, G1 keeps a whole PE and finishes at 10).

Space-shared (Figs 10-12): dedicated PE per job, FCFS (or SJF) queue;
PE identity never affects the trace (all PEs of a resource are equal
rated), so only the per-resource occupancy count is tracked.

``SimState.n_events`` counts applied events, ``n_steps`` counts
supersteps (while-loop iterations); ``overflow`` counts job-slot
allocation failures and must stay 0 (drivers size ``J`` accordingly).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import broker as broker_mod
from . import calendar, network
from ..kernels import ops as kernel_ops
from ..kernels.event_scan import BIG as _BIG  # empty-slot sentinel
from .segments import group_rank
from .types import (CREATED, DONE, EV_ARRIVAL, EV_BROKER, EV_COMPLETION,
                    EV_RETURN, FCFS, IN_TRANSIT, INF, QUEUED, RETURNING,
                    RUNNING, SJF, SPACE_SHARED, TIME_SHARED, pytree_dataclass)

TRACE_LEN = 64
BLOCK_R = 8          # event_scan row blocking; resource axis padded to it


@pytree_dataclass
class SimParams:
    """Per-experiment knobs; all traced so grids of experiments vmap."""
    deadline: jax.Array        # f32[U]
    budget: jax.Array          # f32[U]
    opt: jax.Array             # i32[U] broker optimisation strategy
    max_gridlet_per_pe: jax.Array  # i32[] dispatch staging limit (paper: 2)
    sched_min_period: jax.Array    # f32[] broker poll floor (paper: 1.0)
    sched_frac: jax.Array          # f32[] fraction of deadline-left (0.01)
    measure_alpha: jax.Array       # f32[] measurement smoothing
    registered: jax.Array          # bool[R] GIS availability mask


def default_params(deadline, budget, opt, n_users: int,
                   n_resources: int = 1, registered=None) -> SimParams:
    f = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n_users,))
    if registered is None:
        registered = jnp.ones((n_resources,), bool)
    return SimParams(
        deadline=f(deadline), budget=f(budget),
        opt=jnp.broadcast_to(jnp.asarray(opt, jnp.int32), (n_users,)),
        max_gridlet_per_pe=jnp.asarray(2, jnp.int32),
        sched_min_period=jnp.asarray(1.0, jnp.float32),
        sched_frac=jnp.asarray(0.01, jnp.float32),
        measure_alpha=jnp.asarray(0.5, jnp.float32),
        registered=registered,
    )


@pytree_dataclass
class SimState:
    t: jax.Array               # f32 current simulation time
    g: object                  # GridletBatch
    slot: jax.Array            # i32[N] job-slot column (-1 = none)
    row_gridlet: jax.Array     # i32[R_pad, J] slot -> gridlet (-1 = free)
    spent: jax.Array           # f32[U] committed budget
    done_on: jax.Array         # f32[U,R] jobs of u completed on r
    first_dispatch: jax.Array  # f32[U,R] first dispatch instant (inf)
    next_sched: jax.Array      # f32 next broker event
    term_time: jax.Array       # f32[U] broker termination instant
    n_events: jax.Array        # i32 applied events (batched kinds summed)
    n_steps: jax.Array         # i32 supersteps (while-loop iterations)
    n_trace: jax.Array         # i32 trace entries written
    overflow: jax.Array        # i32 job-slot allocation failures (== 0)
    trace_t: jax.Array         # f32[TRACE_LEN]
    trace_kind: jax.Array      # i32[TRACE_LEN]
    trace_who: jax.Array       # i32[TRACE_LEN]


class SimResult(NamedTuple):
    gridlets: object
    spent: jax.Array
    term_time: jax.Array
    n_events: jax.Array
    trace: tuple
    n_steps: jax.Array
    overflow: jax.Array


# ----------------------------------------------------------------------
# Resource dynamics
# ----------------------------------------------------------------------

def _rates(state, fleet, n_resources):
    """Per-gridlet execution rate (MI per time unit) under Fig 8 shares.

    Flat-layout XLA reference path, kept as the oracle the kernel path
    must agree with (asserted in tests); the superstep loop itself goes
    through kernels.ops.event_scan on the resource-major table.
    """
    g = state.g
    running = g.status == RUNNING
    res = jnp.clip(g.resource, 0, n_resources - 1)
    eff = calendar.effective_mips(fleet, state.t)          # [R] per PE
    policy = fleet.policy[res]

    # --- time-shared: rank jobs on each resource by remaining MI ---
    ts_member = running & (policy == TIME_SHARED)
    rank, counts = group_rank(res, ts_member, g.remaining, n_resources)
    g_on_r = counts[res].astype(jnp.int32)                  # jobs on my res
    p_r = fleet.num_pe[res]
    min_jobs = g_on_r // jnp.maximum(p_r, 1)
    extra = g_on_r % jnp.maximum(p_r, 1)
    max_share_count = (p_r - extra) * min_jobs
    divisor = min_jobs + (rank >= max_share_count).astype(jnp.int32)
    ts_rate = eff[res] / jnp.maximum(divisor, 1).astype(jnp.float32)

    # --- space-shared: a dedicated PE at full effective rate ---
    ss_rate = eff[res]

    rate = jnp.where(policy == TIME_SHARED, ts_rate, ss_rate)
    return jnp.where(running, rate, 0.0)


def _scan_events(state, fleet, n_resources, r_pad):
    """Resource-major Fig 8 scan through kernels.ops.event_scan.

    Gathers ``remaining`` into the [R_pad, J] job-slot table (flat
    gridlet index as the FIFO tie-break key) and returns the kernel
    outputs (rate [R_pad, J], t_min [R_pad], argmin col [R_pad],
    occupancy [R_pad]).
    """
    g = state.g
    rg = state.row_gridlet
    occupied = rg >= 0
    gid = jnp.clip(rg, 0, g.n - 1)
    # An occupied slot whose remaining underflowed to exactly 0 (f32
    # advance rounding) must stay visible to the kernel -- 0 is the
    # empty-slot sentinel -- so it is clamped to a tiny epsilon: it then
    # forecasts an immediate completion and keeps its PE share, exactly
    # as a zero-remaining RUNNING job did in the one-event-at-a-time
    # engine.
    rem_rj = jnp.where(occupied,
                       jnp.maximum(g.remaining[gid], 1e-30), 0.0)
    tie_rj = jnp.where(occupied, rg, 2 ** 30).astype(jnp.float32)
    pad = r_pad - n_resources
    eff = jnp.pad(calendar.effective_mips(fleet, state.t), (0, pad),
                  constant_values=1.0)
    npe = jnp.pad(fleet.num_pe, (0, pad), constant_values=1)
    pol = jnp.pad(fleet.policy, (0, pad))
    return kernel_ops.event_scan(rem_rj, eff, npe, tie=tie_rj, policy=pol)


# ----------------------------------------------------------------------
# Batched event application
# ----------------------------------------------------------------------

def _free_slots(state, mask, res, r_pad):
    """Release the job slots of every gridlet in ``mask``."""
    from .types import replace
    j_cap = state.row_gridlet.shape[1]
    rows = jnp.where(mask, res, r_pad)          # out of range: dropped
    cols = jnp.where(mask, jnp.clip(state.slot, 0, j_cap - 1), 0)
    rg = state.row_gridlet.at[rows, cols].set(-1, mode="drop")
    return replace(state, row_gridlet=rg,
                   slot=jnp.where(mask, -1, state.slot))


def _alloc_slots(state, mask, res, n_resources, r_pad):
    """Allocate a free job-slot column to every gridlet in ``mask``.

    Within a resource, gridlets take columns in flat-index order (the
    FIFO tie-break also used by the kernel, so column identity never
    matters).  Gridlets that find no free column are counted in
    ``overflow`` -- drivers size J so this cannot happen.
    """
    from .types import replace
    g = state.g
    n = g.n
    j_cap = state.row_gridlet.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)
    used = state.row_gridlet >= 0
    free_order = jnp.argsort(used, axis=1, stable=True)   # free cols first
    n_free = j_cap - jnp.sum(used, axis=1)                # [R_pad]
    rank, _ = group_rank(res, mask, idx, n_resources)
    ok = mask & (rank < n_free[res])
    col = free_order[res, jnp.clip(rank, 0, j_cap - 1)]
    rows = jnp.where(ok, res, r_pad)            # out of range: dropped
    cols = jnp.where(ok, col, 0)
    rg = state.row_gridlet.at[rows, cols].set(idx, mode="drop")
    return replace(
        state, row_gridlet=rg,
        slot=jnp.where(ok, col, state.slot),
        overflow=state.overflow + jnp.sum(mask & ~ok, dtype=jnp.int32))


def _apply_completions(state, fleet, completes, t_next, n_resources,
                       r_pad):
    """RUNNING -> RETURNING for the whole batch; job slots freed."""
    from .types import replace
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    out_delay = network.transfer_delay(g.out_bytes, fleet.baud_rate[res])
    g = replace(
        g,
        status=jnp.where(completes, RETURNING, g.status),
        finish=jnp.where(completes, t_next, g.finish),
        t_event=jnp.where(completes, t_next + out_delay, g.t_event),
    )
    return _free_slots(replace(state, g=g), completes, res, r_pad)


def _admit_queued(state, fleet, free_pe, t_next, n_resources):
    """Freed space-shared PEs admit the next queued Gridlets in FCFS/SJF
    order (Fig 10 step 3).  Returns (state, admitted mask) -- slots are
    allocated later together with the arrival batch.
    """
    from .types import replace
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    queued = g.status == QUEUED
    # FCFS: earliest arrival at the resource (QUEUED jobs keep their
    # arrival instant in t_event); SJF: smallest job. Ties by index.
    qkey = jnp.where(fleet.queue_policy[res] == SJF, g.length_mi,
                     g.t_event)
    rank, _ = group_rank(res, queued, qkey, n_resources)
    admitq = queued & (rank < free_pe[res])
    g = replace(
        g,
        status=jnp.where(admitq, RUNNING, g.status),
        start=jnp.where(admitq, jnp.minimum(g.start, t_next), g.start),
        t_event=jnp.where(admitq, INF, g.t_event),
    )
    return replace(state, g=g), admitq


def _apply_returns(state, fleet, t_next, n_users, n_resources):
    """RETURNING & due -> DONE for the whole batch; broker measurement
    update (paper 4.2.1 step 6).  Includes zero-delay returns of jobs
    that completed earlier in this same superstep.
    """
    from .types import replace
    g = state.g
    ret_due = (g.status == RETURNING) & (g.t_event <= t_next)
    g = replace(g,
                status=jnp.where(ret_due, DONE, g.status),
                returned=jnp.where(ret_due, t_next, g.returned))
    ur = g.user * n_resources + jnp.clip(g.resource, 0, n_resources - 1)
    done_on = state.done_on + jax.ops.segment_sum(
        ret_due.astype(jnp.float32), ur,
        num_segments=n_users * n_resources).reshape(n_users, n_resources)
    return replace(state, g=g, done_on=done_on), ret_due


def _apply_arrivals(state, fleet, free_pe, arr_pre, t_next, n_resources):
    """IN_TRANSIT & due -> RUNNING (time-shared / free PE) or QUEUED,
    for the whole batch.

    All time-shared arrivals commute (every resident job just
    re-shares).  Space-shared arrivals fill the ``free_pe`` PEs left
    after this superstep's queue admissions -- arrivals already due
    before the broker event (``arr_pre``) first, then this superstep's
    zero-delay dispatches, flat-index order within each class: exactly
    the order the one-at-a-time loop (ARRIVAL before BROKER at equal
    time) admits them -- and the rest join the queue stamped with their
    arrival instant (the FCFS key).  Returns (state, arrival mask,
    newly-running mask).
    """
    from .types import replace
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    idx = jnp.arange(g.n, dtype=jnp.int32)
    arr_due = (g.status == IN_TRANSIT) & (g.t_event <= t_next)
    is_ss = fleet.policy[res] == SPACE_SHARED
    arr_ss = arr_due & is_ss
    order = jnp.where(arr_pre, idx, idx + g.n)
    rank = jax.lax.cond(
        arr_ss.any(),
        lambda: group_rank(res, arr_ss, order, n_resources)[0],
        lambda: jnp.full((g.n,), jnp.int32(2 ** 30)))
    arr_run = arr_due & (~is_ss | (rank < free_pe[res]))
    arr_queue = arr_ss & ~arr_run
    g = replace(
        g,
        status=jnp.where(arr_run, RUNNING,
                         jnp.where(arr_queue, QUEUED, g.status)),
        start=jnp.where(arr_run, jnp.minimum(g.start, t_next), g.start),
        # QUEUED jobs keep their arrival instant in t_event (the FCFS
        # key); QUEUED is never scanned as a pending event so it's safe.
        t_event=jnp.where(arr_run, INF,
                          jnp.where(arr_queue, t_next, g.t_event)),
    )
    return replace(state, g=g), arr_due, arr_run


# ----------------------------------------------------------------------
# Main loop
# ----------------------------------------------------------------------

def _user_flags(state, params, fleet, n_users):
    """(active, finished) per user -- paper 4.2.1 step 7 semantics.

    A broker stays active only while its cheapest possible purchase --
    the user's smallest still-undispatched Gridlet priced at the best
    G$/MI on the grid -- fits in the remaining budget.  With nothing
    left to dispatch the broker goes inactive (every further poll would
    be a no-op); the user is finished once inactive with nothing in
    flight.
    """
    g = state.g
    u = g.user
    not_done = (g.status != DONE).astype(jnp.int32)
    n_not_done = jax.ops.segment_sum(not_done, u, num_segments=n_users)
    inflight = ((g.status == IN_TRANSIT) | (g.status == QUEUED) |
                (g.status == RUNNING) | (g.status == RETURNING))
    n_inflight = jax.ops.segment_sum(inflight.astype(jnp.int32), u,
                                     num_segments=n_users)
    min_job_cost = broker_mod.min_affordable_cost(g, fleet, n_users)
    all_done = n_not_done == 0
    active = ((state.t < params.deadline) &
              (state.spent + min_job_cost <= params.budget) &
              ~all_done)
    finished = (all_done | ~active) & (n_inflight == 0)
    return active, finished


def step(state: SimState, fleet, params: SimParams, n_users: int):
    """One superstep: scan once, pick earliest time t*, advance, apply
    ALL events due at t* in priority order."""
    from .types import replace
    n_resources = fleet.r
    r_pad = state.row_gridlet.shape[0]
    g = state.g
    j_cap = state.row_gridlet.shape[1]

    # ---- one kernel scan: rates, forecasts, argmin, occupancy --------
    rate_rj, tmin_rows, amin_rows, occ_rows = _scan_events(
        state, fleet, n_resources, r_pad)
    res = jnp.clip(g.resource, 0, n_resources - 1)
    has_slot = (g.status == RUNNING) & (state.slot >= 0)
    rate = jnp.where(has_slot,
                     rate_rj[res, jnp.clip(state.slot, 0, j_cap - 1)], 0.0)
    rel = jnp.where(has_slot,
                    g.remaining / jnp.maximum(rate, 1e-30), INF)

    tmin = tmin_rows.min()
    t_complete = jnp.where(tmin < _BIG, state.t + tmin, INF)

    ret_t = jnp.where(g.status == RETURNING, g.t_event, INF)
    t_return = ret_t.min()
    arr_t = jnp.where(g.status == IN_TRANSIT, g.t_event, INF)
    t_arrive = arr_t.min()
    active, _ = _user_flags(state, params, fleet, n_users)
    t_broker = jnp.where(active.any(), state.next_sched, INF)

    # Priority among simultaneous events: COMPLETION, RETURN, ARRIVAL,
    # BROKER -- every kind due at t* fires this superstep, applied in
    # that order.
    times = jnp.stack([t_complete, t_return, t_arrive, t_broker])
    t_min_all = times.min()
    any_event = jnp.isfinite(t_min_all)
    t_next = jnp.where(any_event, t_min_all, state.t)

    # Advance every running job analytically over [t, t_next).
    dt = jnp.maximum(t_next - state.t, 0.0)
    completes = has_slot & any_event & (state.t + rel <= t_next)
    new_remaining = jnp.where(
        completes, 0.0, jnp.maximum(g.remaining - rate * dt, 0.0))
    # Trace representative: the kernel's per-row argmin of the earliest
    # row (first row attaining the global forecast minimum).
    r_star = jnp.argmin(tmin_rows)
    who_c = state.row_gridlet[
        r_star, jnp.clip(amin_rows[r_star], 0, j_cap - 1)]
    state = replace(state, g=replace(g, remaining=new_remaining), t=t_next)

    # ---- COMPLETION batch (+ space-shared queue admission) -----------
    state = _apply_completions(state, fleet, completes, t_next,
                               n_resources, r_pad)
    # Freed PEs admit queued Gridlets.  Queued jobs only exist while
    # every PE is busy, so the kernel occupancy minus this batch's
    # completions is the exact busy count.
    n_comp_r = jax.ops.segment_sum(completes.astype(jnp.int32), res,
                                   num_segments=n_resources)
    free_pe = jnp.maximum(
        fleet.num_pe - (occ_rows[:n_resources] - n_comp_r), 0)
    free_pe = jnp.where(fleet.policy == SPACE_SHARED, free_pe, 0)
    ss_freed = completes & (fleet.policy[res] == SPACE_SHARED)
    state, admitq = jax.lax.cond(
        ss_freed.any(),
        lambda s: _admit_queued(s, fleet, free_pe, t_next, n_resources),
        lambda s: (s, jnp.zeros_like(completes)), state)
    free_pe = free_pe - jax.ops.segment_sum(
        admitq.astype(jnp.int32), res, num_segments=n_resources)

    # ---- RETURN batch ------------------------------------------------
    state, ret_due = _apply_returns(state, fleet, t_next, n_users,
                                    n_resources)
    who_r = jnp.argmax(ret_due).astype(jnp.int32)

    # Arrivals already due before the broker fires hold admission
    # priority over its zero-delay dispatches (ARRIVAL > BROKER).
    arr_pre = (state.g.status == IN_TRANSIT) & (state.g.t_event <= t_next)

    # ---- BROKER event ------------------------------------------------
    fired_b = jnp.isfinite(t_broker) & (t_broker <= t_next)
    state = jax.lax.cond(
        fired_b,
        lambda s: broker_mod.broker_event(s, fleet, params, n_users),
        lambda s: s, state)

    # ---- ARRIVAL batch (incl. zero-delay arrivals of this superstep's
    # dispatches; commutes with the broker event) ----------------------
    state, arr_due, arr_run = _apply_arrivals(state, fleet, free_pe,
                                              arr_pre, t_next,
                                              n_resources)
    who_a = jnp.argmax(arr_due).astype(jnp.int32)

    # ---- allocate job slots for everything newly RUNNING -------------
    newly = admitq | arr_run
    res_now = jnp.clip(state.g.resource, 0, n_resources - 1)
    state = jax.lax.cond(
        newly.any(),
        lambda s: _alloc_slots(s, newly, res_now, n_resources, r_pad),
        lambda s: s, state)

    # ---- bookkeeping: termination instants, trace, counters ----------
    _, finished = _user_flags(state, params, fleet, n_users)
    term = jnp.where(finished & ~jnp.isfinite(state.term_time),
                     t_next, state.term_time)

    n_comp = jnp.sum(completes, dtype=jnp.int32)
    n_ret = jnp.sum(ret_due, dtype=jnp.int32)
    n_arr = jnp.sum(arr_due, dtype=jnp.int32)
    fired = jnp.stack([n_comp > 0, n_ret > 0, n_arr > 0, fired_b])
    whos = jnp.stack([who_c, who_r, who_a, jnp.asarray(-1, jnp.int32)])
    off = jnp.cumsum(fired.astype(jnp.int32)) - fired.astype(jnp.int32)
    # Out-of-range positions (unfired kinds / full trace) are dropped.
    pos = jnp.where(fired, state.n_trace + off, TRACE_LEN)
    kinds = jnp.arange(4, dtype=jnp.int32)
    state = replace(
        state,
        term_time=term,
        n_events=state.n_events + n_comp + n_ret + n_arr +
        fired_b.astype(jnp.int32),
        n_steps=state.n_steps + 1,
        n_trace=state.n_trace + jnp.sum(fired, dtype=jnp.int32),
        trace_t=state.trace_t.at[pos].set(t_next, mode="drop"),
        trace_kind=state.trace_kind.at[pos].set(kinds, mode="drop"),
        trace_who=state.trace_who.at[pos].set(whos, mode="drop"),
    )
    return state


def _continue(state, fleet, params, n_users, max_events):
    _, finished = _user_flags(state, params, fleet, n_users)
    return (~finished.all()) & (state.n_steps < max_events)


def init_state(gridlets, fleet, n_users: int, first_sched: float = 0.0,
               max_jobs: int | None = None) -> SimState:
    """``max_jobs`` bounds concurrently RUNNING gridlets per resource
    (the J axis of the job-slot table); defaults to the safe bound N."""
    n = gridlets.n
    j_cap = n if max_jobs is None else min(max_jobs, n)
    r_pad = -(-fleet.r // BLOCK_R) * BLOCK_R
    return SimState(
        t=jnp.asarray(0.0, jnp.float32),
        g=gridlets,
        slot=jnp.full((n,), -1, jnp.int32),
        row_gridlet=jnp.full((r_pad, j_cap), -1, jnp.int32),
        spent=jnp.zeros((n_users,), jnp.float32),
        done_on=jnp.zeros((n_users, fleet.r), jnp.float32),
        first_dispatch=jnp.full((n_users, fleet.r), INF, jnp.float32),
        next_sched=jnp.asarray(first_sched, jnp.float32),
        term_time=jnp.full((n_users,), INF, jnp.float32),
        n_events=jnp.asarray(0, jnp.int32),
        n_steps=jnp.asarray(0, jnp.int32),
        n_trace=jnp.asarray(0, jnp.int32),
        overflow=jnp.asarray(0, jnp.int32),
        trace_t=jnp.full((TRACE_LEN,), INF, jnp.float32),
        trace_kind=jnp.full((TRACE_LEN,), -1, jnp.int32),
        trace_who=jnp.full((TRACE_LEN,), -1, jnp.int32),
    )


def _finalize(state: SimState) -> SimResult:
    # Users that never started (e.g. zero budget) terminate at final t.
    term = jnp.where(jnp.isfinite(state.term_time), state.term_time,
                     state.t)
    return SimResult(gridlets=state.g, spent=state.spent, term_time=term,
                     n_events=state.n_events,
                     trace=(state.trace_t, state.trace_kind,
                            state.trace_who),
                     n_steps=state.n_steps, overflow=state.overflow)


@functools.partial(jax.jit, static_argnames=("n_users", "max_events",
                                             "max_jobs"))
def _run_jit(gridlets, fleet, params, n_users, max_events, max_jobs):
    state = init_state(gridlets, fleet, n_users, max_jobs=max_jobs)
    state = jax.lax.while_loop(
        lambda s: _continue(s, fleet, params, n_users, max_events),
        lambda s: step(s, fleet, params, n_users),
        state)
    return _finalize(state)


def run(gridlets, fleet, params: SimParams, n_users: int,
        max_events: int, max_jobs: int | None = None) -> SimResult:
    """Run a full experiment: broker-driven scheduling + execution."""
    return _run_jit(gridlets, fleet, params, n_users, max_events,
                    max_jobs)


def run_inner(gridlets, fleet, params: SimParams, n_users: int,
              max_events: int,
              max_jobs: int | None = None) -> SimResult:
    """Unjitted variant for use under an outer vmap/jit (sweep)."""
    state = init_state(gridlets, fleet, n_users, max_jobs=max_jobs)
    state = jax.lax.while_loop(
        lambda s: _continue(s, fleet, params, n_users, max_events),
        lambda s: step(s, fleet, params, n_users),
        state)
    return _finalize(state)


def run_direct(gridlets, fleet, resource_idx, dispatch_time,
               max_events: int) -> SimResult:
    """Broker-less mode: Gridlets are pre-routed to ``resource_idx`` and
    enter the network at ``dispatch_time`` -- the paper's Table 1 / Figs 9
    and 12 scenario (arrivals straight into one resource).
    """
    from .types import replace
    n = gridlets.n
    r = jnp.broadcast_to(jnp.asarray(resource_idx, jnp.int32), (n,))
    t0 = jnp.broadcast_to(jnp.asarray(dispatch_time, jnp.float32), (n,))
    delay = network.transfer_delay(gridlets.in_bytes, fleet.baud_rate[r])
    g = replace(gridlets,
                status=jnp.full((n,), IN_TRANSIT, jnp.int32),
                resource=r, assigned=r, t_event=t0 + delay)
    params = default_params(jnp.asarray(-1.0), jnp.asarray(0.0),
                            jnp.asarray(0), 1, fleet.r)  # brokers inert
    return _run_jit(g, fleet, params, 1, max_events, None)
