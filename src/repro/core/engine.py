"""The vectorised discrete-event engine (paper sections 3.4-3.5).

One ``lax.while_loop`` advances the whole grid: every iteration finds the
earliest pending event across

  COMPLETION -- forecast finish of the smallest-remaining-share job
                (paper Fig 7 step 2d / Fig 10: internal events),
  RETURN     -- processed Gridlet reaches its broker (GRIDLET_RETURN),
  ARRIVAL    -- dispatched Gridlet reaches its resource (GRIDLET_SUBMIT),
  BROKER     -- periodic scheduling event of the economic broker,

advances all resident jobs analytically by the PE-share algebra of Fig 8,
and applies the event.  Forecasts are recomputed from state on every
iteration, so the paper's stale-internal-event discard rule (section 3.4)
holds by construction: a superseded forecast simply never materialises.

Time-shared share allocation (Fig 8): with g jobs on P PEs,
  min_jobs = g // P PEs' worth of jobs run at MaxShare = eff_mips/min_jobs,
  the rest at MinShare = eff_mips/(min_jobs+1); jobs are laid onto PEs so
  the smallest-remaining jobs receive MaxShare -- this is the unique layout
  consistent with the worked trace of Fig 9 / Table 1 (G3 joins G2's PE at
  t=7, G1 keeps a whole PE and finishes at 10).

Space-shared (Figs 10-12): dedicated PE per job, FCFS (or SJF) queue.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import broker as broker_mod
from . import calendar, network
from .segments import group_rank
from .types import (CREATED, DONE, EV_ARRIVAL, EV_BROKER, EV_COMPLETION,
                    EV_RETURN, FCFS, IN_TRANSIT, INF, QUEUED, RETURNING,
                    RUNNING, SJF, SPACE_SHARED, TIME_SHARED, pytree_dataclass)

TRACE_LEN = 64


@pytree_dataclass
class SimParams:
    """Per-experiment knobs; all traced so grids of experiments vmap."""
    deadline: jax.Array        # f32[U]
    budget: jax.Array          # f32[U]
    opt: jax.Array             # i32[U] broker optimisation strategy
    max_gridlet_per_pe: jax.Array  # i32[] dispatch staging limit (paper: 2)
    sched_min_period: jax.Array    # f32[] broker poll floor (paper: 1.0)
    sched_frac: jax.Array          # f32[] fraction of deadline-left (0.01)
    measure_alpha: jax.Array       # f32[] measurement smoothing
    registered: jax.Array          # bool[R] GIS availability mask


def default_params(deadline, budget, opt, n_users: int,
                   n_resources: int = 1, registered=None) -> SimParams:
    f = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n_users,))
    if registered is None:
        registered = jnp.ones((n_resources,), bool)
    return SimParams(
        deadline=f(deadline), budget=f(budget),
        opt=jnp.broadcast_to(jnp.asarray(opt, jnp.int32), (n_users,)),
        max_gridlet_per_pe=jnp.asarray(2, jnp.int32),
        sched_min_period=jnp.asarray(1.0, jnp.float32),
        sched_frac=jnp.asarray(0.01, jnp.float32),
        measure_alpha=jnp.asarray(0.5, jnp.float32),
        registered=registered,
    )


@pytree_dataclass
class SimState:
    t: jax.Array               # f32 current simulation time
    g: object                  # GridletBatch
    pe: jax.Array              # i32[N] PE slot (space-shared)
    spent: jax.Array           # f32[U] committed budget
    done_on: jax.Array         # f32[U,R] jobs of u completed on r
    first_dispatch: jax.Array  # f32[U,R] first dispatch instant (inf)
    next_sched: jax.Array      # f32 next broker event
    term_time: jax.Array       # f32[U] broker termination instant
    n_events: jax.Array        # i32
    trace_t: jax.Array         # f32[TRACE_LEN]
    trace_kind: jax.Array      # i32[TRACE_LEN]
    trace_who: jax.Array       # i32[TRACE_LEN]


class SimResult(NamedTuple):
    gridlets: object
    spent: jax.Array
    term_time: jax.Array
    n_events: jax.Array
    trace: tuple


# ----------------------------------------------------------------------
# Resource dynamics
# ----------------------------------------------------------------------

def _rates(state, fleet, n_resources, max_pe):
    """Per-gridlet execution rate (MI per time unit) under Fig 8 shares."""
    g = state.g
    running = g.status == RUNNING
    res = jnp.clip(g.resource, 0, n_resources - 1)
    eff = calendar.effective_mips(fleet, state.t)          # [R] per PE
    policy = fleet.policy[res]

    # --- time-shared: rank jobs on each resource by remaining MI ---
    ts_member = running & (policy == TIME_SHARED)
    rank, counts = group_rank(res, ts_member, g.remaining, n_resources)
    g_on_r = counts[res].astype(jnp.int32)                  # jobs on my res
    p_r = fleet.num_pe[res]
    min_jobs = g_on_r // jnp.maximum(p_r, 1)
    extra = g_on_r % jnp.maximum(p_r, 1)
    max_share_count = (p_r - extra) * min_jobs
    divisor = min_jobs + (rank >= max_share_count).astype(jnp.int32)
    ts_rate = eff[res] / jnp.maximum(divisor, 1).astype(jnp.float32)

    # --- space-shared: a dedicated PE at full effective rate ---
    ss_rate = eff[res]

    rate = jnp.where(policy == TIME_SHARED, ts_rate, ss_rate)
    return jnp.where(running, rate, 0.0)


def _ss_occupancy(state, fleet, n_resources, max_pe):
    """PE occupancy grid for space-shared placement. BIG where invalid."""
    g = state.g
    run_ss = (g.status == RUNNING) & \
        (fleet.policy[jnp.clip(g.resource, 0, n_resources - 1)] == SPACE_SHARED)
    res = jnp.where(run_ss, g.resource, 0)
    pe = jnp.where(run_ss, jnp.clip(state.pe, 0, max_pe - 1), 0)
    occ = jnp.zeros((n_resources, max_pe), jnp.int32)
    occ = occ.at[res, pe].add(run_ss.astype(jnp.int32))
    invalid = jnp.arange(max_pe)[None, :] >= fleet.num_pe[:, None]
    return occ + invalid.astype(jnp.int32) * 10**6


# ----------------------------------------------------------------------
# Event application
# ----------------------------------------------------------------------

def _apply_completion(state, fleet, i, t, n_resources, max_pe):
    """RUNNING -> RETURNING; space-shared: admit next queued job."""
    from .types import replace
    g = state.g
    r = g.resource[i]
    out_delay = network.transfer_delay(g.out_bytes[i], fleet.baud_rate[r])
    g = replace(
        g,
        status=g.status.at[i].set(RETURNING),
        remaining=g.remaining.at[i].set(0.0),
        finish=g.finish.at[i].set(t),
        t_event=g.t_event.at[i].set(t + out_delay),
    )
    state = replace(state, g=g)

    # Space-shared: freed PE admits the next queued Gridlet (Fig 10 step 3).
    is_ss = fleet.policy[r] == SPACE_SHARED
    queued = (g.status == QUEUED) & (g.resource == r)
    # FCFS: earliest arrival at the resource (QUEUED jobs keep their
    # arrival instant in t_event); SJF: smallest job. Ties by index.
    key = jnp.where(fleet.queue_policy[r] == SJF, g.length_mi, g.t_event)
    key = jnp.where(queued, key, INF)
    j = jnp.argmin(key)
    any_queued = is_ss & queued[j]

    freed_pe = state.pe[i]

    def admit(state):
        g = state.g
        g = replace(
            g,
            status=g.status.at[j].set(RUNNING),
            start=g.start.at[j].set(jnp.minimum(g.start[j], t)),
            t_event=g.t_event.at[j].set(INF),
        )
        return replace(state, g=g, pe=state.pe.at[j].set(freed_pe))

    return jax.lax.cond(any_queued, admit, lambda s: s, state)


def _apply_return(state, fleet, params, i, t):
    """RETURNING -> DONE; broker measurement update (paper 4.2.1 step 6)."""
    from .types import replace
    g = state.g
    u, r = g.user[i], g.resource[i]
    g = replace(g, status=g.status.at[i].set(DONE),
                returned=g.returned.at[i].set(t))
    done_on = state.done_on.at[u, r].add(1.0)
    return replace(state, g=g, done_on=done_on)


def _apply_arrival(state, fleet, i, t, n_resources, max_pe):
    """IN_TRANSIT -> RUNNING (time-shared / free PE) or QUEUED.

    Time-shared arrivals commute (every resident job just re-shares), so
    ALL arrivals due at exactly ``t`` on time-shared resources are
    admitted in one event -- broker dispatch storms otherwise cost one
    engine iteration per Gridlet (measured 1.8x fewer iterations on the
    20-user benchmark; EXPERIMENTS.md section Perf, engine cell).
    Space-shared admission stays one-at-a-time (PE assignment orders).
    """
    from .types import replace
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)

    # --- batched time-shared arrivals at this instant ---
    due_ts = ((g.status == IN_TRANSIT) & (g.t_event <= t) &
              (fleet.policy[res] == TIME_SHARED))
    status = jnp.where(due_ts, RUNNING, g.status)
    start = jnp.where(due_ts, jnp.minimum(g.start, t), g.start)
    t_event = jnp.where(due_ts, INF, g.t_event)

    # --- single space-shared arrival (gridlet i), if applicable ---
    r = g.resource[i]
    is_ss = fleet.policy[r] == SPACE_SHARED
    occ = _ss_occupancy(state, fleet, n_resources, max_pe)
    free_pe = jnp.argmin(occ[r])
    has_free = occ[r, free_pe] == 0
    starts_now = is_ss & has_free
    status = status.at[i].set(
        jnp.where(is_ss, jnp.where(starts_now, RUNNING, QUEUED),
                  status[i]))
    start = start.at[i].set(
        jnp.where(starts_now, jnp.minimum(g.start[i], t), start[i]))
    # QUEUED jobs keep their arrival instant in t_event (the FCFS key);
    # QUEUED status is never scanned as a pending event so this is safe.
    t_event = t_event.at[i].set(
        jnp.where(is_ss, jnp.where(starts_now, INF, t), t_event[i]))
    pe = state.pe.at[i].set(
        jnp.where(is_ss & has_free, free_pe, state.pe[i]))

    g = replace(g, status=status, start=start, t_event=t_event)
    return replace(state, g=g, pe=pe)


# ----------------------------------------------------------------------
# Main loop
# ----------------------------------------------------------------------

def _user_flags(state, params, fleet, n_users):
    """(active, finished) per user -- paper 4.2.1 step 7 semantics."""
    g = state.g
    u = g.user
    not_done = (g.status != DONE).astype(jnp.int32)
    n_not_done = jax.ops.segment_sum(not_done, u, num_segments=n_users)
    inflight = ((g.status == IN_TRANSIT) | (g.status == QUEUED) |
                (g.status == RUNNING) | (g.status == RETURNING))
    n_inflight = jax.ops.segment_sum(inflight.astype(jnp.int32), u,
                                     num_segments=n_users)
    min_job_cost = (fleet.cost_per_sec / fleet.mips_per_pe).min() * 1.0
    all_done = n_not_done == 0
    active = ((state.t < params.deadline) &
              (state.spent + min_job_cost <= params.budget) &
              ~all_done)
    finished = (all_done | ~active) & (n_inflight == 0)
    return active, finished


def step(state: SimState, fleet, params: SimParams, n_users: int,
         max_pe: int):
    """One engine iteration: pick earliest event, advance, apply."""
    from .types import replace
    n_resources = fleet.r
    g = state.g

    rate = _rates(state, fleet, n_resources, max_pe)
    forecast = jnp.where(g.status == RUNNING,
                         state.t + g.remaining / jnp.maximum(rate, 1e-30),
                         INF)
    t_complete = forecast.min()
    i_complete = jnp.argmin(forecast)

    ret_t = jnp.where(g.status == RETURNING, g.t_event, INF)
    t_return, i_return = ret_t.min(), jnp.argmin(ret_t)

    arr_t = jnp.where(g.status == IN_TRANSIT, g.t_event, INF)
    t_arrive, i_arrive = arr_t.min(), jnp.argmin(arr_t)

    active, _ = _user_flags(state, params, fleet, n_users)
    t_broker = jnp.where(active.any(), state.next_sched, INF)

    # Priority among simultaneous events: COMPLETION, RETURN, ARRIVAL,
    # BROKER (argmin keeps the first of equal keys).
    times = jnp.stack([t_complete, t_return, t_arrive, t_broker])
    kind = jnp.argmin(times)
    t_next = times[kind]
    t_next = jnp.where(jnp.isfinite(t_next), t_next, state.t)

    # Advance every running job analytically over [t, t_next).
    dt = jnp.maximum(t_next - state.t, 0.0)
    new_remaining = jnp.maximum(g.remaining - rate * dt, 0.0)
    g = replace(g, remaining=new_remaining)
    state = replace(state, g=g, t=t_next)

    who = jnp.stack([i_complete, i_return, i_arrive, -1])[kind]

    def on_complete(s):
        return _apply_completion(s, fleet, i_complete, t_next,
                                 n_resources, max_pe)

    def on_return(s):
        return _apply_return(s, fleet, params, i_return, t_next)

    def on_arrive(s):
        return _apply_arrival(s, fleet, i_arrive, t_next,
                              n_resources, max_pe)

    def on_broker(s):
        return broker_mod.broker_event(s, fleet, params, n_users)

    state = jax.lax.switch(kind, [on_complete, on_return, on_arrive,
                                  on_broker], state)

    # Record broker termination instants.
    _, finished = _user_flags(state, params, fleet, n_users)
    term = jnp.where(finished & ~jnp.isfinite(state.term_time),
                     t_next, state.term_time)

    k = jnp.minimum(state.n_events, TRACE_LEN - 1)
    state = replace(
        state,
        term_time=term,
        n_events=state.n_events + 1,
        trace_t=state.trace_t.at[k].set(t_next),
        trace_kind=state.trace_kind.at[k].set(kind),
        trace_who=state.trace_who.at[k].set(who),
    )
    return state


def _continue(state, fleet, params, n_users, max_events):
    _, finished = _user_flags(state, params, fleet, n_users)
    return (~finished.all()) & (state.n_events < max_events)


def init_state(gridlets, fleet, n_users: int,
               first_sched: float = 0.0) -> SimState:
    n = gridlets.n
    return SimState(
        t=jnp.asarray(0.0, jnp.float32),
        g=gridlets,
        pe=jnp.full((n,), -1, jnp.int32),
        spent=jnp.zeros((n_users,), jnp.float32),
        done_on=jnp.zeros((n_users, fleet.r), jnp.float32),
        first_dispatch=jnp.full((n_users, fleet.r), INF, jnp.float32),
        next_sched=jnp.asarray(first_sched, jnp.float32),
        term_time=jnp.full((n_users,), INF, jnp.float32),
        n_events=jnp.asarray(0, jnp.int32),
        trace_t=jnp.full((TRACE_LEN,), INF, jnp.float32),
        trace_kind=jnp.full((TRACE_LEN,), -1, jnp.int32),
        trace_who=jnp.full((TRACE_LEN,), -1, jnp.int32),
    )


@functools.partial(jax.jit,
                   static_argnames=("n_users", "max_events", "max_pe"))
def _run_jit(gridlets, fleet, params, n_users, max_events, max_pe):
    state = init_state(gridlets, fleet, n_users)
    state = jax.lax.while_loop(
        lambda s: _continue(s, fleet, params, n_users, max_events),
        lambda s: step(s, fleet, params, n_users, max_pe),
        state)
    # Users that never started (e.g. zero budget) terminate at final t.
    term = jnp.where(jnp.isfinite(state.term_time), state.term_time, state.t)
    return SimResult(gridlets=state.g, spent=state.spent, term_time=term,
                     n_events=state.n_events,
                     trace=(state.trace_t, state.trace_kind, state.trace_who))


def run(gridlets, fleet, params: SimParams, n_users: int,
        max_events: int) -> SimResult:
    """Run a full experiment: broker-driven scheduling + execution."""
    return _run_jit(gridlets, fleet, params, n_users, max_events,
                    fleet.max_pe)


def run_inner(gridlets, fleet, params: SimParams, n_users: int,
              max_events: int, max_pe: int) -> SimResult:
    """Trace-safe variant for use under vmap/jit: max_pe passed statically."""
    state = init_state(gridlets, fleet, n_users)
    state = jax.lax.while_loop(
        lambda s: _continue(s, fleet, params, n_users, max_events),
        lambda s: step(s, fleet, params, n_users, max_pe),
        state)
    term = jnp.where(jnp.isfinite(state.term_time), state.term_time, state.t)
    return SimResult(gridlets=state.g, spent=state.spent, term_time=term,
                     n_events=state.n_events,
                     trace=(state.trace_t, state.trace_kind, state.trace_who))


def run_direct(gridlets, fleet, resource_idx, dispatch_time,
               max_events: int) -> SimResult:
    """Broker-less mode: Gridlets are pre-routed to ``resource_idx`` and
    enter the network at ``dispatch_time`` -- the paper's Table 1 / Figs 9
    and 12 scenario (arrivals straight into one resource).
    """
    from .types import replace
    n = gridlets.n
    r = jnp.broadcast_to(jnp.asarray(resource_idx, jnp.int32), (n,))
    t0 = jnp.broadcast_to(jnp.asarray(dispatch_time, jnp.float32), (n,))
    delay = network.transfer_delay(gridlets.in_bytes, fleet.baud_rate[r])
    g = replace(gridlets,
                status=jnp.full((n,), IN_TRANSIT, jnp.int32),
                resource=r, assigned=r, t_event=t0 + delay)
    params = default_params(jnp.asarray(-1.0), jnp.asarray(0.0),
                            jnp.asarray(0), 1, fleet.r)  # brokers inert
    return _run_jit(g, fleet, params, 1, max_events, fleet.max_pe)
