"""The vectorised discrete-event engine (paper sections 3.4-3.5),
refactored around a **resource-major superstep loop** over pluggable
:class:`repro.core.des.EventSource`'s.

State layout (shape/dtype conventions)
--------------------------------------
Gridlet state stays in the flat struct-of-arrays ``GridletBatch`` (the
broker's natural layout; every per-gridlet array is ``[N]``), but every
*executing* Gridlet additionally occupies one column of a resource-major
``[R_pad, J]`` i32 job-slot table (``R_pad`` = resources padded to the
kernel block, ``J`` = job slots per resource):

  ``SimState.slot[i]``          -- i32[N] column of Gridlet ``i`` (-1 = none),
  ``SimState.row_gridlet[r,j]`` -- i32[R_pad, J] inverse map: flat Gridlet
                                   index (-1 = free).

Slots are allocated on admission (RUNNING) and freed on completion or
resource failure, so the table always holds exactly the running set.
Each while-loop iteration -- one **superstep** -- gathers ``remaining``
into the table and evaluates the Fig 8 PE-share + forecast math in a
single call to ``kernels.ops.event_scan`` (compiled Pallas on TPU,
vectorised XLA fallback on CPU hosts); the kernel also emits the per-row
earliest completion (argmin) and PE occupancy so no second pass over the
state is needed.  Reservation-held PEs enter the kernel as its
``pe_blocked`` [R] input and failed resources as its ``row_ok`` mask.

Per-resource failure/reservation state (all ``[R]``): ``res_up`` bool,
``next_fail``/``next_recover``/``fail_since``/``downtime`` f32; per-user
accounting (``spent``, ``done_on``, ...) is ``[U]`` / ``[U, R]`` f32.

Superstep semantics
-------------------
The paper's engine (section 3.4) pops one timestamp-ordered event per
iteration.  A superstep instead asks every registered event source (the
``des.EventSource`` protocol: ``candidates(state)`` / ``apply(state,
now)``) for its pending instants -- one fused
``kernels.ops.event_frontier`` min/mask pass over the concatenated
candidate vectors yields the earliest instant t*, the per-source fired
masks, and (for the batched path) the speculation horizon:

  COMPLETION    -- forecast finish of the smallest-remaining-share job
                   (paper Fig 7 step 2d / Fig 10: internal events),
  FAILURE       -- a resource goes down (per-resource MTBF stream),
  RECOVERY      -- a failed resource comes back up (MTTR stream),
  RESERVATION   -- an advance-reservation window opens or closes,
  NETWORK       -- a fair-share link transfer drains its last byte (or
                   a pre-routed transfer enters its link),
  RETURN        -- processed Gridlet reaches its broker (GRIDLET_RETURN),
  ARRIVAL       -- dispatched Gridlet reaches its resource (GRIDLET_SUBMIT),
  CALENDAR_STEP -- a local-load calendar boundary (weekend edge),
  BROKER        -- periodic scheduling event of the economic broker,

advances all resident jobs analytically by the PE-share algebra of Fig 8
over ``[t, t*)`` (and, with the network subsystem on, all in-flight
transfers by their fair link shares), then applies **every** source due
at the earliest pending ``t*`` in one vectorised batch per kind, in the
fixed tie-break priority order

  COMPLETION > FAILURE > RECOVERY > RESERVATION > NETWORK > RETURN
             > ARRIVAL > CALENDAR_STEP > BROKER.

Within a kind, ties are FIFO by flat Gridlet index -- exactly the order
the one-event-at-a-time loop would have produced, so the Table 1 /
Fig 9 / Fig 12 traces are reproduced bit-for-bit.  Application order
inside the superstep differs from the priority order in exactly one
place: BROKER is *applied* before ARRIVAL so that two event chains the
paper engine spreads over extra zero-dt iterations fold into the same
superstep -- a zero-delay RETURN of a Gridlet that completed at ``t*``,
and the zero-delay ARRIVAL of a Gridlet the broker dispatched at ``t*``
(arrival application commutes with the broker event; pre-broker arrivals
keep admission precedence via the ``arr_pre`` mask, preserving the
ARRIVAL > BROKER tie-break).  Forecasts are recomputed from state every
superstep, so the paper's stale-internal-event discard rule (section
3.4) holds by construction: a superseded forecast simply never
materialises.  Sources with nothing pending report +inf and apply as
the identity, so scenarios that leave a source unused (zero failure
rate, empty reservation table, zero weekend load) are bit-for-bit
identical to runs without it.

Failure semantics: when a resource fails, its RUNNING and QUEUED
Gridlets move to ``types.FAILED``, their job slots are freed and their
committed cost is refunded (no double billing); Gridlets IN_TRANSIT to a
down resource fail-and-refund on arrival.  The broker re-plans FAILED
Gridlets exactly like CREATED ones (see broker._assign), re-billing only
on the new dispatch; ``SimState.n_resubmits`` counts those re-dispatches
and ``downtime`` accumulates per-resource down intervals.

Time-shared share allocation (Fig 8): with g jobs on P PEs,
  min_jobs = g // P PEs' worth of jobs run at MaxShare = eff_mips/min_jobs,
  the rest at MinShare = eff_mips/(min_jobs+1); jobs are laid onto PEs so
  the smallest-remaining jobs receive MaxShare -- this is the unique layout
  consistent with the worked trace of Fig 9 / Table 1 (G3 joins G2's PE at
  t=7, G1 keeps a whole PE and finishes at 10).  Reservation windows
  shrink P to the unreserved PE count.

Space-shared (Figs 10-12): dedicated PE per job, FCFS (or SJF) queue;
PE identity never affects the trace (all PEs of a resource are equal
rated), so only the per-resource occupancy count is tracked.
Reservations gate admission (never preempt residents).

Fair-share links (the network subsystem): the static ``net_cap`` knob
sizes a ``[R_pad, T]`` transfer-slot table (``SimState.xslot`` /
``link_gridlet`` / ``link_rem``) holding the remaining bytes of every
in-flight staging and result return whose payload can contend
(``network.link_tabled``); all concurrent transfers on a resource link
split ``params.link_baud`` equally (plus ``params.bg_flows`` phantom
background flows), forecasts run through ``kernels.ops.link_scan``
exactly like completion forecasts run through ``event_scan``, and the
NETWORK source releases a drained transfer's ARRIVAL/RETURN instant
into the same superstep.  ``net_cap = 0`` (default) disables the table
and keeps the analytic ``bytes / baud`` timestamps untouched;
zero-byte payloads and infinite links never table, so zero-contention
configurations are bit-for-bit identical to the analytic path (see
docs/ARCHITECTURE.md "The network layer").

Speculative k-step batching
---------------------------
One while-loop iteration can commit more than one superstep: after the
committing superstep, :func:`step_batched` derives a **speculation
horizon** ``t_safe`` -- the min over every registered source's
``horizon(state, t_max)`` hook (des.EventSource) -- and applies up to
``k - 1`` further COMPLETION/RETURN supersteps whose instants lie
*strictly* below it.  COMPLETION and RETURN are speculation-safe
(applying them never pulls another source's pending instant earlier);
FAILURE, RECOVERY, RESERVATION, ARRIVAL, CALENDAR_STEP and BROKER all
cut the horizon at their own next instant, so the first timestamp where
any of them could intervene ends the slab and the next committing
superstep handles it with the full priority/tie-break machinery.  Each
speculative superstep is the exact COMPLETION/RETURN slice of the
general superstep, so results, traces and counters are bit-for-bit
identical for every ``batch`` value -- only the iteration count (and
the per-iteration dispatch constant) changes.  Dense-interference
scenarios (failures every superstep) degrade gracefully: every
micro-step declines and the loop behaves like ``batch=1``.  See
docs/PERFORMANCE.md for the safety argument and measurements.

Slab-fed scans (the sort-free hot path)
---------------------------------------
The while-loop carry holds a **slab**: the last scan's (remaining, tie)
rank table plus the FCFS/SJF queue ranking, each with a validity flag.
Completions depart in rank order (a per-row prefix), so both rankings
survive ordinary supersteps by a per-row subtraction; the next scan --
committing or speculative -- injects the carried rank into the
identical Fig 8 arithmetic (``event_scan_xla(rank=...)``) and runs
with zero sorts.  Validity is *checked* each scan
(:func:`_partition_ok`: the rank's only consumer is the
MaxShare/MinShare divisor split, so boundary agreement with the value
order is sufficient) and the carry is dropped whenever the table
restructures where ranks matter (admissions/arrivals onto time-shared
rows, failures, recoveries, reservation boundaries; new queue members
for the queue half) -- one exact lexsort then reseeds it.
``SimState.n_reseeds`` counts those reseeds; completion-dominated runs
stay >90% sort-free, and the count is identical for every ``batch``
value.  See docs/PERFORMANCE.md.

``SimState.n_events`` counts applied events, ``n_steps`` counts
while-loop iterations (committing supersteps), ``n_spec`` counts the
speculative supersteps the batched path folded into them; ``overflow``
counts job-slot and transfer-slot allocation failures and must stay 0
(drivers size ``J`` / ``net_cap`` accordingly).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import broker as broker_mod
from . import calendar, des, network, rand
from . import economy as econ_mod
from . import reservation as resv_mod
from . import telemetry as telemetry_mod
from ..kernels import event_scan as _event_kernels
from ..kernels import ops as kernel_ops
from ..kernels.event_scan import BIG as _BIG  # empty-slot sentinel
from .segments import group_rank
from .types import (CREATED, DONE, FAILED, FCFS, IN_TRANSIT, INF, QUEUED,
                    RETURNING, RUNNING, SJF, SPACE_SHARED, TIME_SHARED,
                    pytree_dataclass)

TRACE_LEN = 64
BLOCK_R = 8          # event_scan row blocking; resource axis padded to it
DEFAULT_BATCH = 8    # superstep batching factor k (see step_batched)


@pytree_dataclass
class SimParams:
    """Per-experiment knobs; all traced so grids of experiments vmap."""
    deadline: jax.Array        # f32[U]
    budget: jax.Array          # f32[U]
    opt: jax.Array             # i32[U] broker optimisation strategy
    max_gridlet_per_pe: jax.Array  # i32[] dispatch staging limit (paper: 2)
    sched_min_period: jax.Array    # f32[] broker poll floor (paper: 1.0)
    sched_frac: jax.Array          # f32[] fraction of deadline-left (0.01)
    measure_alpha: jax.Array       # f32[] measurement smoothing
    registered: jax.Array          # bool[R] GIS availability mask
    mtbf: jax.Array            # f32[R] mean time between failures (0 = off)
    mttr: jax.Array            # f32[R] mean time to recovery
    fail_key: jax.Array        # PRNG key seeding the MTBF/MTTR streams
    resv_res: jax.Array        # i32[K] reservation -> resource
    resv_pes: jax.Array        # i32[K] PEs held
    resv_start: jax.Array      # f32[K] window start (inclusive)
    resv_end: jax.Array        # f32[K] window end (exclusive)
    link_baud: jax.Array       # f32[R] fair-share link capacity (net
                               #     mode; inf = uncontended link)
    bg_flows: jax.Array        # f32[R] phantom background flows riding
                               #     each link (net mode; may be
                               #     fractional)
    pricing_model: jax.Array   # i32[] economy.PRICE_* (0 = static --
                               #     both pricing sources inert)
    market_period: jax.Array   # f32[] commodity repricing period
    market_gain: jax.Array     # f32[] posted-price adjustment rate per
                               #     unit of excess demand
    price_floor: jax.Array     # f32[] posted-price clamp, x base price
    price_cap: jax.Array       # f32[] posted-price clamp, x base price
    auction_period: jax.Array  # f32[] sealed-bid auction round period
    auction_key: jax.Array     # PRNG key seeding the bid draws
    plan_ahead: jax.Array      # bool[] plan-ahead DBC dispatch: price
                               #     reservation windows + link queueing
                               #     into the capacity estimates and run
                               #     the exact cost-time grouping
                               #     (cs/0203020) -- see broker._measure
    # --- shared-trunk topology (None = private links only; a None
    #     field is an empty pytree subtree, so `is None` is a STATIC
    #     gate -- the default compiles the exact pre-trunk program) ---
    trunk_of: object           # i32[R] trunk id per resource (-1 =
                               #     private-only) or None
    trunk_baud: object         # f32[R] trunk capacity gathered out to
                               #     per-resource form, or None
    trunk_bg: object           # f32[R] trunk phantom background flows
                               #     (per-resource form), or None
    # --- trace-driven fault injection (None = no trace; same static
    #     None gate as the trunk fields) ---
    fault_time: object         # f32[K] scheduled instants, ascending
    fault_target: object       # i32[K] 0..R-1 = resource; R + id =
                               #     trunk id (every incident resource
                               #     flips in one apply)
    fault_up: object           # bool[K] True = bring up, False = cut
    # --- fault-tolerant broker knobs (always-present traced scalars;
    #     the defaults are vacuous, bitwise-frozen legacy behaviour) ---
    retry_limit: jax.Array     # i32[] max refund+resubmit cycles per
                               #     gridlet (default 2**30 = unbounded)
    backoff_base: jax.Array    # f32[] exponential backoff unit: the
                               #     n-th retry re-dispatches no earlier
                               #     than fail_t + base * 2**(n-1)
                               #     (default 0.0 = immediate)
    blacklist_cooldown: jax.Array  # f32[] broker _measure ignores
                               #     resources that recovered less than
                               #     this long ago (default 0.0 = off)


def default_params(deadline, budget, opt, n_users: int,
                   n_resources: int = 1, registered=None, mtbf=None,
                   mttr=None, reservations=None,
                   fail_key=None, link_baud=None,
                   bg_flows=None, pricing_model=econ_mod.PRICE_STATIC,
                   market_period=None, market_gain=None,
                   price_floor=None, price_cap=None,
                   auction_period=None, auction_key=None,
                   plan_ahead=False, trunk_of=None, trunk_baud=None,
                   trunk_bg=None, fault_trace=None, retry_limit=None,
                   backoff_base=None,
                   blacklist_cooldown=None) -> SimParams:
    """``mtbf``/``mttr`` broadcast to [R]; 0 disables the failure source.
    ``reservations`` is a ReservationBook, an iterable of (resource,
    pes, start, end) tuples, or the 4-array table itself.
    ``link_baud``/``bg_flows`` feed the fair-share network subsystem
    (only consulted when the engine runs with ``net_cap > 0``); the
    default infinite ``link_baud`` makes every link uncontended --
    callers that enable the subsystem pass ``fleet.baud_rate`` (or a
    scenario override) here.  ``pricing_model`` selects the dynamic
    pricing source (economy.PRICE_*; the default keeps fleet prices
    static and both pricing sources inert, bit-identical to the
    pre-economy engine); the remaining knobs default to the thesis-ish
    settings (reprice/auction every 10 time units, +-25% adjustment,
    posted prices clamped to [0.5, 2.0] x base).

    ``trunk_of`` (per-resource trunk id, -1 = private) enables the
    shared-trunk topology: ``trunk_baud``/``trunk_bg`` are per-TRUNK
    vectors (or scalars), gathered out to per-resource form via
    network.trunk_topology.  ``fault_trace`` enables trace-driven
    fault injection: an iterable of (time, target, up) rows or the
    [K, 3] array itself, where target 0..R-1 names a resource and
    R + id names a trunk (the whole failure domain flips at once);
    rows are time-sorted here so the engine's cursor replay is order-
    independent.  ``retry_limit``/``backoff_base``/
    ``blacklist_cooldown`` are the fault-tolerant broker knobs; the
    defaults freeze legacy behaviour bitwise (unbounded immediate
    retries, no blacklist)."""
    f = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n_users,))
    r = lambda x: jnp.broadcast_to(jnp.asarray(
        0.0 if x is None else x, jnp.float32), (n_resources,))
    if registered is None:
        registered = jnp.ones((n_resources,), bool)
    if reservations is None:
        resv = resv_mod.empty_tables()
    elif hasattr(reservations, "as_tables"):
        resv = reservations.as_tables()
    elif (isinstance(reservations, tuple) and len(reservations) == 4
          and all(hasattr(x, "dtype") for x in reservations)):
        resv = reservations
    else:
        resv = resv_mod.as_tables(reservations)
    if trunk_of is None:
        t_of = t_baud = t_bg = None
    else:
        t_of, t_baud, t_bg = network.trunk_topology(
            trunk_of, n_resources, trunk_baud=trunk_baud,
            trunk_bg=trunk_bg)
    if fault_trace is None:
        ft = ftgt = fup = None
    else:
        tr = jnp.asarray(
            [(float(a), int(b), bool(c)) for a, b, c in fault_trace]
            if not hasattr(fault_trace, "dtype") else fault_trace,
            jnp.float32).reshape(-1, 3)
        order = jnp.argsort(tr[:, 0], stable=True)
        tr = tr[order]
        ft = tr[:, 0]
        ftgt = tr[:, 1].astype(jnp.int32)
        fup = tr[:, 2] > 0.5
    return SimParams(
        deadline=f(deadline), budget=f(budget),
        opt=jnp.broadcast_to(jnp.asarray(opt, jnp.int32), (n_users,)),
        max_gridlet_per_pe=jnp.asarray(2, jnp.int32),
        sched_min_period=jnp.asarray(1.0, jnp.float32),
        sched_frac=jnp.asarray(0.01, jnp.float32),
        measure_alpha=jnp.asarray(0.5, jnp.float32),
        registered=registered,
        mtbf=r(mtbf), mttr=r(mttr),
        fail_key=(jax.random.PRNGKey(0) if fail_key is None else fail_key),
        resv_res=resv[0], resv_pes=resv[1],
        resv_start=resv[2], resv_end=resv[3],
        link_baud=jnp.broadcast_to(
            jnp.asarray(INF if link_baud is None else link_baud,
                        jnp.float32), (n_resources,)),
        bg_flows=r(bg_flows),
        pricing_model=jnp.asarray(pricing_model, jnp.int32),
        market_period=jnp.asarray(
            10.0 if market_period is None else market_period, jnp.float32),
        market_gain=jnp.asarray(
            0.25 if market_gain is None else market_gain, jnp.float32),
        price_floor=jnp.asarray(
            0.5 if price_floor is None else price_floor, jnp.float32),
        price_cap=jnp.asarray(
            2.0 if price_cap is None else price_cap, jnp.float32),
        auction_period=jnp.asarray(
            10.0 if auction_period is None else auction_period,
            jnp.float32),
        auction_key=(jax.random.PRNGKey(0) if auction_key is None
                     else auction_key),
        plan_ahead=jnp.asarray(plan_ahead, bool),
        trunk_of=t_of, trunk_baud=t_baud, trunk_bg=t_bg,
        fault_time=ft, fault_target=ftgt, fault_up=fup,
        retry_limit=jnp.asarray(
            2**30 if retry_limit is None else retry_limit, jnp.int32),
        backoff_base=jnp.asarray(
            0.0 if backoff_base is None else backoff_base, jnp.float32),
        blacklist_cooldown=jnp.asarray(
            0.0 if blacklist_cooldown is None else blacklist_cooldown,
            jnp.float32),
    )


@pytree_dataclass
class SimState:
    t: jax.Array               # f32 current simulation time
    g: object                  # GridletBatch
    slot: jax.Array            # i32[N] job-slot column (-1 = none)
    row_gridlet: jax.Array     # i32[R_pad, J] slot -> gridlet (-1 = free)
    xslot: jax.Array           # i32[N] transfer-slot column (-1 = none;
                               #     net mode only, see link_gridlet)
    link_gridlet: jax.Array    # i32[R_pad, T] transfer slot -> gridlet
                               #     (-1 = free); T = 0 disables the
                               #     fair-share network subsystem
    link_rem: jax.Array        # f32[R_pad, T] bytes still to move per
                               #     in-flight transfer
    spent: jax.Array           # f32[U] committed budget
    done_on: jax.Array         # f32[U,R] jobs of u completed on r
    first_dispatch: jax.Array  # f32[U,R] first dispatch instant (inf)
    next_sched: jax.Array      # f32 next broker event
    term_time: jax.Array       # f32[U] broker termination instant
    res_up: jax.Array          # bool[R] resource currently up
    next_fail: jax.Array       # f32[R] scheduled failure instant (inf = none)
    next_recover: jax.Array    # f32[R] scheduled recovery instant
    fail_since: jax.Array      # f32[R] instant the resource went down
    downtime: jax.Array        # f32[R] accumulated down intervals
    recovered_at: jax.Array    # f32[R] instant of the last recovery
                               #     (-inf = never; feeds the broker's
                               #     cooldown blacklist)
    trace_ptr: jax.Array       # i32 cursor into the fault-injection
                               #     trace (rows < ptr already applied)
    rng_key: jax.Array         # PRNG key for the MTBF/MTTR streams
    price: jax.Array           # f32[R] posted G$/MI trading metric
                               #     (== fleet.cost_per_mi() until a
                               #     pricing round moves it; per-MI so
                               #     the broker never divides a carried
                               #     array by an invariant in-loop --
                               #     XLA may compile that division
                               #     differently per path, breaking the
                               #     bitwise cross-path contract)
    next_market: jax.Array     # f32 next commodity repricing instant
                               #     (inf = market source off)
    next_auction: jax.Array    # f32 next auction round instant (inf =
                               #     auction source off)
    auction_key: jax.Array     # PRNG key for sealed-bid draws (one
                               #     split consumed per fired round)
    n_events: jax.Array        # i32 applied events (batched kinds summed)
    n_steps: jax.Array         # i32 while-loop iterations (committing
                               #     supersteps; speculative ones excluded)
    n_spec: jax.Array          # i32 speculative supersteps applied by the
                               #     k-step batched path
    n_reseeds: jax.Array       # i32 scans that re-sorted the job-slot
                               #     table (slab carry misses)
    n_scans: jax.Array         # i32 Fig 8 scans performed (committing +
                               #     speculative, incl. declined micro-
                               #     steps) -- the reseed denominator
    n_trace: jax.Array         # i32 trace entries written
    n_failed: jax.Array        # i32 gridlets hit by a failure
    n_resubmits: jax.Array     # i32 FAILED gridlets re-dispatched
    overflow: jax.Array        # i32 job-slot / transfer-slot
                               #     allocation failures (== 0)
    trace_t: jax.Array         # f32[TRACE_LEN]
    trace_kind: jax.Array      # i32[TRACE_LEN] des.K_* codes
    trace_who: jax.Array       # i32[TRACE_LEN]


class SimResult(NamedTuple):
    gridlets: object
    spent: jax.Array
    term_time: jax.Array
    n_events: jax.Array
    trace: tuple
    n_steps: jax.Array
    overflow: jax.Array
    n_failed: jax.Array
    n_resubmits: jax.Array
    downtime: jax.Array
    n_spec: jax.Array
    n_reseeds: jax.Array
    n_scans: jax.Array
    # The metrics ring (core/telemetry.py) when the run recorded one,
    # else None.  Observability only: every "what" comparison across
    # engine paths / telemetry on-off excludes it (like the "how"
    # counters, it may pack supersteps differently per path).
    telemetry: object = None


# ----------------------------------------------------------------------
# Resource dynamics
# ----------------------------------------------------------------------

def _rates(state, fleet, n_resources):
    """Per-gridlet execution rate (MI per time unit) under Fig 8 shares.

    Flat-layout XLA reference path, kept as the oracle the kernel path
    must agree with (asserted in tests); the superstep loop itself goes
    through kernels.ops.event_scan on the resource-major table.
    """
    g = state.g
    running = g.status == RUNNING
    res = jnp.clip(g.resource, 0, n_resources - 1)
    eff = calendar.effective_mips(fleet, state.t)          # [R] per PE
    policy = fleet.policy[res]

    # --- time-shared: rank jobs on each resource by remaining MI ---
    ts_member = running & (policy == TIME_SHARED)
    rank, counts = group_rank(res, ts_member, g.remaining, n_resources)
    g_on_r = counts[res].astype(jnp.int32)                  # jobs on my res
    p_r = fleet.num_pe[res]
    min_jobs = g_on_r // jnp.maximum(p_r, 1)
    extra = g_on_r % jnp.maximum(p_r, 1)
    max_share_count = (p_r - extra) * min_jobs
    divisor = min_jobs + (rank >= max_share_count).astype(jnp.int32)
    ts_rate = eff[res] / jnp.maximum(divisor, 1).astype(jnp.float32)

    # --- space-shared: a dedicated PE at full effective rate ---
    ss_rate = eff[res]

    rate = jnp.where(policy == TIME_SHARED, ts_rate, ss_rate)
    return jnp.where(running, rate, 0.0)


def _reserved_pes(params, t, n_resources):
    """PEs blocked by committed reservation windows at ``t``: i32[R]."""
    return resv_mod.active_pes(params.resv_res, params.resv_pes,
                               params.resv_start, params.resv_end, t,
                               n_resources)


def _table_inputs(state, fleet, params, n_resources, r_pad):
    """Gather the [R_pad, J] job-slot table and the per-row kernel
    inputs -- the shared prologue of the committing scan and the
    slab-fed speculative scan (identical arithmetic is what keeps the
    two paths bit-for-bit interchangeable).

    An occupied slot whose remaining underflowed to exactly 0 (f32
    advance rounding) must stay visible to the kernel -- 0 is the
    empty-slot sentinel -- so it is clamped to a tiny epsilon: it then
    forecasts an immediate completion and keeps its PE share, exactly
    as a zero-remaining RUNNING job did in the one-event-at-a-time
    engine.
    """
    g = state.g
    rg = state.row_gridlet
    occupied = rg >= 0
    gid = jnp.clip(rg, 0, g.n - 1)
    rem_rj = jnp.where(occupied,
                       jnp.maximum(g.remaining[gid], 1e-30), 0.0)
    tie_rj = jnp.where(occupied, rg, 2 ** 30).astype(jnp.float32)
    pad = r_pad - n_resources
    eff = jnp.pad(calendar.effective_mips(fleet, state.t), (0, pad),
                  constant_values=1.0)
    npe = jnp.pad(fleet.num_pe, (0, pad), constant_values=1)
    pol = jnp.pad(fleet.policy, (0, pad))
    blocked = jnp.pad(
        _reserved_pes(params, state.t, n_resources).astype(jnp.float32),
        (0, pad))
    row_ok = jnp.pad(state.res_up, (0, pad),
                     constant_values=True).astype(jnp.float32)
    return rem_rj, tie_rj, eff, npe, pol, blocked, row_ok


def _scan_events(state, fleet, params, n_resources, r_pad, rank=None):
    """Resource-major Fig 8 scan through kernels.ops.event_scan.

    Gathers ``remaining`` into the [R_pad, J] job-slot table (flat
    gridlet index as the FIFO tie-break key) and returns the kernel
    outputs (rate [R_pad, J], t_min [R_pad], argmin col [R_pad],
    occupancy [R_pad], rank [R_pad, J]).  Reservation-held PEs and down
    resources enter as the kernel's ``pe_blocked`` / ``row_ok`` masks.
    ``rank`` injects a precomputed rank table (the slab-fed speculative
    path), making the scan entirely sort-free.
    """
    rem_rj, tie_rj, eff, npe, pol, blocked, row_ok = _table_inputs(
        state, fleet, params, n_resources, r_pad)
    return kernel_ops.event_scan(rem_rj, eff, npe, tie=tie_rj, policy=pol,
                                 pe_blocked=blocked, row_ok=row_ok,
                                 rank=rank, with_rank=True)


# ----------------------------------------------------------------------
# Fair-share link dynamics (the network subsystem)
# ----------------------------------------------------------------------
#
# The engine's static ``net_cap`` knob sizes the [R_pad, T] transfer-
# slot table (T = net_cap transfer slots per resource link; 0 disables
# the subsystem entirely -- the table is then [R_pad, 0] and every
# branch below is statically skipped, so the analytic path is untouched
# code, not a runtime no-op).  With the subsystem on, a transfer whose
# payload can actually contend (network.link_tabled: positive bytes
# over a finite-positive link) occupies one column of the table with
# its ``remaining_bytes``; all concurrent transfers on a link share its
# baud rate equally (kernels.ops.link_scan), remainders advance
# piecewise-constantly between events exactly like remaining MI under
# Fig 8 shares, and the NETWORK event source fires when a transfer
# drains -- releasing the gridlet's ARRIVAL/RETURN instant to "now" so
# the release folds into the same superstep.  Zero-byte payloads and
# infinite links never enter the table and keep the analytic
# (instantaneous) timestamps, which is what keeps zero-contention
# configurations bit-for-bit identical to the analytic engine.

def _net_on(state) -> bool:
    """Static: the fair-share network subsystem is enabled (T > 0)."""
    return state.link_rem.shape[1] > 0


def _residents_r(state, n_resources):
    """bool[R]: the resource hosts *resident* work -- RUNNING or QUEUED
    gridlets a failure/recovery strike would actually interfere with.
    Used by the speculation horizon: the resident set of a resource can
    only shrink inside a slab (queue admissions draw from already-
    resident QUEUED jobs; arrivals and broker dispatches cut the
    horizon), so a strike gated off here stays non-interfering for the
    whole slab and is fired by the speculative micro-steps instead."""
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    resident = (g.status == RUNNING) | (g.status == QUEUED)
    return jax.ops.segment_sum(resident.astype(jnp.int32), res,
                               num_segments=n_resources) > 0


def _xfer_bytes(g):
    """Payload of each gridlet's pending/possible transfer: input files
    while staging (IN_TRANSIT), result files on the way back."""
    return jnp.where(g.status == IN_TRANSIT, g.in_bytes, g.out_bytes)


def _link_scan(state, params, n_resources, r_pad):
    """Fair-share rates + next-transfer-completion forecast per link,
    through kernels.ops.link_scan (Pallas on TPU, XLA fallback on CPU).
    The flat gridlet index is the argmin tie-break key, mirroring the
    job-slot table's FIFO convention.

    With a shared-trunk topology (params.trunk_of, a static None gate)
    each row additionally receives a per-row fair-share rate *cap*:
    the trunk's capacity divided by its total occupancy across every
    incident row.  The cross-row occupancy gather runs here -- plain
    jnp over the [R_pad, T] table -- because the row-blocked kernel
    grid cannot see other rows; the kernel then just min()s the cap in
    (kernels.event_scan._link_math).  network.fastest_drain stays a
    valid speculation lower bound: a trunk can only *lower* rates, so
    no tabled drain ever finishes earlier than the private-link bound.
    """
    pad = r_pad - n_resources
    baud = jnp.pad(params.link_baud, (0, pad), constant_values=1.0)
    bg = jnp.pad(params.bg_flows, (0, pad))
    tie = jnp.where(state.link_gridlet >= 0, state.link_gridlet,
                    2 ** 30).astype(jnp.float32)
    cap = None
    if params.trunk_of is not None:
        # live-row occupancy, computed exactly like _link_math's m
        live = (baud > 0.0) & (baud < network.BIG)
        valid = ((state.link_rem > 0.0) & (state.link_rem < network.BIG)
                 & live[:, None])
        occ = jnp.sum(valid.astype(jnp.float32), axis=1)
        cap = network.trunk_rate_cap(
            occ,
            jnp.pad(params.trunk_of, (0, pad), constant_values=-1),
            jnp.pad(params.trunk_baud, (0, pad), constant_values=1.0),
            jnp.pad(params.trunk_bg, (0, pad)))
    return kernel_ops.link_scan(state.link_rem, baud, bg=bg, tie=tie,
                                cap=cap)


def _pending_entries(state, params, n_resources):
    """Transfers created with a *future* network-entry instant (pre-
    routed ``run_direct`` dispatches): tabled payloads holding their
    entry time in ``t_event`` while awaiting a transfer slot.  The
    NETWORK source enqueues them exactly at that instant."""
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    moving = (g.status == IN_TRANSIT) | (g.status == RETURNING)
    return (moving & (state.xslot < 0) & jnp.isfinite(g.t_event) &
            network.link_tabled(_xfer_bytes(g), params.link_baud[res]))


def _advance_transfers(state, ctx, t_next, any_event, gate=None):
    """Advance every in-flight transfer analytically over [t, t_next)
    by the fair-share rates in ``ctx["net_scan"]`` (the link twin of
    :func:`_advance_jobs`; must run while ``state.t`` still holds the
    interval start).  Transfers forecast to drain by ``t_next`` are
    zeroed and recorded in ``ctx["xfer_done"]`` for the NETWORK apply;
    survivors are clamped to a tiny epsilon so f32 rounding can never
    turn an occupied slot into the empty-slot sentinel.  ``gate`` (the
    sweep engine's masked micro-supersteps) makes the advance a bitwise
    no-op when False even for occupied slots whose remainder sits at
    the epsilon clamp."""
    from .types import replace
    rate_lt = ctx["net_scan"][0]
    occupied = state.link_gridlet >= 0
    rem = state.link_rem
    rel = jnp.where(occupied, rem / jnp.maximum(rate_lt, 1e-30), INF)
    dt = jnp.maximum(t_next - state.t, 0.0)
    due = occupied & any_event & (state.t + rel <= t_next)
    adv = occupied if gate is None else occupied & gate
    new_rem = jnp.where(
        due, 0.0,
        jnp.where(adv, jnp.maximum(rem - rate_lt * dt, 1e-30), rem))
    ctx["xfer_done"] = due
    return replace(state, link_rem=new_rem)


def _enqueue_transfers(state, mask, n_resources, r_pad):
    """Allocate a transfer-slot column on each masked gridlet's
    resource link, load its payload as ``remaining_bytes``, and mark
    the gridlet's pending instant load-dependent (``t_event = inf`` --
    the NETWORK source owns it now).  Same sort-free running-count +
    binary-search allocation as :func:`_alloc_slots`; gridlets that
    find no free column are counted in ``overflow`` (drivers size
    ``net_cap`` so this cannot happen)."""
    from .types import replace
    g = state.g
    n = g.n
    t_cap = state.link_gridlet.shape[1]
    res = jnp.clip(g.resource, 0, n_resources - 1)
    idx = jnp.arange(n, dtype=jnp.int32)
    free = state.link_gridlet < 0
    n_free = jnp.sum(free, axis=1)                        # [R_pad]
    rank = _count_rank(res, mask, n_resources)
    ok = mask & (rank < n_free[res])
    cumfree = jnp.cumsum(free.astype(jnp.int32), axis=1)  # [R_pad, T]
    want = rank + 1
    lo = jnp.zeros((n,), jnp.int32)
    hi = jnp.full((n,), t_cap - 1, jnp.int32)
    for _ in range(max(1, (t_cap - 1).bit_length())):
        mid = (lo + hi) // 2
        ge = cumfree[res, mid] >= want
        lo = jnp.where(ge, lo, mid + 1)
        hi = jnp.where(ge, mid, hi)
    col = hi
    rows = jnp.where(ok, res, r_pad)            # out of range: dropped
    cols = jnp.where(ok, col, 0)
    lg = state.link_gridlet.at[rows, cols].set(idx, mode="drop")
    lr = state.link_rem.at[rows, cols].set(
        jnp.where(ok, _xfer_bytes(g), 0.0), mode="drop")
    g2 = replace(g, t_event=jnp.where(ok, INF, g.t_event))
    return replace(
        state, g=g2, link_gridlet=lg, link_rem=lr,
        xslot=jnp.where(ok, col, state.xslot),
        overflow=state.overflow + jnp.sum(mask & ~ok, dtype=jnp.int32))


def _enqueue_new_transfers(state, params, n_resources, r_pad,
                           select_free=False):
    """End-of-superstep pass: transfers *created this superstep*
    (broker dispatches, completions' result returns) enter their link
    now.  Tabled creation marked them ``t_event == inf`` with no slot,
    so the condition is transient; pending entries (finite ``t_event``)
    wait for the NETWORK source instead.  ``select_free`` (static) runs
    the allocation unconditionally -- it is a bitwise no-op on an empty
    mask (the masked-apply contract), so the sweep engine skips the
    ``cond``."""
    g = state.g
    moving = (g.status == IN_TRANSIT) | (g.status == RETURNING)
    new = moving & (state.xslot < 0) & ~jnp.isfinite(g.t_event)
    if select_free:
        return _enqueue_transfers(state, new, n_resources, r_pad)
    return jax.lax.cond(
        new.any(),
        lambda s: _enqueue_transfers(s, new, n_resources, r_pad),
        lambda s: s, state)


def _free_link_slots(state, mask):
    """Release the transfer slots of every gridlet in ``mask`` (their
    transfer was consumed by an ARRIVAL/RETURN application)."""
    from .types import replace
    r_pad, t_cap = state.link_gridlet.shape
    res = jnp.clip(state.g.resource, 0, r_pad - 1)
    rows = jnp.where(mask, res, r_pad)          # out of range: dropped
    cols = jnp.where(mask, jnp.clip(state.xslot, 0, t_cap - 1), 0)
    lg = state.link_gridlet.at[rows, cols].set(-1, mode="drop")
    lr = state.link_rem.at[rows, cols].set(0.0, mode="drop")
    return replace(state, link_gridlet=lg, link_rem=lr,
                   xslot=jnp.where(mask, -1, state.xslot))


# ----------------------------------------------------------------------
# Batched event application
# ----------------------------------------------------------------------

def _free_slots(state, mask, res, r_pad):
    """Release the job slots of every gridlet in ``mask``."""
    from .types import replace
    j_cap = state.row_gridlet.shape[1]
    rows = jnp.where(mask, res, r_pad)          # out of range: dropped
    cols = jnp.where(mask, jnp.clip(state.slot, 0, j_cap - 1), 0)
    rg = state.row_gridlet.at[rows, cols].set(-1, mode="drop")
    return replace(state, row_gridlet=rg,
                   slot=jnp.where(mask, -1, state.slot))


def _count_rank(res, mask, n_resources):
    """Rank of each masked element among its resource's masked set, in
    flat-index order -- ``group_rank(res, mask, idx, R)`` without the
    sort: when the order key IS the array order, the rank is a running
    segmented count (one [N, R] cumsum; XLA CPU sorts at this size cost
    ~10x more).  Non-members get garbage (callers mask)."""
    onehot = ((res[:, None] ==
               jnp.arange(n_resources, dtype=jnp.int32)[None, :])
              & mask[:, None]).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(excl, res[:, None].astype(jnp.int32),
                               axis=1)[:, 0]


def _alloc_slots(state, mask, res, n_resources, r_pad):
    """Allocate a free job-slot column to every gridlet in ``mask``.

    Within a resource, gridlets take columns in flat-index order (the
    FIFO tie-break also used by the kernel, so column identity never
    matters).  Gridlets that find no free column are counted in
    ``overflow`` -- drivers size J so this cannot happen.

    Sort-free: the per-resource batch rank is a running segmented count
    (:func:`_count_rank`), and the rank-th free column comes from an
    unrolled binary search over the row's running free-column count --
    log2(J) cheap gathers instead of a [R, J] argsort or scatter (both
    ~10x slower on XLA CPU at fleet shapes).
    """
    from .types import replace
    g = state.g
    n = g.n
    j_cap = state.row_gridlet.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)
    used = state.row_gridlet >= 0
    free = ~used
    n_free = jnp.sum(free, axis=1)                        # [R_pad]
    rank = _count_rank(res, mask, n_resources)
    ok = mask & (rank < n_free[res])
    # col = the rank-th free column of the row = the smallest c whose
    # inclusive free count reaches rank + 1 (same column the stable
    # argsort-of-used used to yield).
    cumfree = jnp.cumsum(free.astype(jnp.int32), axis=1)  # [R_pad, J]
    want = rank + 1
    lo = jnp.zeros((n,), jnp.int32)
    hi = jnp.full((n,), j_cap - 1, jnp.int32)
    for _ in range(max(1, (j_cap - 1).bit_length())):
        mid = (lo + hi) // 2
        ge = cumfree[res, mid] >= want
        lo = jnp.where(ge, lo, mid + 1)
        hi = jnp.where(ge, mid, hi)
    col = hi
    rows = jnp.where(ok, res, r_pad)            # out of range: dropped
    cols = jnp.where(ok, col, 0)
    rg = state.row_gridlet.at[rows, cols].set(idx, mode="drop")
    return replace(
        state, row_gridlet=rg,
        slot=jnp.where(ok, col, state.slot),
        overflow=state.overflow + jnp.sum(mask & ~ok, dtype=jnp.int32))


def _apply_completions(state, fleet, params, completes, t_next,
                       n_resources, r_pad):
    """RUNNING -> RETURNING for the whole batch; job slots freed.

    The result-return instant is analytic (``t_next + out_delay``)
    unless the network subsystem is on and the payload contends for its
    link: those transfers are marked load-dependent (``t_event = inf``)
    and enter the transfer-slot table at the end of this superstep
    (:func:`_enqueue_new_transfers`)."""
    from .types import replace
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    if _net_on(state):
        baud = params.link_baud[res]
        tabled = network.link_tabled(g.out_bytes, baud)
        t_ev = jnp.where(
            tabled, INF,
            t_next + network.transfer_delay(g.out_bytes, baud))
    else:
        t_ev = t_next + network.transfer_delay(g.out_bytes,
                                               fleet.baud_rate[res])
    g = replace(
        g,
        status=jnp.where(completes, RETURNING, g.status),
        finish=jnp.where(completes, t_next, g.finish),
        t_event=jnp.where(completes, t_ev, g.t_event),
    )
    return _free_slots(replace(state, g=g), completes, res, r_pad)


def _queue_rank(state, fleet, n_resources):
    """Fresh FCFS/SJF within-resource rank of every QUEUED gridlet --
    the seed of the queue-rank carry (one lexsort; both keys are static
    while a job stays queued, and admissions only ever remove a rank
    prefix, so the carry stays exact until the queue *membership*
    changes)."""
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    queued = g.status == QUEUED
    # FCFS: earliest arrival at the resource (QUEUED jobs keep their
    # arrival instant in t_event); SJF: smallest job. Ties by index.
    qkey = jnp.where(fleet.queue_policy[res] == SJF, g.length_mi,
                     g.t_event)
    return group_rank(res, queued, qkey, n_resources)[0]


def _admit_queued(state, fleet, free_pe, t_next, n_resources, qrank):
    """Freed space-shared PEs admit the next queued Gridlets in FCFS/SJF
    order (Fig 10 step 3) -- the ``qrank`` lowest ranks per resource.
    Returns (state, admitted mask) -- slots are allocated later
    together with the arrival batch.
    """
    from .types import replace
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    queued = g.status == QUEUED
    admitq = queued & (qrank < free_pe[res])
    g = replace(
        g,
        status=jnp.where(admitq, RUNNING, g.status),
        start=jnp.where(admitq, jnp.minimum(g.start, t_next), g.start),
        t_event=jnp.where(admitq, INF, g.t_event),
    )
    return replace(state, g=g), admitq


def _apply_returns(state, fleet, t_next, n_users, n_resources,
                   gate=None):
    """RETURNING & due -> DONE for the whole batch; broker measurement
    update (paper 4.2.1 step 6).  Includes zero-delay returns of jobs
    that completed earlier in this same superstep.  ``gate`` (the sweep
    engine's masked micro-supersteps) forces the due mask empty when
    False, making the application a bitwise no-op regardless of
    ``t_next``.
    """
    from .types import replace
    g = state.g
    ret_due = (g.status == RETURNING) & (g.t_event <= t_next)
    if gate is not None:
        ret_due &= gate
    g = replace(g,
                status=jnp.where(ret_due, DONE, g.status),
                returned=jnp.where(ret_due, t_next, g.returned))
    ur = g.user * n_resources + jnp.clip(g.resource, 0, n_resources - 1)
    done_on = state.done_on + jax.ops.segment_sum(
        ret_due.astype(jnp.float32), ur,
        num_segments=n_users * n_resources).reshape(n_users, n_resources)
    state = replace(state, g=g, done_on=done_on)
    if _net_on(state):    # consumed transfers release their link slots
        state = _free_link_slots(state, ret_due & (state.xslot >= 0))
    return state, ret_due


def _fail_gridlets(state, victims, n_users, now, params):
    """The fail-and-refund invariant, shared by the FAILURE source, the
    trace-injection source and the down-resource arrival path:
    ``victims`` move to FAILED, drop their broker assignment and
    pending event, and their committed cost is refunded (the broker
    re-bills only on the resubmission dispatch).  Each victim's retry
    counter ticks and its earliest re-dispatch instant moves to
    ``now + backoff_base * 2**(n_retries - 1)`` -- the broker's
    ``_retryable`` gate consumes both (at the default knobs the gate is
    vacuous: retry_at == now and the limit is unbounded, bitwise-frozen
    legacy behaviour).  Every write is gated on ``victims``, so the
    body is a bitwise no-op on an empty mask even at garbage ``now``
    (the masked-apply contract)."""
    from .types import replace
    g = state.g
    refund = jax.ops.segment_sum(jnp.where(victims, g.cost, 0.0),
                                 g.user, num_segments=n_users)
    n_retries = g.n_retries + victims.astype(jnp.int32)
    backoff = params.backoff_base * jnp.exp2(jnp.minimum(
        n_retries - 1, 30).astype(jnp.float32))
    g = replace(
        g,
        status=jnp.where(victims, FAILED, g.status),
        assigned=jnp.where(victims, -1, g.assigned),
        t_event=jnp.where(victims, INF, g.t_event),
        cost=jnp.where(victims, 0.0, g.cost),
        n_retries=n_retries,
        retry_at=jnp.where(victims, now + backoff, g.retry_at),
    )
    return replace(
        state, g=g, spent=state.spent - refund,
        n_failed=state.n_failed + jnp.sum(victims, dtype=jnp.int32))


def _apply_arrivals(state, fleet, params, free_pe, arr_pre, t_next,
                    n_users, n_resources, select_free=False):
    """IN_TRANSIT & due -> RUNNING (time-shared / free PE) or QUEUED,
    for the whole batch; arrivals at a *down* resource fail-and-refund.

    All time-shared arrivals commute (every resident job just
    re-shares).  Space-shared arrivals fill the ``free_pe`` PEs left
    after this superstep's queue admissions -- arrivals already due
    before the broker event (``arr_pre``) first, then this superstep's
    zero-delay dispatches, flat-index order within each class: exactly
    the order the one-at-a-time loop (ARRIVAL before BROKER at equal
    time) admits them -- and the rest join the queue stamped with their
    arrival instant (the FCFS key).  Returns (state, arrival mask,
    newly-running mask, newly-queued mask).
    """
    from .types import replace
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    idx = jnp.arange(g.n, dtype=jnp.int32)
    arr_due = (g.status == IN_TRANSIT) & (g.t_event <= t_next)
    arr_fail = arr_due & ~state.res_up[res]
    arr_live = arr_due & ~arr_fail
    is_ss = fleet.policy[res] == SPACE_SHARED
    arr_ss = arr_live & is_ss
    order = jnp.where(arr_pre, idx, idx + g.n)
    if select_free:
        # The rank is only consulted by arr_ss members (everyone else
        # short-circuits on ~is_ss or ~arr_live), so running group_rank
        # unconditionally is result-identical to the gated form.
        rank = group_rank(res, arr_ss, order, n_resources)[0]
    else:
        rank = jax.lax.cond(
            arr_ss.any(),
            lambda: group_rank(res, arr_ss, order, n_resources)[0],
            lambda: jnp.full((g.n,), jnp.int32(2 ** 30)))
    arr_run = arr_live & (~is_ss | (rank < free_pe[res]))
    arr_queue = arr_ss & ~arr_run
    state = _fail_gridlets(state, arr_fail, n_users, t_next, params)
    g = state.g
    g = replace(
        g,
        status=jnp.where(arr_run, RUNNING,
                         jnp.where(arr_queue, QUEUED, g.status)),
        start=jnp.where(arr_run, jnp.minimum(g.start, t_next), g.start),
        # QUEUED jobs keep their arrival instant in t_event (the FCFS
        # key); QUEUED is never scanned as a pending event so it's safe.
        t_event=jnp.where(arr_run, INF,
                          jnp.where(arr_queue, t_next, g.t_event)),
    )
    state = replace(state, g=g)
    if _net_on(state):    # consumed transfers release their link slots
        state = _free_link_slots(state, arr_due & (state.xslot >= 0))
    return state, arr_due, arr_run, arr_queue


def _apply_failures(state, fleet, params, due_r, now, n_users,
                    n_resources, r_pad, masked=False):
    """Down the resources in ``due_r``: RUNNING/QUEUED residents move to
    FAILED, their slots are freed and their committed cost refunded; the
    MTTR stream schedules each resource's recovery.  ``masked`` (static)
    makes the body a bitwise no-op on an empty ``due_r`` -- every write
    below is already gated on ``due_r``/``victim``; the PRNG split is
    the one non-maskable leaf, selected back when nothing fired (the
    masked-apply contract for the select-free sweep engine)."""
    from .types import replace
    g = state.g
    key, k1 = jax.random.split(state.rng_key)
    if masked:
        key = jnp.where(due_r.any(), key, state.rng_key)
    repair = jnp.where(params.mttr > 0.0,
                       rand.exponential(k1, params.mttr), 0.0)
    on_r = jnp.clip(g.resource, 0, n_resources - 1)
    victim = ((g.status == RUNNING) | (g.status == QUEUED)) & due_r[on_r]
    state = _fail_gridlets(state, victim, n_users, now, params)
    state = replace(
        state, rng_key=key,
        res_up=state.res_up & ~due_r,
        next_fail=jnp.where(due_r, INF, state.next_fail),
        next_recover=jnp.where(due_r, now + repair, state.next_recover),
        fail_since=jnp.where(due_r, now, state.fail_since),
        # Reset the brokers' measurement window for the failed resource:
        # the failure wiped its in-flight progress, and a measured rate
        # of 0/elapsed would otherwise predict zero capacity forever.
        # After recovery the broker re-trusts the advertised rate, as a
        # fresh GIS registration would.
        first_dispatch=jnp.where(due_r[None, :], INF,
                                 state.first_dispatch))
    return _free_slots(state, victim & (state.slot >= 0), on_r, r_pad)


def _apply_recoveries(state, params, due_r, now, masked=False):
    """Bring the resources in ``due_r`` back up (GIS re-registration);
    the MTBF stream schedules each one's next failure.  ``masked`` as
    in :func:`_apply_failures`: bitwise no-op on an empty ``due_r``,
    with the PRNG split selected back."""
    from .types import replace
    key, k1 = jax.random.split(state.rng_key)
    if masked:
        key = jnp.where(due_r.any(), key, state.rng_key)
    uptime = rand.exponential(k1, params.mtbf)     # inf where mtbf <= 0
    return replace(
        state, rng_key=key,
        res_up=state.res_up | due_r,
        next_fail=jnp.where(due_r, now + uptime, state.next_fail),
        next_recover=jnp.where(due_r, INF, state.next_recover),
        downtime=state.downtime +
        jnp.where(due_r, now - state.fail_since, 0.0),
        fail_since=jnp.where(due_r, INF, state.fail_since),
        # The broker's cooldown blacklist keys off this stamp; -inf
        # init means a never-failed resource is never blacklisted.
        recovered_at=jnp.where(due_r, now, state.recovered_at))


def _trace_masks(params, due, n_resources):
    """Expand the due fault-trace rows into per-resource down/up masks.

    A row's target in ``0..R-1`` names a single resource; ``R + id``
    names trunk ``id`` -- every resource with ``trunk_of == id`` flips
    in the same apply (the correlated failure domain).  Rows are
    expanded independently, downs and ups separately; the caller
    applies downs first so an up and a down of the same resource at
    the same instant nets to up (deterministic tie-break).
    """
    tgt = params.fault_target
    r_idx = jnp.arange(n_resources, dtype=jnp.int32)
    hit = tgt[None, :] == r_idx[:, None]                    # [R, K]
    if params.trunk_of is not None:
        hit |= (tgt[None, :] - n_resources) == params.trunk_of[:, None]
    down_r = jnp.any(hit & (due & ~params.fault_up)[None, :], axis=1)
    up_r = jnp.any(hit & (due & params.fault_up)[None, :], axis=1)
    return down_r, up_r


def _apply_trace(state, fleet, params, due, down_r, up_r, now, n_users,
                 n_resources, r_pad):
    """Apply one batch of due fault-trace rows: scheduled downs follow
    the FAILURE semantics (residents fail-and-refund, slots freed,
    measurement window reset), scheduled ups the RECOVERY semantics
    (downtime accrual, cooldown stamp) -- but both deterministic, no
    PRNG, and the trace *owns* its targets: a trace-down clears any
    pending stochastic failure/recovery instant for the resource and a
    trace-up does not re-arm the MTBF stream (mixing trace targets
    with nonzero MTBF on the same resource is unsupported; see
    docs/ARCHITECTURE.md "Failure domains").  Every write is gated on
    the masks, so the body is a bitwise no-op on an empty ``due``
    (masked-apply contract; no cond needed on the select-free path).
    """
    from .types import replace
    g = state.g
    on_r = jnp.clip(g.resource, 0, n_resources - 1)
    eff_down = down_r & state.res_up
    victim = ((g.status == RUNNING) | (g.status == QUEUED)) & \
        down_r[on_r]
    state = _fail_gridlets(state, victim, n_users, now, params)
    state = replace(
        state,
        res_up=state.res_up & ~down_r,
        next_fail=jnp.where(down_r, INF, state.next_fail),
        next_recover=jnp.where(down_r, INF, state.next_recover),
        fail_since=jnp.where(eff_down, now, state.fail_since),
        first_dispatch=jnp.where(eff_down[None, :], INF,
                                 state.first_dispatch),
        trace_ptr=state.trace_ptr + jnp.sum(due, dtype=jnp.int32))
    state = _free_slots(state, victim & (state.slot >= 0), on_r, r_pad)
    # ups after downs: same-instant down+up of one resource nets to up
    eff_up = up_r & ~state.res_up
    return replace(
        state,
        res_up=state.res_up | up_r,
        next_recover=jnp.where(up_r, INF, state.next_recover),
        downtime=state.downtime + jnp.where(
            eff_up & jnp.isfinite(state.fail_since),
            now - state.fail_since, 0.0),
        fail_since=jnp.where(eff_up, INF, state.fail_since),
        recovered_at=jnp.where(eff_up, now, state.recovered_at))


def _admit_after_reservation(state, fleet, params, now, n_resources,
                             qrank, gate=None):
    """A reservation boundary changed the blocked-PE counts: re-admit
    queued work onto whatever space-shared capacity is now free.
    ``gate`` (the select-free path) zeroes the free-PE budget when
    False, making the admission a bitwise no-op."""
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    busy = jax.ops.segment_sum(
        (g.status == RUNNING).astype(jnp.int32), res,
        num_segments=n_resources)
    avail = fleet.num_pe - _reserved_pes(params, now, n_resources) - busy
    free_pe = jnp.where((fleet.policy == SPACE_SHARED) & state.res_up,
                        jnp.maximum(avail, 0), 0)
    if gate is not None:
        free_pe = jnp.where(gate, free_pe, 0)
    return _admit_queued(state, fleet, free_pe, now, n_resources, qrank)


# ----------------------------------------------------------------------
# Event sources (des.EventSource protocol)
# ----------------------------------------------------------------------

def _make_sources(fleet, params, n_users, ctx):
    """The engine's registered event sources, ordered by
    des.PRIORITY_ORDER.  ``ctx`` is the per-superstep scratch dict the
    built-in sources share (kernel scan outputs, event masks, the
    remaining free-PE budget); sources communicate through it only
    *outside* lax.cond branches.  To plug in a new kind, build a
    des.FnSource with a fresh K_* code and splice it into this tuple at
    its priority rank (docs/ARCHITECTURE.md walks through an example);
    ``step`` derives all index wiring (apply order, fired flags, event
    counts, trace rows) from each ``source.kind``, so splicing never
    renumbers the built-ins.  A source that batches several events per
    superstep reports them via ``ctx[("count", kind)]`` (and optionally
    a representative ``ctx[("who", kind)]`` for the trace); otherwise
    the engine counts 1 per firing.
    """
    n_resources = fleet.r

    # -- COMPLETION: the kernel scan IS the candidate computation -------
    def completion_candidates(state):
        r_pad = state.row_gridlet.shape[0]
        if "scan" not in ctx:       # the speculative path presets it
            ctx["scan"] = _scan_events(state, fleet, params,
                                       n_resources, r_pad)
        tmin = ctx["scan"][1]
        # per-ROW forecast instants: the frontier op takes the min (the
        # add is monotone, so min(t + tmin_r) == t + min(tmin_r) in f32)
        return jnp.where(tmin < _BIG, state.t + tmin, INF)

    def completion_apply(state, now):
        r_pad = state.row_gridlet.shape[0]
        completes, res = ctx["completes"], ctx["res"]
        occ_rows = ctx["scan"][3]
        state = _apply_completions(state, fleet, params, completes, now,
                                   n_resources, r_pad)
        # Freed PEs admit queued Gridlets.  Queued jobs only exist while
        # every unreserved PE is busy, so the kernel occupancy minus
        # this batch's completions is the exact busy count.
        n_comp_r = jax.ops.segment_sum(completes.astype(jnp.int32), res,
                                       num_segments=n_resources)
        ctx["n_comp_r"] = n_comp_r
        avail = fleet.num_pe - _reserved_pes(params, now, n_resources)
        free_pe = jnp.maximum(avail - (occ_rows[:n_resources] - n_comp_r),
                              0)
        free_pe = jnp.where((fleet.policy == SPACE_SHARED) & state.res_up,
                            free_pe, 0)
        ss_freed = completes & (fleet.policy[res] == SPACE_SHARED)
        # The admission only runs when a space-shared completion could
        # actually admit something: with an empty queue the admission
        # is the identity (rank BIG for everyone), so gating on
        # QUEUED.any() is result-identical.  The FCFS/SJF queue rank
        # comes from the carried queue ordering when it is still valid
        # (admissions remove rank prefixes, so it usually is) -- the
        # lexsort seed only reruns after the queue membership changed.
        pred = ss_freed.any() & (state.g.status == QUEUED).any()
        qr0, qok = ctx["qcarry"]

        if ctx.get("select_free"):
            # Masked admission: a zero free-PE budget admits nothing
            # bitwise, so no cond is needed.  The sweep micro-steps
            # additionally run sort-free -- their fire gate guarantees
            # the carried queue rank is valid whenever an admission
            # could happen (see _sweep_micro), so qr0 is used as-is;
            # the committing superstep reseeds with one unconditional
            # lexsort selected against the carry (what the cond lowers
            # to under vmap anyway).
            if ctx.get("sort_free"):
                qr_used = qr0
            else:
                qr_used = jnp.where(qok, qr0,
                                    _queue_rank(state, fleet,
                                                n_resources))
            state, admitq = _admit_queued(
                state, fleet, jnp.where(pred, free_pe, 0), now,
                n_resources, qr_used)
        else:
            def admit(s):
                qr = jax.lax.cond(
                    qok, lambda: qr0,
                    lambda: _queue_rank(s, fleet, n_resources))
                s, admitq = _admit_queued(s, fleet, free_pe, now,
                                          n_resources, qr)
                return s, admitq, qr

            state, admitq, qr_used = jax.lax.cond(
                pred, admit,
                lambda s: (s, jnp.zeros_like(completes), qr0),
                state)
        n_admit_r = jax.ops.segment_sum(
            admitq.astype(jnp.int32), res, num_segments=n_resources)
        ctx["qcarry"] = (qr_used - n_admit_r[res], qok | pred)
        ctx["free_pe"] = free_pe - n_admit_r
        ctx["newly"] = admitq
        ctx[("count", des.K_COMPLETION)] = jnp.sum(completes,
                                                   dtype=jnp.int32)
        return state

    # -- FAILURE / RECOVERY: MTBF/MTTR renewal streams ------------------
    def failure_apply(state, now):
        r_pad = state.row_gridlet.shape[0]
        due_r = jnp.isfinite(state.next_fail) & (state.next_fail <= now)
        ctx[("count", des.K_FAILURE)] = jnp.sum(due_r, dtype=jnp.int32)
        ctx[("who", des.K_FAILURE)] = jnp.argmax(due_r).astype(jnp.int32)
        # QUEUED victims leave the queue mid-rank: the carried ordering
        # no longer describes it.
        qr, qok = ctx["qcarry"]
        ctx["qcarry"] = (qr, qok & ~due_r.any())
        if ctx.get("select_free"):
            return _apply_failures(state, fleet, params, due_r, now,
                                   n_users, n_resources, r_pad,
                                   masked=True)
        return jax.lax.cond(
            due_r.any(),
            lambda s: _apply_failures(s, fleet, params, due_r, now,
                                      n_users, n_resources, r_pad),
            lambda s: s, state)

    def recovery_apply(state, now):
        due_r = jnp.isfinite(state.next_recover) & \
            (state.next_recover <= now)
        ctx[("count", des.K_RECOVERY)] = jnp.sum(due_r, dtype=jnp.int32)
        ctx[("who", des.K_RECOVERY)] = jnp.argmax(due_r).astype(jnp.int32)
        if ctx.get("select_free"):
            return _apply_recoveries(state, params, due_r, now,
                                     masked=True)
        return jax.lax.cond(
            due_r.any(),
            lambda s: _apply_recoveries(s, params, due_r, now),
            lambda s: s, state)

    # -- TRACE: replayable fault-injection schedule ---------------------
    # The deterministic twin of FAILURE/RECOVERY: a cursor walks the
    # time-sorted (time, target, up) rows; due rows expand through the
    # trunk incidence into whole failure domains.  params.fault_time is
    # None (a static gate -- an empty pytree subtree) in the default
    # configuration, which compiles the exact pre-trace program: one
    # all-inf candidate, an identity apply.
    def trace_candidates(state):
        if params.fault_time is None:
            return jnp.full((1,), INF, jnp.float32)
        k_idx = jnp.arange(params.fault_time.shape[0], dtype=jnp.int32)
        return jnp.where(k_idx >= state.trace_ptr, params.fault_time,
                         INF)

    def trace_apply(state, now):
        if params.fault_time is None:
            return state
        r_pad = state.row_gridlet.shape[0]
        k_idx = jnp.arange(params.fault_time.shape[0], dtype=jnp.int32)
        # Rows are time-sorted, so the due set is exactly the cursor's
        # contiguous prefix of instants <= now -- empty whenever the
        # source did not fire (ascending times guarantee it), which is
        # what makes the unconditional select-free application a
        # bitwise no-op.
        due = (k_idx >= state.trace_ptr) & (params.fault_time <= now)
        down_r, up_r = _trace_masks(params, due, n_resources)
        ctx[("count", des.K_TRACE)] = jnp.sum(due, dtype=jnp.int32)
        ctx[("who", des.K_TRACE)] = jnp.where(
            due.any(), params.fault_target[jnp.argmax(due)],
            -1).astype(jnp.int32)
        # QUEUED victims leave the queue mid-rank (like FAILURE); ups
        # only add capacity, which never perturbs the carried rank.
        qr, qok = ctx["qcarry"]
        ctx["qcarry"] = (qr, qok & ~down_r.any())
        if ctx.get("select_free"):
            return _apply_trace(state, fleet, params, due, down_r, up_r,
                                now, n_users, n_resources, r_pad)
        return jax.lax.cond(
            due.any(),
            lambda s: _apply_trace(s, fleet, params, due, down_r, up_r,
                                   now, n_users, n_resources, r_pad),
            lambda s: s, state)

    # -- RESERVATION: windows open/close at params.resv_* boundaries ----
    def reservation_candidates(state):
        return resv_mod.boundary_candidates(params.resv_start,
                                            params.resv_end, state.t)

    def reservation_apply(state, now):
        fired = ctx["fired_resv"]
        pred = fired & (state.g.status == QUEUED).any()
        qr0, qok = ctx["qcarry"]

        if ctx.get("select_free"):
            qr_used = jnp.where(qok, qr0,
                                _queue_rank(state, fleet, n_resources))
            state, admitq = _admit_after_reservation(
                state, fleet, params, now, n_resources, qr_used,
                gate=pred)
        else:
            def admit(s):
                qr = jax.lax.cond(
                    qok, lambda: qr0,
                    lambda: _queue_rank(s, fleet, n_resources))
                s, admitq = _admit_after_reservation(s, fleet, params,
                                                     now, n_resources,
                                                     qr)
                return s, admitq, qr

            state, admitq, qr_used = jax.lax.cond(
                pred, admit,
                lambda s: (s, jnp.zeros((s.g.n,), bool), qr0), state)
        n_admit_r = jax.ops.segment_sum(
            admitq.astype(jnp.int32),
            jnp.clip(state.g.resource, 0, n_resources - 1),
            num_segments=n_resources)
        ctx["qcarry"] = (
            qr_used - n_admit_r[jnp.clip(state.g.resource, 0,
                                         n_resources - 1)],
            qok | pred)
        ctx["newly"] = ctx["newly"] | admitq
        ctx["free_pe"] = ctx["free_pe"] - n_admit_r
        return state

    # -- MARKET / AUCTION: dynamic pricing rounds (economy layer) -------
    # Both write only SimState.price / their own next-round instant, so
    # they are naturally maskable (every write gated on `due`, False at
    # a garbage `now`) and carry NO slab-invalidation duty: the posted
    # price never enters the Fig 8 rate arithmetic, it only shifts what
    # the broker buys at its next poll.  They keep the conservative
    # default horizon (own candidates), so speculation slabs cut at
    # each round boundary and the sources fire only in committing
    # supersteps -- speculation-safe with zero micro-step changes.
    def market_candidates(state):
        return state.next_market.reshape(1)

    def market_apply(state, now):
        from .types import replace
        due = jnp.isfinite(state.next_market) & (state.next_market <= now)
        g = state.g
        res = jnp.clip(g.resource, 0, n_resources - 1)
        resident = (g.status == RUNNING) | (g.status == QUEUED)
        n_res = jax.ops.segment_sum(resident.astype(jnp.float32), res,
                                    num_segments=n_resources)
        demand = n_res / jnp.maximum(fleet.num_pe.astype(jnp.float32),
                                     1.0)
        base = jnp.asarray(fleet.cost_per_mi(), jnp.float32)
        newp = econ_mod.commodity_reprice(state.price, base, demand,
                                          params.market_gain,
                                          params.price_floor,
                                          params.price_cap)
        return replace(
            state,
            price=jnp.where(due, newp, state.price),
            next_market=jnp.where(due, now + params.market_period,
                                  state.next_market))

    def auction_candidates(state):
        return state.next_auction.reshape(1)

    def auction_apply(state, now):
        from .types import replace
        due = jnp.isfinite(state.next_auction) & \
            (state.next_auction <= now)
        # Masked PRNG contract (same pattern as _apply_failures): split
        # unconditionally, select the advanced key back only when the
        # round actually fired, so a masked-off apply is bitwise
        # identity and every fired round consumes exactly one split.
        key, kbid = jax.random.split(state.auction_key)
        key = jnp.where(due, key, state.auction_key)
        base = jnp.asarray(fleet.cost_per_mi(), jnp.float32)
        newp = econ_mod.auction_round(kbid, base, params.price_floor,
                                      params.price_cap)
        return replace(
            state,
            price=jnp.where(due, newp, state.price),
            next_auction=jnp.where(due, now + params.auction_period,
                                   state.next_auction),
            auction_key=key)

    # -- NETWORK: fair-share links (the [R_pad, T] transfer table) ------
    def network_candidates(state):
        # With the subsystem off the source exposes no candidates and
        # applies as the identity: analytic runs never see it.
        if not _net_on(state):
            return jnp.zeros((0,), jnp.float32)
        r_pad = state.row_gridlet.shape[0]
        if "net_scan" not in ctx:   # the horizon frontier re-enters here
            ctx["net_scan"] = _link_scan(state, params, n_resources,
                                         r_pad)
        tmin = ctx["net_scan"][1]
        # per-LINK next-transfer-completion forecast + the pending
        # network-entry instants of pre-routed future dispatches
        link_cand = jnp.where(tmin < _BIG, state.t + tmin, INF)
        pend = _pending_entries(state, params, n_resources)
        return jnp.concatenate(
            [link_cand, jnp.where(pend, state.g.t_event, INF)])

    def network_apply(state, now):
        if not _net_on(state):
            return state
        from .types import replace
        r_pad = state.row_gridlet.shape[0]
        n = state.g.n
        # (1) transfers that drained by `now` (recorded by the advance
        # pass) release their gridlet's pending instant to `now`; the
        # RETURN/ARRIVAL batches later this superstep consume them.
        due = ctx["xfer_done"]
        done_n = jnp.zeros((n,), bool).at[
            jnp.where(due, state.link_gridlet, n)].set(True, mode="drop")
        state = replace(state, g=replace(
            state.g, t_event=jnp.where(done_n, now, state.g.t_event)))
        # (2) pending entries whose network-entry instant arrived join
        # their link with the full payload as remaining bytes.
        pend = _pending_entries(state, params, n_resources) & \
            (state.g.t_event <= now)
        if ctx.get("select_free"):
            # _enqueue_transfers is a bitwise no-op on an empty mask.
            state = _enqueue_transfers(state, pend, n_resources, r_pad)
        else:
            state = jax.lax.cond(
                pend.any(),
                lambda s: _enqueue_transfers(s, pend, n_resources,
                                             r_pad),
                lambda s: s, state)
        ctx[("count", des.K_NETWORK)] = (
            jnp.sum(done_n, dtype=jnp.int32) +
            jnp.sum(pend, dtype=jnp.int32))
        ctx[("who", des.K_NETWORK)] = jnp.where(
            done_n.any(), jnp.argmax(done_n),
            jnp.argmax(pend)).astype(jnp.int32)
        return state

    # -- RETURN / ARRIVAL / CALENDAR / BROKER ---------------------------
    def return_candidates(state):
        g = state.g
        mask = g.status == RETURNING
        if _net_on(state):
            # tabled transfers are owned by the NETWORK source until
            # they drain (t_event inf while in flight, `now` once due);
            # a pending-entry return must not fire at its entry instant.
            res = jnp.clip(g.resource, 0, n_resources - 1)
            mask &= ~(network.link_tabled(g.out_bytes,
                                          params.link_baud[res]) &
                      (state.xslot < 0))
        return jnp.where(mask, g.t_event, INF)

    def return_apply(state, now):
        state, ret_due = _apply_returns(state, fleet, now, n_users,
                                        n_resources,
                                        gate=ctx.get("gate"))
        ctx[("count", des.K_RETURN)] = jnp.sum(ret_due, dtype=jnp.int32)
        ctx[("who", des.K_RETURN)] = jnp.argmax(ret_due).astype(jnp.int32)
        return state

    def arrival_candidates(state):
        g = state.g
        mask = g.status == IN_TRANSIT
        if _net_on(state):
            res = jnp.clip(g.resource, 0, n_resources - 1)
            mask &= ~(network.link_tabled(g.in_bytes,
                                          params.link_baud[res]) &
                      (state.xslot < 0))
        return jnp.where(mask, g.t_event, INF)

    def arrival_apply(state, now):
        state, arr_due, arr_run, arr_queue = _apply_arrivals(
            state, fleet, params, ctx["free_pe"], ctx["arr_pre"], now,
            n_users, n_resources,
            select_free=bool(ctx.get("select_free")))
        ctx[("count", des.K_ARRIVAL)] = jnp.sum(arr_due, dtype=jnp.int32)
        ctx[("who", des.K_ARRIVAL)] = jnp.argmax(arr_due).astype(jnp.int32)
        ctx["newly"] = ctx["newly"] | arr_run
        # New QUEUED members: the carried queue ordering is stale.
        qr, qok = ctx["qcarry"]
        ctx["qcarry"] = (qr, qok & ~arr_queue.any())
        return state

    def calendar_candidates(state):
        return calendar.next_boundary(fleet, state.t)   # per resource

    def calendar_apply(state, now):
        # The boundary itself is the event: landing a superstep on it
        # makes the piecewise-constant load integrate exactly (shares
        # are recomputed from the new load next scan).
        return state

    def broker_candidates(state):
        active, _ = _user_flags(state, params, fleet, n_users)
        # max(next_sched, t): a failure refund can re-activate a broker
        # whose poll instant already passed; never step time backwards.
        return jnp.where(active.any(),
                         jnp.maximum(state.next_sched, state.t),
                         INF).reshape(1)

    def broker_apply(state, now):
        # Pre-broker arrivals hold admission precedence over the
        # broker's zero-delay dispatches (the ARRIVAL > BROKER
        # tie-break), recorded before the dispatch batch runs.
        g = state.g
        ctx["arr_pre"] = (g.status == IN_TRANSIT) & (g.t_event <= now)
        pre_transit = g.status == IN_TRANSIT
        if ctx.get("select_free"):
            # The broker's full Fig 20 cycle is not naturally maskable
            # (measurement smoothing, next_sched bumps): the generic
            # masked-apply fallback runs it once and selects every
            # leaf -- exactly what the cond lowers to under vmap.
            state = des.tree_select(
                ctx["fired_b"],
                broker_mod.broker_event(state, fleet, params, n_users),
                state)
        else:
            state = jax.lax.cond(
                ctx["fired_b"],
                lambda s: broker_mod.broker_event(s, fleet, params,
                                                  n_users),
                lambda s: s, state)
        if _net_on(state):
            # Re-time the broker's fresh dispatches under the network
            # subsystem: contending payloads become load-dependent
            # (t_event inf; they enter their link at the end of this
            # superstep), the rest take the analytic delay at the
            # subsystem's link_baud (0 for the instantaneous cases).
            from .types import replace
            g2 = state.g
            res = jnp.clip(g2.resource, 0, n_resources - 1)
            newt = (g2.status == IN_TRANSIT) & ~pre_transit
            baud = params.link_baud[res]
            tabled = newt & network.link_tabled(g2.in_bytes, baud)
            t_ev = jnp.where(
                tabled, INF,
                jnp.where(newt,
                          now + network.transfer_delay(g2.in_bytes, baud),
                          g2.t_event))
            state = replace(state, g=replace(g2, t_event=t_ev))
        return state

    # Speculation-safety is per source (des.EventSource horizon hooks),
    # and the micro-steps now fire the full *slab-safe* source subset --
    # COMPLETION, FAILURE, RECOVERY, NETWORK drains, RETURN -- so only
    # genuinely interfering firings cut the horizon:
    #
    # * COMPLETION and RETURN are fully speculation-safe (horizon_fn =
    #   no_interference): applying them never pulls another source's
    #   pending instant earlier.  With the network subsystem ON a
    #   completion may *create* a return transfer mid-slab; that is
    #   safe too, because the micro-steps run the same end-of-superstep
    #   link-entry pass as a commit and re-derive fair shares each
    #   micro-scan -- and the IN_TRANSIT bounds below are membership-
    #   invariant, so a new link member never invalidates them.
    # * FAILURE / RECOVERY cut only when the resource has *resident*
    #   (RUNNING | QUEUED) work to interfere with; a strike on an idle
    #   or purely-transit resource fires inside the slab through the
    #   micro-steps' failure/recovery applies.  The resident set per
    #   resource can only shrink mid-slab (admissions come from QUEUED
    #   residents; arrivals and broker dispatches cut the horizon), so
    #   a gate that holds at commit time holds slab-wide.
    # * NETWORK cuts at (a) each pending entry's network-entry instant
    #   (joining a link re-divides its fair shares) and (b) a
    #   membership-invariant lower bound on each in-flight *staging*
    #   (IN_TRANSIT) drain -- network.fastest_drain, the sole-member
    #   rate -- because a staging drain matures an ARRIVAL, which only
    #   the committing superstep applies.  Result-return (RETURNING)
    #   drains cut nothing: the micro-steps' NETWORK apply releases
    #   them and the same-superstep RETURN batch consumes them, exactly
    #   the commit path's slice.
    # * Every other source keeps the conservative default -- each
    #   candidate stream cuts at its own instant; +inf streams (an
    #   empty reservation table, a never-polling broker) cut nothing.
    def failure_horizon(state):
        return jnp.where(_residents_r(state, n_resources),
                         state.next_fail, INF)

    def recovery_horizon(state):
        return jnp.where(_residents_r(state, n_resources),
                         state.next_recover, INF)

    def network_horizon(state):
        if not _net_on(state):
            return jnp.zeros((0,), jnp.float32)
        g = state.g
        r_pad = state.row_gridlet.shape[0]
        pad = r_pad - n_resources
        baud = jnp.pad(params.link_baud, (0, pad), constant_values=1.0)
        bg = jnp.pad(params.bg_flows, (0, pad))
        gid = state.link_gridlet
        staging = (gid >= 0) & \
            (g.status[jnp.clip(gid, 0, g.n - 1)] == IN_TRANSIT)
        bound = state.t + network.fastest_drain(
            state.link_rem, baud[:, None], bg[:, None])
        pend = _pending_entries(state, params, n_resources)
        return jnp.concatenate(
            [jnp.where(staging, bound, INF).ravel(),
             jnp.where(pend, g.t_event, INF)])

    sources = (
        des.FnSource(des.K_COMPLETION, "completion",
                     completion_candidates, completion_apply,
                     horizon_fn=des.no_interference),
        des.FnSource(des.K_FAILURE, "failure",
                     lambda s: s.next_fail, failure_apply,
                     horizon_candidates_fn=failure_horizon),
        des.FnSource(des.K_RECOVERY, "recovery",
                     lambda s: s.next_recover, recovery_apply,
                     horizon_candidates_fn=recovery_horizon),
        # TRACE keeps the conservative default horizon: every pending
        # trace instant cuts the speculation horizon (exactly like a
        # per-resource FAILURE with residents would), so trace rows
        # only ever fire in committing supersteps and the speculative
        # micro-steps never need to know the source exists.
        des.FnSource(des.K_TRACE, "trace", trace_candidates,
                     trace_apply),
        des.FnSource(des.K_RESERVATION, "reservation",
                     reservation_candidates, reservation_apply),
        des.FnSource(des.K_MARKET, "market",
                     market_candidates, market_apply),
        des.FnSource(des.K_AUCTION, "auction",
                     auction_candidates, auction_apply),
        des.FnSource(des.K_NETWORK, "network", network_candidates,
                     network_apply,
                     horizon_candidates_fn=network_horizon),
        des.FnSource(des.K_RETURN, "return", return_candidates,
                     return_apply, horizon_fn=des.no_interference),
        des.FnSource(des.K_ARRIVAL, "arrival", arrival_candidates,
                     arrival_apply),
        des.FnSource(des.K_CALENDAR, "calendar_step",
                     calendar_candidates, calendar_apply),
        des.FnSource(des.K_BROKER, "broker", broker_candidates,
                     broker_apply),
    )
    # des.PRIORITY_ORDER is the single source of truth for the tie-break
    # ranking; a spliced-in source must be added there too (trace-time
    # check, free under jit).
    assert tuple(s.kind for s in sources) == des.PRIORITY_ORDER, \
        "engine sources out of sync with des.PRIORITY_ORDER"
    return sources


# ----------------------------------------------------------------------
# Main loop
# ----------------------------------------------------------------------

def _user_flags(state, params, fleet, n_users):
    """(active, finished) per user -- paper 4.2.1 step 7 semantics.

    A broker stays active only while its cheapest possible purchase --
    the user's smallest still-undispatched (CREATED or FAILED) Gridlet
    priced at the best G$/MI on the grid -- fits in the remaining
    budget.  With nothing left to dispatch the broker goes inactive
    (every further poll would be a no-op); the user is finished once
    inactive with nothing in flight.
    """
    g = state.g
    u = g.user
    not_done = (g.status != DONE).astype(jnp.int32)
    n_not_done = jax.ops.segment_sum(not_done, u, num_segments=n_users)
    inflight = ((g.status == IN_TRANSIT) | (g.status == QUEUED) |
                (g.status == RUNNING) | (g.status == RETURNING))
    n_inflight = jax.ops.segment_sum(inflight.astype(jnp.int32), u,
                                     num_segments=n_users)
    min_job_cost = broker_mod.min_affordable_cost(g, fleet, n_users,
                                                  price=state.price,
                                                  params=params)
    all_done = n_not_done == 0
    active = ((state.t < params.deadline) &
              (state.spent + min_job_cost <= params.budget) &
              ~all_done)
    finished = (all_done | ~active) & (n_inflight == 0)
    return active, finished


def _advance_jobs(state, ctx, t_next, any_event, n_resources):
    """Advance every running job analytically over [t, t_next) by the
    kernel rates in ``ctx["scan"]``; records the completion batch
    (``completes``/``res``) and its trace representative in ``ctx`` and
    moves the clock to ``t_next``."""
    from .types import replace
    g = state.g
    j_cap = state.row_gridlet.shape[1]
    rate_rj, tmin_rows, amin_rows = ctx["scan"][:3]
    res = jnp.clip(g.resource, 0, n_resources - 1)
    has_slot = (g.status == RUNNING) & (state.slot >= 0)
    rate = jnp.where(has_slot,
                     rate_rj[res, jnp.clip(state.slot, 0, j_cap - 1)], 0.0)
    rel = jnp.where(has_slot,
                    g.remaining / jnp.maximum(rate, 1e-30), INF)
    dt = jnp.maximum(t_next - state.t, 0.0)
    completes = has_slot & any_event & (state.t + rel <= t_next)
    new_remaining = jnp.where(
        completes, 0.0, jnp.maximum(g.remaining - rate * dt, 0.0))
    # Trace representative: the kernel's per-row argmin of the earliest
    # row (first row attaining the global forecast minimum).
    r_star = jnp.argmin(tmin_rows)
    who_c = state.row_gridlet[
        r_star, jnp.clip(amin_rows[r_star], 0, j_cap - 1)]
    ctx["completes"], ctx["res"] = completes, res
    ctx[("who", des.K_COMPLETION)] = who_c
    return replace(state, g=replace(g, remaining=new_remaining), t=t_next)


def _alloc_newly(state, ctx, n_resources, r_pad):
    """Allocate job slots for everything newly RUNNING this superstep.

    Re-check status: a same-instant FAILURE may have killed a gridlet
    completion_apply just admitted (it had no slot yet, so the failure
    freed nothing) -- allocating for it would leak a ghost slot."""
    newly = ctx["newly"] & (state.g.status == RUNNING)
    res_now = jnp.clip(state.g.resource, 0, n_resources - 1)
    if ctx.get("select_free"):
        # _alloc_slots is a bitwise no-op on an empty mask.
        return _alloc_slots(state, newly, res_now, n_resources, r_pad)
    return jax.lax.cond(
        newly.any(),
        lambda s: _alloc_slots(s, newly, res_now, n_resources, r_pad),
        lambda s: s, state)


def _bookkeep(state, fleet, params, n_users, kinds, counts, whos, t_next):
    """Record termination instants, trace rows and the event counter for
    one (full or speculative) superstep.  ``kinds``/``counts``/``whos``
    are aligned [S] vectors in priority order; a kind with count 0
    writes no trace row.  ``n_steps`` is NOT bumped here -- it counts
    while-loop iterations and is owned by :func:`step`.  Returns
    ``(state, finished)``: the per-user termination flags double as the
    while-loop's continue condition, carried alongside the state so the
    loop ``cond`` never re-derives :func:`_user_flags` from scratch
    (state is unchanged between here and the next cond evaluation)."""
    from .types import replace
    _, finished = _user_flags(state, params, fleet, n_users)
    term = jnp.where(finished & ~jnp.isfinite(state.term_time),
                     t_next, state.term_time)
    fired = counts > 0
    off = jnp.cumsum(fired.astype(jnp.int32)) - fired.astype(jnp.int32)
    # Out-of-range positions (unfired kinds / full trace) are dropped.
    pos = jnp.where(fired, state.n_trace + off, TRACE_LEN)
    return replace(
        state,
        term_time=term,
        n_events=state.n_events + jnp.sum(counts),
        n_trace=state.n_trace + jnp.sum(fired, dtype=jnp.int32),
        trace_t=state.trace_t.at[pos].set(t_next, mode="drop"),
        trace_kind=state.trace_kind.at[pos].set(kinds, mode="drop"),
        trace_who=state.trace_who.at[pos].set(whos, mode="drop"),
    ), finished


def step(state: SimState, fleet, params: SimParams, n_users: int):
    """One committing superstep: ask every source for its candidate
    instants, pick the earliest t* through the fused frontier pass,
    advance the Fig 8 share algebra over [t, t*), apply every source
    due at t*.  (Standalone form without the cross-iteration slab
    carry; the jitted loops run :func:`_step_commit` directly.)"""
    state, _, _, _ = _step_commit(state, fleet, params, n_users,
                                  _empty_slab(state))
    return state


def _step_commit(state: SimState, fleet, params: SimParams,
                 n_users: int, slab, select_free=False, tel=None):
    """The committing superstep.  Takes and returns the slab carry
    ``(rank f32[R_pad, J], ok bool[])`` -- the last scan's (remaining,
    tie) rank table shifted by every completion since, and whether it
    still describes the current table.  The commit's own scan is
    slab-fed exactly like the speculative micro-steps' (sort-free when
    the carry holds, one lexsort reseed when it does not), so a
    completion-dominated stretch of supersteps runs without any sort
    at all.  Returns ``(state, slab, finished, tel)`` -- the per-user
    termination flags ride in the while-loop carry so the loop
    condition never recomputes them, and ``tel`` is the telemetry ring
    carry (``None`` when telemetry is off; it never feeds back into
    the simulation arithmetic).

    ``select_free`` (static) is the sweep-engine variant: every
    ``lax.cond`` in the superstep body is replaced by a masked
    unconditional application (bitwise no-op when not due -- the
    des.py masked-apply contract), so nothing lowers to a
    both-branches select under an outer vmap.  Results are bit-for-bit
    identical."""
    from .types import replace
    n_resources = fleet.r
    r_pad = state.row_gridlet.shape[0]

    # ---- fused event frontier over every source's candidates ---------
    # (one min/mask pass replaces the per-source stacked scalar
    # reductions; the completion source's candidates come from the
    # slab-fed kernel scan, the network source's from the link scan,
    # both preset here)
    ctx = {"select_free": select_free}
    ctx["scan"], reseeded = _checked_scan(state, fleet, params,
                                          n_resources, r_pad, slab,
                                          select_free=select_free)
    ctx["qcarry"] = (slab[2], slab[3])
    state = replace(state, n_scans=state.n_scans + 1,
                    n_reseeds=state.n_reseeds +
                    reseeded.astype(jnp.int32))
    sources = _make_sources(fleet, params, n_users, ctx)
    cands = [s.candidates(state) for s in sources]
    sizes = tuple(c.shape[0] for c in cands)
    t_star, fired, _, _, _ = kernel_ops.event_frontier(
        jnp.concatenate(cands), sizes)
    any_event = jnp.isfinite(t_star)
    t_next = jnp.where(any_event, t_star, state.t)

    # ---- advance transfers + running jobs analytically over
    # [t, t_next) (transfers first: both passes read the interval start
    # from state.t, which _advance_jobs moves to t_next) --------------
    if _net_on(state):
        state = _advance_transfers(state, ctx, t_next, any_event)
    state = _advance_jobs(state, ctx, t_next, any_event, n_resources)
    # All index wiring below is derived from source.kind, so splicing a
    # new source into _make_sources never renumbers the built-ins.
    pos_of = {s.kind: i for i, s in enumerate(sources)}
    fired_t = [fired[i] for i in range(len(sources))]
    ctx["fired_resv"] = fired_t[pos_of[des.K_RESERVATION]]
    ctx["fired_b"] = fired_t[pos_of[des.K_BROKER]]

    # ---- apply every due source: priority order, except BROKER before
    # ARRIVAL (see module docstring) -----------------------------------
    order = list(range(len(sources)))
    order.remove(pos_of[des.K_BROKER])
    order.insert(order.index(pos_of[des.K_ARRIVAL]), pos_of[des.K_BROKER])
    for i in order:
        state = sources[i].apply(state, t_next)

    # ---- allocate job slots for everything newly RUNNING -------------
    state = _alloc_newly(state, ctx, n_resources, r_pad)
    # ---- transfers created this superstep enter their links ----------
    if _net_on(state):
        state = _enqueue_new_transfers(state, params, n_resources, r_pad,
                                       select_free=select_free)

    # ---- bookkeeping: termination instants, trace, counters ----------
    # Per-source event counts: a batching source reported its own count
    # through ctx[("count", kind)]; the rest count 1 per firing.
    no_who = jnp.asarray(-1, jnp.int32)
    counts = jnp.stack([
        ctx.get(("count", s.kind), fired_t[i].astype(jnp.int32))
        for i, s in enumerate(sources)])
    whos = jnp.stack([ctx.get(("who", s.kind), no_who) for s in sources])
    kinds = jnp.asarray([s.kind for s in sources], jnp.int32)
    state, finished = _bookkeep(state, fleet, params, n_users, kinds,
                                counts, whos, t_next)
    state = replace(state, n_steps=state.n_steps + 1)
    # Observability only: records the post-apply state into the metrics
    # ring.  Nothing below reads ``tel``; see core/telemetry.py.
    tel = telemetry_mod.record(tel, state, fleet, kinds, counts, t_next,
                               spec=False)

    fired_interfering = (fired_t[pos_of[des.K_FAILURE]]
                         | fired_t[pos_of[des.K_RECOVERY]]
                         | fired_t[pos_of[des.K_TRACE]]
                         | fired_t[pos_of[des.K_RESERVATION]])
    return state, _slab_after(state, ctx, ctx["scan"], fired_interfering,
                              fleet, n_resources, r_pad), finished, tel


def _empty_slab(state):
    """The no-carry slab: forces the next scan (and the next queue
    admission) through one exact lexsort reseed -- loop entry, and the
    unjitted :func:`step`.  Layout: ``(rank f32[R_pad, J], ok bool[],
    qrank i32[N], qok bool[])`` -- the job-slot table's (remaining,
    tie) rank and the FCFS/SJF queue rank, each with its own validity
    flag."""
    return (jnp.zeros(state.row_gridlet.shape, jnp.float32),
            jnp.asarray(False),
            jnp.zeros((state.g.n,), jnp.int32),
            jnp.asarray(False))


def _partition_ok(rem, tie, valid, rank, npe_e, g, pol):
    """True iff the carried rank still yields the exact Fig 8 rate
    assignment the fresh lexsort rank would.

    The rank feeds exactly one thing: the share divisor ``k + [rank >=
    msc]`` -- which of the row's jobs sit in the MaxShare set.  So the
    injected-rank scan is bit-identical to the fresh-sort scan iff the
    rank's msc-boundary partition matches the (remaining, tie) value
    order: the lexicographic max of the carried MaxShare side must lie
    strictly below the lexicographic min of the MinShare side.  That
    is two masked reductions per row -- no sorts, no scatters.  Rows
    that never consult the rank pass for free: space-shared rows
    (every job owns a PE) and rows with ``g <= P_eff`` (everyone gets
    divisor 1).  Within-partition order drift from f32 advance
    rounding (two jobs collapsing to equal remaining in "wrong" tie
    order) is harmless by construction -- equal values share a
    divisor, complete together, and never straddle a *passing*
    boundary check.
    """
    k = jnp.floor(g / jnp.maximum(npe_e, 1.0))
    extra = g - k * jnp.maximum(npe_e, 1.0)
    msc = (npe_e - extra) * k
    left = valid & (rank < msc)
    right = valid & (rank >= msc)
    rem_lo = jnp.max(jnp.where(left, rem, -_BIG), axis=1, keepdims=True)
    rem_hi = jnp.min(jnp.where(right, rem, _BIG), axis=1, keepdims=True)
    tie_lo = jnp.max(jnp.where(left & (rem == rem_lo), tie, -_BIG),
                     axis=1, keepdims=True)
    tie_hi = jnp.min(jnp.where(right & (rem == rem_hi), tie, _BIG),
                     axis=1, keepdims=True)
    row_ok = (rem_lo < rem_hi) | ((rem_lo == rem_hi) & (tie_lo < tie_hi))
    rank_free = (pol > 0.5) | (g <= npe_e)
    return jnp.all(rank_free | row_ok)


def _checked_scan(state, fleet, params, n_resources, r_pad, slab,
                  select_free=False):
    """The Fig 8 scan, slab-fed when possible: inject the carried rank
    (sort-free, purely elementwise) when it still describes the table,
    else reseed with one exact lexsort scan.  Both branches run the
    identical downstream arithmetic, so the choice never changes a
    result -- only whether a sort happens.

    ``select_free`` (static) replaces the two-branch cond with ONE
    injected scan whose rank is ``where(use, carry, fresh lexsort)``.
    Under vmap the cond lowers to a select executing BOTH full scans
    per lane; the select-free form pays one lexsort plus one
    elementwise scan -- the dominant term in the sweep engine's
    batched-throughput win.  Bit-identical: the fresh branch of
    ``event_scan_xla`` computes its rank through the very same
    ``_lexsort_rank`` before running the identical arithmetic."""
    rank_carry, slab_ok = slab[0], slab[1]
    rem, tie, eff, npe, pol, blk, row_ok = _table_inputs(
        state, fleet, params, n_resources, r_pad)
    pol_f = pol.astype(jnp.float32)[:, None]
    npe_e, valid, g = _event_kernels._row_masks(
        rem, npe.astype(jnp.float32)[:, None], pol_f, blk[:, None],
        row_ok[:, None])
    use = slab_ok & _partition_ok(rem, tie, valid, rank_carry, npe_e, g,
                                  pol_f)

    if select_free:
        rank_fresh = _event_kernels._lexsort_rank(rem, tie, valid)[0]
        rank_in = jnp.where(use, rank_carry, rank_fresh)
        return kernel_ops.event_scan(rem, eff, npe, tie=tie, policy=pol,
                                     pe_blocked=blk, row_ok=row_ok,
                                     rank=rank_in,
                                     with_rank=True), ~use

    def inject(_):
        return kernel_ops.event_scan(rem, eff, npe, tie=tie, policy=pol,
                                     pe_blocked=blk, row_ok=row_ok,
                                     rank=rank_carry, with_rank=True)

    def fresh(_):
        return kernel_ops.event_scan(rem, eff, npe, tie=tie, policy=pol,
                                     pe_blocked=blk, row_ok=row_ok,
                                     with_rank=True)

    return jax.lax.cond(use, inject, fresh, None), ~use


def _slab_after(state, ctx, scan, fired_interfering, fleet, n_resources,
                r_pad):
    """The slab carry after a superstep applied its events: survivors'
    ranks shift down by the per-row completed count (completions are a
    value-prefix, hence a rank-prefix), and the carry stays valid
    unless the table was restructured where ranks matter --
    newly-RUNNING jobs landing on a *time-shared* row (space-shared
    rows never consult the rank), or any interfering source firing
    (failure/recovery/reservation rewrite slots or row masks).  The
    queue-rank half of the carry was maintained in place by the apply
    chain (``ctx["qcarry"]``)."""
    n_comp_r = jnp.pad(ctx["n_comp_r"], (0, r_pad - n_resources))
    rank = scan[4] - n_comp_r[:, None].astype(jnp.float32)
    res = jnp.clip(state.g.resource, 0, n_resources - 1)
    ts_newly = ctx["newly"] & (fleet.policy[res] == TIME_SHARED)
    qrank, qok = ctx["qcarry"]
    return (rank, ~(ts_newly.any() | fired_interfering), qrank, qok)


def _speculative_step(state, fleet, params, n_users, t_safe, slab,
                      finished, tel=None):
    """One speculative micro-superstep of the k-step batched path.

    Applies the earliest pending batch of the *slab-safe* sources --
    COMPLETION, FAILURE, RECOVERY, NETWORK drains, RETURN -- if, and
    only if, it lies *strictly* inside the speculation horizon
    ``t_safe``.  Inside the horizon no other source (and no
    *interfering* firing of these: a strike on a resource with resident
    work, an IN_TRANSIT drain maturing an ARRIVAL, a pending link
    entry) can fire (see :func:`_speculation_horizon`), so the global
    earliest pending instant is the min over exactly these streams and
    the full superstep machinery reduces to the slice applied here --
    the resulting state, trace rows and counters are bit-for-bit what
    :func:`step` would have produced.

    ``slab = (rank, ok)`` is the precomputed-wave carry: the committing
    superstep's (remaining, tie) rank table, shifted by every departure
    since.  While it remains valid (``ok`` and :func:`_partition_ok`), the
    whole scan -- Fig 8 rates, forecasts, argmin, occupancy -- is
    recomputed **from the carried rank with zero sorts** through the
    identical arithmetic of the lexsort path (`kernels.event_scan_xla`
    with an injected rank), so micro-steps consume the slab's waves in
    rank order instead of re-ranking.  Whenever an admission or another
    structural change invalidated the carry, the micro-step falls back
    to one exact rescan and reseeds the carry from its fresh rank.
    With the network subsystem on, in-flight transfers drain at their
    fair-share rates across the micro-step's interval exactly as in a
    committing superstep, and RETURNING drains forecast inside the
    horizon fire through the NETWORK apply (their RETURN rides the same
    micro-step); only drains that would mature an ARRIVAL -- IN_TRANSIT
    stagings -- are horizon-cut and land in a commit.
    Returns ``(state, fired, slab', finished', tel')``; ``fired`` False means
    the state was returned untouched (the caller stops speculating:
    pending times only move when events apply) and ``finished`` passes
    through unchanged.
    """
    n_resources = fleet.r
    r_pad = state.row_gridlet.shape[0]
    ctx = {}
    sources = _make_sources(fleet, params, n_users, ctx)
    by_kind = {s.kind: s for s in sources}
    comp, ret = by_kind[des.K_COMPLETION], by_kind[des.K_RETURN]

    # ---- the scan: slab-fed (sort-free) or exact-rescan reseed -------
    from .types import replace as _replace
    ctx["scan"], reseeded = _checked_scan(state, fleet, params,
                                          n_resources, r_pad, slab)
    ctx["qcarry"] = (slab[2], slab[3])
    state = _replace(state, n_scans=state.n_scans + 1,
                     n_reseeds=state.n_reseeds +
                     reseeded.astype(jnp.int32))
    rank_used = ctx["scan"][4]
    if _net_on(state):
        ctx["net_scan"] = _link_scan(state, params, n_resources, r_pad)

    tmin = ctx["scan"][1].min()
    t_comp = jnp.where(tmin < _BIG, state.t + tmin, INF)
    t_next = jnp.minimum(t_comp, ret.next_time(state))
    # Slab-safe strikes and link drains fire here too: a FAILURE /
    # RECOVERY due on a resident-free resource and any RETURNING-drain
    # forecast can lie inside the horizon (their interfering cases cut
    # t_safe -- see _make_sources); IN_TRANSIT drains never pass the
    # `fire` test because their membership-invariant bound cut t_safe.
    t_next = jnp.minimum(t_next, jnp.min(state.next_fail))
    t_next = jnp.minimum(t_next, jnp.min(state.next_recover))
    if _net_on(state):
        tmin_l = ctx["net_scan"][1].min()
        t_next = jnp.minimum(
            t_next, jnp.where(tmin_l < _BIG, state.t + tmin_l, INF))
    # ~finished.all(): the while loop would have stopped -- a strike
    # stream never dries up on its own, so without this gate a slab
    # could keep firing failures past the batch=1 run's last superstep.
    fire = (jnp.isfinite(t_next) & (t_next < t_safe) &
            ~finished.all())

    def live(s):
        from .types import replace
        if _net_on(s):
            s = _advance_transfers(s, ctx, t_next, fire)
        s = _advance_jobs(s, ctx, t_next, fire, n_resources)
        # The commit path's apply order, restricted to the slab-safe
        # sources (priority order: COMP, FAIL, REC, NET, RET).
        s = comp.apply(s, t_next)     # completions + queue admissions
        s = by_kind[des.K_FAILURE].apply(s, t_next)
        s = by_kind[des.K_RECOVERY].apply(s, t_next)
        if _net_on(s):
            s = by_kind[des.K_NETWORK].apply(s, t_next)
        s = ret.apply(s, t_next)      # incl. zero-delay returns
        s = _alloc_newly(s, ctx, n_resources, r_pad)
        if _net_on(s):                # exact slice of the commit path;
            s = _enqueue_new_transfers(s, params, n_resources, r_pad)
        kind_list = [des.K_COMPLETION, des.K_FAILURE, des.K_RECOVERY]
        if _net_on(s):
            kind_list.append(des.K_NETWORK)
        kind_list.append(des.K_RETURN)
        kinds = jnp.asarray(kind_list, jnp.int32)
        counts = jnp.stack([ctx[("count", k)] for k in kind_list])
        whos = jnp.stack([ctx[("who", k)] for k in kind_list])
        s, fin = _bookkeep(s, fleet, params, n_users, kinds, counts,
                           whos, t_next)
        tel2 = telemetry_mod.record(tel, s, fleet, kinds, counts,
                                    t_next, spec=True)
        # A fired strike restructures rows/slots exactly as in a
        # commit: invalidate the rank carry so the next scan reseeds.
        interfering = (ctx[("count", des.K_FAILURE)] +
                       ctx[("count", des.K_RECOVERY)]) > 0
        slab2 = _slab_after(s, ctx, ctx["scan"], interfering,
                            fleet, n_resources, r_pad)
        return replace(s, n_spec=s.n_spec + 1), slab2, fin, tel2

    def dead(s):
        # Untouched state: the scan just performed (reseeded or not)
        # still describes the table, so hand it to the next scan.
        return s, (rank_used, jnp.asarray(True), slab[2], slab[3]), \
            finished, tel

    (state, slab_next, finished, tel) = jax.lax.cond(fire, live, dead,
                                                     state)
    return state, fire, slab_next, finished, tel


def _sweep_micro(state, fleet, params, n_users, t_safe, slab, finished,
                 alive, tel=None):
    """One **masked** speculative micro-superstep of the select-free
    sweep engine -- :func:`_speculative_step` with every branch point
    replaced by masked arithmetic, built for lanes of an outer vmap.

    The fire decision becomes a pure mask: the batch applies iff its
    instant lies strictly inside the horizon AND the slab carry is
    valid AND any space-shared queue admission it needs can ride the
    carried queue rank.  When any leg fails, every due mask below is
    forced empty (``t_eff`` collapses to ``state.t`` and the gate
    threads through the masked-apply contract), so the whole body is a
    bitwise no-op -- a *masked no-op superstep* -- and per-lane
    divergence costs zero extra work under vmap.

    Three deliberate deviations from :func:`_speculative_step`, none
    observable in results:

    * the scan always injects the carried rank (never a lexsort): a
      micro-step with an invalid carry *declines* instead of
      reseeding, and the next committing superstep -- whose select-free
      scan folds the reseed into its single injected scan -- handles
      the batch with full generality;
    * a batch needing a queue admission while the queue-rank carry is
      stale likewise declines (``slab[3] | ~pred_admit`` in the gate),
      so micro-steps never sort;
    * consequently the "how" counters (``n_steps``/``n_spec``/
      ``n_scans``/``n_reseeds``) count a different superstep packing
      than the reference whenever a carry invalidates mid-slab --
      results, traces and ``n_events`` stay bit-for-bit identical.

    Returns ``(state, fire, slab', finished', tel')``; ``fire`` doubles as
    the next micro-step's ``alive`` (once a micro-step declines, the
    state -- hence every pending instant -- is unchanged, so every
    later one declines too).
    """
    from .types import replace as _replace
    n_resources = fleet.r
    r_pad = state.row_gridlet.shape[0]
    ctx = {"select_free": True, "sort_free": True}
    sources = _make_sources(fleet, params, n_users, ctx)
    by_kind = {s.kind: s for s in sources}
    comp, ret = by_kind[des.K_COMPLETION], by_kind[des.K_RETURN]

    # ---- one unconditionally-injected, sort-free scan ----------------
    rem, tie, eff, npe, pol, blk, row_ok = _table_inputs(
        state, fleet, params, n_resources, r_pad)
    pol_f = pol.astype(jnp.float32)[:, None]
    npe_e, valid, g_row = _event_kernels._row_masks(
        rem, npe.astype(jnp.float32)[:, None], pol_f, blk[:, None],
        row_ok[:, None])
    use = slab[1] & _partition_ok(rem, tie, valid, slab[0], npe_e,
                                  g_row, pol_f)
    scan = kernel_ops.event_scan(rem, eff, npe, tie=tie, policy=pol,
                                 pe_blocked=blk, row_ok=row_ok,
                                 rank=slab[0], with_rank=True)
    ctx["scan"] = scan
    ctx["qcarry"] = (slab[2], slab[3])
    if _net_on(state):
        ctx["net_scan"] = _link_scan(state, params, n_resources, r_pad)

    tmin = scan[1].min()
    t_comp = jnp.where(tmin < _BIG, state.t + tmin, INF)
    t_next = jnp.minimum(t_comp, ret.next_time(state))
    # Slab-safe strikes and RETURNING link drains fire here too (their
    # interfering cases cut t_safe; see _make_sources / the unmasked
    # _speculative_step).
    t_next = jnp.minimum(t_next, jnp.min(state.next_fail))
    t_next = jnp.minimum(t_next, jnp.min(state.next_recover))
    if _net_on(state):
        tmin_l = ctx["net_scan"][1].min()
        t_next = jnp.minimum(
            t_next, jnp.where(tmin_l < _BIG, state.t + tmin_l, INF))
    # Preview (without applying) whether this batch would need a
    # space-shared queue admission; scan outputs are garbage when the
    # carry is invalid, but then ``use`` already kills the gate.
    g = state.g
    res = jnp.clip(g.resource, 0, n_resources - 1)
    j_cap = state.row_gridlet.shape[1]
    has_slot = (g.status == RUNNING) & (state.slot >= 0)
    rate = jnp.where(has_slot,
                     scan[0][res, jnp.clip(state.slot, 0, j_cap - 1)],
                     0.0)
    rel = jnp.where(has_slot, g.remaining / jnp.maximum(rate, 1e-30),
                    INF)
    would_c = has_slot & (state.t + rel <= t_next)
    pred_admit = ((would_c & (fleet.policy[res] == SPACE_SHARED)).any()
                  & (g.status == QUEUED).any())
    # ~finished.all() mirrors the while-loop stop: strike streams never
    # dry up, so a slab must not outlive the batch=1 run's last step.
    fire = (jnp.isfinite(t_next) & (t_next < t_safe) & use & alive &
            (slab[3] | ~pred_admit) & ~finished.all())
    t_eff = jnp.where(fire, t_next, state.t)
    ctx["gate"] = fire

    # ---- the masked slab-safe slice (COMP, FAIL, REC, NET, RET) ------
    if _net_on(state):
        state = _advance_transfers(state, ctx, t_eff, fire, gate=fire)
    state = _advance_jobs(state, ctx, t_eff, fire, n_resources)
    state = comp.apply(state, t_eff)
    state = by_kind[des.K_FAILURE].apply(state, t_eff)
    state = by_kind[des.K_RECOVERY].apply(state, t_eff)
    if _net_on(state):
        state = by_kind[des.K_NETWORK].apply(state, t_eff)
    state = ret.apply(state, t_eff)
    state = _alloc_newly(state, ctx, n_resources, r_pad)
    if _net_on(state):
        state = _enqueue_new_transfers(state, params, n_resources,
                                       r_pad, select_free=True)
    kind_list = [des.K_COMPLETION, des.K_FAILURE, des.K_RECOVERY]
    if _net_on(state):
        kind_list.append(des.K_NETWORK)
    kind_list.append(des.K_RETURN)
    kinds = jnp.asarray(kind_list, jnp.int32)
    counts = jnp.stack([ctx[("count", k)] for k in kind_list])
    whos = jnp.stack([ctx[("who", k)] for k in kind_list])
    state, finished = _bookkeep(state, fleet, params, n_users, kinds,
                                counts, whos, t_eff)
    state = _replace(
        state,
        n_spec=state.n_spec + fire.astype(jnp.int32),
        n_scans=state.n_scans + alive.astype(jnp.int32))
    # Masked recorder: a declined micro-step (``fire`` False) writes no
    # ring row -- the explicit gate, not the counts, decides (declined
    # steps are bitwise no-ops including counts, but being explicit
    # keeps the masked path's contract visible).
    tel = telemetry_mod.record(tel, state, fleet, kinds, counts, t_eff,
                               spec=True, gate=fire)

    # Slab: micro admissions are space-shared only (ts_newly is always
    # empty here), so validity persists from the input unless a strike
    # fired (it restructures rows/slots; mirror the commit's
    # invalidation so the next scan reseeds); the rank shifts by the
    # departed per-row completion counts (zero when declined).
    interfering = (ctx[("count", des.K_FAILURE)] +
                   ctx[("count", des.K_RECOVERY)]) > 0
    n_comp_r = jnp.pad(ctx["n_comp_r"], (0, r_pad - n_resources))
    slab2 = (scan[4] - n_comp_r[:, None].astype(jnp.float32),
             slab[1] & ~interfering) + ctx["qcarry"]
    return state, fire, slab2, finished, tel


def _speculation_horizon(state, fleet, params, n_users):
    """Earliest instant at which any source could interfere with the
    speculative micro-steps' slab-safe batching (COMPLETION, FAILURE /
    RECOVERY strikes on resident-free resources, RETURNING link drains,
    RETURN), derived from the registered sources' ``horizon_candidates``
    hooks (des.EventSource) through the same fused frontier pass as the
    committing superstep -- the safety condition is owned by the
    sources, not hard-coded here.

    COMPLETION and RETURN contribute no candidates (their firings never
    pull another source's pending instant earlier); FAILURE / RECOVERY
    contribute only strikes on resources with resident work; NETWORK
    contributes pending link-entry instants and membership-invariant
    lower bounds on IN_TRANSIT staging drains; every other source
    conservatively contributes its own candidate streams, each cutting
    at its own instant (+inf streams -- a zero-rate failure row, an
    empty reservation table -- cut nothing).  The derived cut is safe
    because within the slab only the slab-safe slice applies, and none
    of its firings can (re-)activate a broker, pull an interfering
    strike earlier, move a reservation or calendar boundary, or put a
    gridlet in transit.  Note the completion scan is *not* run here:
    interference candidates never need the forecast kernel.
    """
    ctx = {}
    sources = _make_sources(fleet, params, n_users, ctx)
    cands = [s.horizon_candidates(state) for s in sources]
    sizes = tuple(c.shape[0] for c in cands)
    _, _, _, t_safe, _ = kernel_ops.event_frontier(
        jnp.concatenate(cands), sizes)
    return t_safe


def step_batched(state: SimState, fleet, params: SimParams, n_users: int,
                 batch: int, slab=None, tel=None):
    """One batched while-loop iteration: a committing superstep (which
    handles whatever is due next, at full priority/tie-break
    generality) followed by up to ``batch - 1`` speculative
    COMPLETION/RETURN supersteps strictly inside the safety horizon,
    fed by the committing superstep's precomputed wave ranking (the
    slab carry -- see :func:`_speculative_step`).  Takes and returns
    ``(state, slab)`` so the ranking survives across while-loop
    iterations (returns ``(state, slab, finished, tel)`` -- the last
    superstep's per-user termination flags, which the jitted loops
    carry so the loop condition never recomputes :func:`_user_flags`,
    plus the telemetry ring carry, ``None`` when telemetry is off);
    ``slab=None`` starts without one.

    When the horizon is empty (an interfering source is due immediately
    -- dense failure scenarios, broker polls every superstep) every
    micro-step declines and the iteration degrades gracefully to the
    single-step path; ``batch=1`` skips the speculation machinery
    entirely and IS the single-step path.
    """
    if slab is None:
        slab = _empty_slab(state)
    state, slab, finished, tel = _step_commit(state, fleet, params,
                                              n_users, slab, tel=tel)
    if batch <= 1:
        return state, slab, finished, tel
    t_safe = _speculation_horizon(state, fleet, params, n_users)

    def micro(_, carry):
        s, alive, slab, fin, tel = carry

        def go(s):
            return _speculative_step(s, fleet, params, n_users, t_safe,
                                     slab, fin, tel)

        # Once a micro-step declines, every later one would too (the
        # state, hence every pending time, is unchanged): short-circuit.
        return jax.lax.cond(
            alive, go,
            lambda s: (s, jnp.asarray(False), slab, fin, tel), s)

    state, _, slab, finished, tel = jax.lax.fori_loop(
        0, batch - 1, micro,
        (state, jnp.asarray(True), slab, finished, tel))
    return state, slab, finished, tel


def step_sweep(state: SimState, fleet, params: SimParams, n_users: int,
               batch: int, slab=None, tel=None):
    """One select-free batched iteration -- :func:`step_batched` with
    every ``lax.cond`` replaced by masked arithmetic, built to live
    under an outer ``vmap`` over scenarios (the sweep engine).

    A select-free committing superstep handles whatever is due next at
    full generality, then a fixed ``batch - 1`` masked micro-supersteps
    (:func:`_sweep_micro`) are committed *unconditionally* -- a
    micro-step that must not fire executes as a bitwise no-op instead
    of branching, so under vmap no lane ever pays for another lane's
    divergence (a ``lax.cond`` would lower to a select running both
    branches for every lane).  Results are bit-for-bit identical to
    :func:`step_batched` for every batch value; only the "how"
    counters may pack supersteps differently (see
    :func:`_sweep_micro`).
    """
    if slab is None:
        slab = _empty_slab(state)
    state, slab, finished, tel = _step_commit(state, fleet, params,
                                              n_users, slab,
                                              select_free=True, tel=tel)
    if batch <= 1:
        return state, slab, finished, tel
    t_safe = _speculation_horizon(state, fleet, params, n_users)

    def micro(_, carry):
        s, alive, slab, fin, tel = carry
        return _sweep_micro(s, fleet, params, n_users, t_safe, slab,
                            fin, alive, tel)

    state, _, slab, finished, tel = jax.lax.fori_loop(
        0, batch - 1, micro,
        (state, jnp.asarray(True), slab, finished, tel))
    return state, slab, finished, tel


def _continue(state, finished, max_events):
    # Bound TOTAL supersteps (committing + speculative) so the budget
    # means the same thing for every batch value; a truncated batch=k
    # run stops within k-1 supersteps of the batch=1 run (check
    # ExperimentResult.truncated before comparing truncated runs).
    # ``finished`` is carried from the last superstep's bookkeeping
    # (ROADMAP "next constants to shrink": the loop cond no longer
    # re-derives _user_flags -- state cannot change between the
    # bookkeeping and this evaluation, so the carried flags are exact).
    return (~finished.all()) & (state.n_steps + state.n_spec < max_events)


def init_state(gridlets, fleet, n_users: int, first_sched: float = 0.0,
               max_jobs: int | None = None,
               params: SimParams | None = None,
               net_cap: int = 0) -> SimState:
    """``max_jobs`` bounds concurrently RUNNING gridlets per resource
    (the J axis of the job-slot table); defaults to the safe bound N.
    ``params`` seeds the failure stream (no failures when omitted).
    ``net_cap`` (static) sizes the fair-share transfer-slot table: T =
    net_cap transfer slots per resource link; 0 (the default) disables
    the network subsystem entirely -- transfers keep their analytic
    timestamps."""
    n = gridlets.n
    j_cap = n if max_jobs is None else min(max_jobs, n)
    t_cap = min(max(net_cap, 0), n)
    r_pad = -(-fleet.r // BLOCK_R) * BLOCK_R
    if params is None:
        key = jax.random.PRNGKey(0)
        next_fail = jnp.full((fleet.r,), INF, jnp.float32)
        next_market = jnp.asarray(INF, jnp.float32)
        next_auction = jnp.asarray(INF, jnp.float32)
        auction_key = jax.random.PRNGKey(0)
    else:
        key, k1 = jax.random.split(params.fail_key)
        next_fail = rand.exponential(k1, params.mtbf)  # inf if mtbf <= 0
        # First pricing round one full period in (inf = model off), so
        # PRICE_STATIC runs never see the sources fire and stay bitwise
        # identical to pre-pricing builds.
        next_market = jnp.where(
            (params.pricing_model == econ_mod.PRICE_COMMODITY) &
            (params.market_period > 0),
            params.market_period, INF).astype(jnp.float32)
        next_auction = jnp.where(
            (params.pricing_model == econ_mod.PRICE_AUCTION) &
            (params.auction_period > 0),
            params.auction_period, INF).astype(jnp.float32)
        auction_key = params.auction_key
    return SimState(
        t=jnp.asarray(0.0, jnp.float32),
        g=gridlets,
        slot=jnp.full((n,), -1, jnp.int32),
        row_gridlet=jnp.full((r_pad, j_cap), -1, jnp.int32),
        xslot=jnp.full((n,), -1, jnp.int32),
        link_gridlet=jnp.full((r_pad, t_cap), -1, jnp.int32),
        link_rem=jnp.zeros((r_pad, t_cap), jnp.float32),
        spent=jnp.zeros((n_users,), jnp.float32),
        done_on=jnp.zeros((n_users, fleet.r), jnp.float32),
        first_dispatch=jnp.full((n_users, fleet.r), INF, jnp.float32),
        next_sched=jnp.asarray(first_sched, jnp.float32),
        term_time=jnp.full((n_users,), INF, jnp.float32),
        res_up=jnp.ones((fleet.r,), bool),
        next_fail=next_fail,
        next_recover=jnp.full((fleet.r,), INF, jnp.float32),
        fail_since=jnp.full((fleet.r,), INF, jnp.float32),
        downtime=jnp.zeros((fleet.r,), jnp.float32),
        # -inf: t - recovered_at is +inf for a never-failed resource,
        # so the cooldown blacklist can never trigger on it.
        recovered_at=jnp.full((fleet.r,), -INF, jnp.float32),
        trace_ptr=jnp.asarray(0, jnp.int32),
        rng_key=key,
        price=jnp.broadcast_to(
            jnp.asarray(fleet.cost_per_mi(), jnp.float32), (fleet.r,)),
        next_market=next_market,
        next_auction=next_auction,
        auction_key=auction_key,
        n_events=jnp.asarray(0, jnp.int32),
        n_steps=jnp.asarray(0, jnp.int32),
        n_spec=jnp.asarray(0, jnp.int32),
        n_reseeds=jnp.asarray(0, jnp.int32),
        n_scans=jnp.asarray(0, jnp.int32),
        n_trace=jnp.asarray(0, jnp.int32),
        n_failed=jnp.asarray(0, jnp.int32),
        n_resubmits=jnp.asarray(0, jnp.int32),
        overflow=jnp.asarray(0, jnp.int32),
        trace_t=jnp.full((TRACE_LEN,), INF, jnp.float32),
        trace_kind=jnp.full((TRACE_LEN,), -1, jnp.int32),
        trace_who=jnp.full((TRACE_LEN,), -1, jnp.int32),
    )


def _finalize(state: SimState, tel=None) -> SimResult:
    # Users that never started (e.g. zero budget) terminate at final t.
    term = jnp.where(jnp.isfinite(state.term_time), state.term_time,
                     state.t)
    # Resources still down at the end accrue downtime to the final t.
    downtime = state.downtime + jnp.where(
        state.res_up, 0.0, state.t - state.fail_since)
    return SimResult(gridlets=state.g, spent=state.spent, term_time=term,
                     n_events=state.n_events,
                     trace=(state.trace_t, state.trace_kind,
                            state.trace_who),
                     n_steps=state.n_steps, overflow=state.overflow,
                     n_failed=state.n_failed,
                     n_resubmits=state.n_resubmits, downtime=downtime,
                     n_spec=state.n_spec, n_reseeds=state.n_reseeds,
                     n_scans=state.n_scans, telemetry=tel)


@functools.partial(jax.jit, static_argnames=("n_users", "max_events",
                                             "max_jobs", "batch",
                                             "net_cap", "telemetry"))
def _run_jit(gridlets, fleet, params, n_users, max_events, max_jobs,
             batch, net_cap=0, telemetry=None):
    state = init_state(gridlets, fleet, n_users, max_jobs=max_jobs,
                       params=params, net_cap=net_cap)
    # The loop carry holds the slab (the last scan's rank table) and
    # the per-user termination flags next to the state, so
    # completion-dominated stretches of iterations -- committing AND
    # speculative supersteps -- run without any sort, and the loop
    # condition reads the carried flags instead of re-deriving
    # _user_flags per evaluation.  The telemetry ring rides the carry
    # as a fourth element; ``telemetry=None`` (static) makes it an
    # empty pytree node, lowering to exactly the telemetry-free loop.
    _, fin0 = _user_flags(state, params, fleet, n_users)
    tel0 = (telemetry_mod.init(telemetry, fleet.r)
            if telemetry else None)
    state, _, _, tel = jax.lax.while_loop(
        lambda c: _continue(c[0], c[2], max_events),
        lambda c: step_batched(c[0], fleet, params, n_users, batch,
                               c[1], c[3]),
        (state, _empty_slab(state), fin0, tel0))
    return _finalize(state, tel)


def run(gridlets, fleet, params: SimParams, n_users: int,
        max_events: int, max_jobs: int | None = None,
        batch: int = DEFAULT_BATCH, net_cap: int = 0,
        telemetry: int | None = None) -> SimResult:
    """Run a full experiment: broker-driven scheduling + execution.

    ``batch`` (static) is the superstep batching factor k: each
    while-loop iteration commits one superstep and then speculatively
    applies up to k-1 further COMPLETION/RETURN supersteps inside the
    safety horizon (see :func:`step_batched`).  ``batch=1`` is the
    single-step path; any k produces bit-for-bit identical results for
    runs that finish within ``max_events`` total supersteps (a
    truncated run stops within k-1 supersteps of the k=1 cut -- check
    ``truncated`` before comparing).

    ``net_cap`` (static) enables the contention-aware network
    subsystem: transfers with positive payloads over finite links
    fair-share each resource's ``params.link_baud`` instead of taking
    the analytic bytes/baud delay, with up to ``net_cap`` concurrent
    transfers per link (0 = analytic links, the default).

    ``telemetry`` (static) enables the observability ring: a positive
    capacity records one metrics row per committed superstep into
    ``SimResult.telemetry`` (see :mod:`repro.core.telemetry`).  The
    ring is a separate loop carry that never feeds back into the
    simulation -- results are bitwise identical with it on or off, and
    ``telemetry=None`` compiles to exactly the telemetry-free program.
    """
    return _run_jit(gridlets, fleet, params, n_users, max_events,
                    max_jobs, batch, net_cap, telemetry)


def run_inner(gridlets, fleet, params: SimParams, n_users: int,
              max_events: int, max_jobs: int | None = None,
              batch: int = 1, net_cap: int = 0,
              telemetry: int | None = None) -> SimResult:
    """Unjitted variant for use under an outer vmap/jit (sweep).

    ``batch`` defaults to 1 here: under vmap the speculative path's
    conditionals lower to selects that evaluate both branches, so
    batching saves no work for swept grids (results stay identical
    either way).
    """
    state = init_state(gridlets, fleet, n_users, max_jobs=max_jobs,
                       params=params, net_cap=net_cap)
    _, fin0 = _user_flags(state, params, fleet, n_users)
    tel0 = (telemetry_mod.init(telemetry, fleet.r)
            if telemetry else None)
    state, _, _, tel = jax.lax.while_loop(
        lambda c: _continue(c[0], c[2], max_events),
        lambda c: step_batched(c[0], fleet, params, n_users, batch,
                               c[1], c[3]),
        (state, _empty_slab(state), fin0, tel0))
    return _finalize(state, tel)


def run_sweep(gridlets, fleet, params: SimParams, n_users: int,
              max_events: int, max_jobs: int | None = None,
              batch: int = DEFAULT_BATCH, net_cap: int = 0,
              telemetry: int | None = None) -> SimResult:
    """Unjitted select-free variant for use under an outer vmap/jit --
    the sweep engine (see :func:`step_sweep`).

    Where :func:`run_inner` pins ``batch=1`` because the speculative
    path's conds lower to both-branch selects under vmap, this loop is
    select-free by construction: ``batch`` defaults to the full
    ``DEFAULT_BATCH`` and each lane of an outer vmap pays only for the
    work it actually commits.  Results are bit-for-bit identical to
    :func:`run_inner` / :func:`run` (asserted by
    tests/test_sweep_engine.py); the "how" counters (``n_steps``/
    ``n_spec``/``n_scans``/``n_reseeds``) may pack the same events into
    supersteps differently.
    """
    state = init_state(gridlets, fleet, n_users, max_jobs=max_jobs,
                       params=params, net_cap=net_cap)
    _, fin0 = _user_flags(state, params, fleet, n_users)
    tel0 = (telemetry_mod.init(telemetry, fleet.r)
            if telemetry else None)
    state, _, _, tel = jax.lax.while_loop(
        lambda c: _continue(c[0], c[2], max_events),
        lambda c: step_sweep(c[0], fleet, params, n_users, batch, c[1],
                             c[3]),
        (state, _empty_slab(state), fin0, tel0))
    return _finalize(state, tel)


# ----------------------------------------------------------------------
# Lane-batched sweep loop: the scenario axis INSIDE the while loop
# ----------------------------------------------------------------------

def _tree_where(pred, new, old):
    """Per-lane select over whole pytrees: ``pred`` is bool[L], every
    leaf carries a leading lane axis.  The freeze step of the
    lane-batched loop -- exactly the select ``vmap`` inserts around a
    lifted ``while_loop`` body, written out by hand."""
    def sel(a, b):
        return jnp.where(pred.reshape(pred.shape + (1,) * (a.ndim - 1)),
                         a, b)
    return jax.tree_util.tree_map(sel, new, old)


def _commit_lanes(state, fleet, params, n_users, slab, tel=None):
    """The select-free committing superstep over a whole lane batch --
    :func:`_step_commit` with the scenario axis *inside* the step, so
    expensive bodies that most supersteps do not need run under a real
    scalar ``lax.cond`` on an any-lane predicate instead of
    unconditionally per lane:

    * the rank reseed lexsort (the single most expensive commit term)
      runs only when some lane's slab carry actually went stale;
    * FAILURE/RECOVERY run only when some lane has a stream due;
    * RESERVATION only when some lane crossed a window boundary;
    * BROKER (the full Fig 20 cycle, which ``des.tree_select`` would
      otherwise evaluate every superstep for every lane) only when some
      lane's poll fired;
    * ARRIVAL only when some lane has an in-transit gridlet due
      (checked *post*-broker: zero-byte dispatches arrive in their
      creation superstep).

    Each skipped body is exact, not approximate: by the masked-apply
    contract (tests/test_sweep_engine.py::test_masked_apply_contract) a
    masked application with nothing due is a bitwise no-op, so skipping
    it when NO lane has anything due is the identity.  The always-hot
    pieces (the injected sort-free scan, the fused frontier, the
    analytic advances, COMPLETION and RETURN) stay vmapped over lanes.
    Under ``shard_map`` each device evaluates the predicates over *its*
    lanes only, so a shard whose lanes never poll skips polls other
    shards are paying for.  Results are bit-for-bit identical to
    :func:`_step_commit` per lane; only the "how" counters can differ.
    """
    from .types import replace
    n_resources = fleet.r
    r_pad = state.row_gridlet.shape[1]          # leaves are [L, ...]
    net = state.link_rem.shape[-1] > 0          # _net_on, lane-batched
    pos = {k: i for i, k in enumerate(des.PRIORITY_ORDER)}

    # ---- prologue (vmapped): is each lane's rank carry still valid? --
    def prologue(state, params, slab):
        rem, tie, eff, npe, pol, blk, row_ok = _table_inputs(
            state, fleet, params, n_resources, r_pad)
        pol_f = pol.astype(jnp.float32)[:, None]
        npe_e, valid, g_row = _event_kernels._row_masks(
            rem, npe.astype(jnp.float32)[:, None], pol_f, blk[:, None],
            row_ok[:, None])
        use = slab[1] & _partition_ok(rem, tie, valid, slab[0], npe_e,
                                      g_row, pol_f)
        return use, rem, tie, valid

    use, rem, tie, valid = jax.vmap(prologue)(state, params, slab)

    rank_fresh = jax.lax.cond(
        jnp.any(~use),
        lambda: jax.vmap(lambda r, t, v: _event_kernels._lexsort_rank(
            r, t, v)[0])(rem, tie, valid),
        lambda: slab[0])
    rank_in = jnp.where(use[:, None, None], slab[0], rank_fresh)

    # ---- head (vmapped): injected scan, frontier, advances,
    # COMPLETION -- every superstep needs these ------------------------
    def head(state, params, slab, rank_in, use):
        ctx = {"select_free": True}
        rem, tie, eff, npe, pol, blk, row_ok = _table_inputs(
            state, fleet, params, n_resources, r_pad)
        ctx["scan"] = kernel_ops.event_scan(
            rem, eff, npe, tie=tie, policy=pol, pe_blocked=blk,
            row_ok=row_ok, rank=rank_in, with_rank=True)
        ctx["qcarry"] = (slab[2], slab[3])
        state = replace(state, n_scans=state.n_scans + 1,
                        n_reseeds=state.n_reseeds +
                        (~use).astype(jnp.int32))
        sources = _make_sources(fleet, params, n_users, ctx)
        cands = [s.candidates(state) for s in sources]
        sizes = tuple(c.shape[0] for c in cands)
        t_star, fired, _, _, _ = kernel_ops.event_frontier(
            jnp.concatenate(cands), sizes)
        any_event = jnp.isfinite(t_star)
        t_next = jnp.where(any_event, t_star, state.t)
        if _net_on(state):
            state = _advance_transfers(state, ctx, t_next, any_event)
        state = _advance_jobs(state, ctx, t_next, any_event, n_resources)
        ctx["fired_resv"] = fired[pos[des.K_RESERVATION]]
        ctx["fired_b"] = fired[pos[des.K_BROKER]]
        state = sources[pos[des.K_COMPLETION]].apply(state, t_next)
        # The ctx keys later pieces consume, snapshotted as a pytree the
        # conds can thread (sources communicate through ctx only inside
        # one trace; across cond boundaries the pack IS the ctx).
        pack = {"scan": ctx["scan"], "qcarry": ctx["qcarry"],
                "free_pe": ctx["free_pe"], "newly": ctx["newly"],
                "n_comp_r": ctx["n_comp_r"],
                "count_comp": ctx[("count", des.K_COMPLETION)],
                "who_comp": ctx[("who", des.K_COMPLETION)]}
        if _net_on(state):
            pack["xfer_done"] = ctx["xfer_done"]
        fr_due = ((jnp.isfinite(state.next_fail) &
                   (state.next_fail <= t_next)).any() |
                  (jnp.isfinite(state.next_recover) &
                   (state.next_recover <= t_next)).any())
        return state, t_next, fired, pack, fr_due

    state, t_next, fired, pack, fr_due = jax.vmap(head)(
        state, params, slab, rank_in, use)

    def _ctx(pack, **extra):
        ctx = {"select_free": True, "scan": pack["scan"],
               "qcarry": pack["qcarry"], "free_pe": pack["free_pe"],
               "newly": pack["newly"], "n_comp_r": pack["n_comp_r"]}
        if "xfer_done" in pack:
            ctx["xfer_done"] = pack["xfer_done"]
        ctx.update(extra)
        return ctx

    zero_i = jnp.zeros(t_next.shape, jnp.int32)

    # ---- FAILURE + RECOVERY: cond on any lane having a stream due ----
    # (the due predicates are recomputed vs t_next exactly as
    # failure_apply/recovery_apply would -- COMPLETION touches neither
    # next_fail nor next_recover, so the head's snapshot is exact)
    def fr_taken(ops):
        state, params, t_next, pack = ops

        def one(state, params, t_next, pack):
            ctx = _ctx(pack)
            src = _make_sources(fleet, params, n_users, ctx)
            state = src[pos[des.K_FAILURE]].apply(state, t_next)
            state = src[pos[des.K_RECOVERY]].apply(state, t_next)
            return (state, dict(pack, qcarry=ctx["qcarry"]),
                    ctx[("count", des.K_FAILURE)],
                    ctx[("who", des.K_FAILURE)],
                    ctx[("count", des.K_RECOVERY)],
                    ctx[("who", des.K_RECOVERY)])

        return jax.vmap(one)(state, params, t_next, pack)

    def fr_skip(ops):
        state, params, t_next, pack = ops
        return state, pack, zero_i, zero_i, zero_i, zero_i

    state, pack, c_fail, w_fail, c_rec, w_rec = jax.lax.cond(
        jnp.any(fr_due), fr_taken, fr_skip,
        (state, params, t_next, pack))

    # ---- TRACE: static python gate + cond on any lane's cursor due ---
    # (no trace configured = the source is inert and the counts fall
    # through to the tail's fired-column default, which is always 0;
    # with a trace, the conservative horizon guarantees rows fire only
    # in committing supersteps -- exactly here -- and the ascending
    # fault times make the per-lane apply a bitwise no-op for lanes
    # whose cursor row is not yet due)
    if params.fault_time is not None:
        fired_tr = fired[:, pos[des.K_TRACE]]

        def trace_taken(ops):
            state, params, t_next, pack = ops

            def one(state, params, t_next, pack):
                ctx = _ctx(pack)
                src = _make_sources(fleet, params, n_users, ctx)
                state = src[pos[des.K_TRACE]].apply(state, t_next)
                return (state, dict(pack, qcarry=ctx["qcarry"]),
                        ctx[("count", des.K_TRACE)],
                        ctx[("who", des.K_TRACE)])

            return jax.vmap(one)(state, params, t_next, pack)

        def trace_skip(ops):
            state, params, t_next, pack = ops
            return state, pack, zero_i, zero_i

        state, pack, c_trace, w_trace = jax.lax.cond(
            jnp.any(fired_tr), trace_taken, trace_skip,
            (state, params, t_next, pack))

    # ---- RESERVATION: cond on any lane crossing a boundary -----------
    fired_resv = fired[:, pos[des.K_RESERVATION]]

    def resv_taken(ops):
        state, params, t_next, pack = ops

        def one(state, params, t_next, pack, f):
            ctx = _ctx(pack, fired_resv=f)
            src = _make_sources(fleet, params, n_users, ctx)
            state = src[pos[des.K_RESERVATION]].apply(state, t_next)
            return state, dict(pack, qcarry=ctx["qcarry"],
                               free_pe=ctx["free_pe"],
                               newly=ctx["newly"])

        return jax.vmap(one)(state, params, t_next, pack, fired_resv)

    state, pack = jax.lax.cond(
        jnp.any(fired_resv), resv_taken, lambda ops: (ops[0], ops[3]),
        (state, params, t_next, pack))

    # ---- MARKET + AUCTION: cond on any lane's pricing round firing ---
    # (both applies are pure functions of state + t_next with no ctx
    # traffic; their counts fall through to the tail's default wiring)
    fired_px = (fired[:, pos[des.K_MARKET]] |
                fired[:, pos[des.K_AUCTION]])

    def px_taken(ops):
        state, params, t_next = ops

        def one(state, params, t_next):
            src = _make_sources(fleet, params, n_users,
                                {"select_free": True})
            state = src[pos[des.K_MARKET]].apply(state, t_next)
            return src[pos[des.K_AUCTION]].apply(state, t_next)

        return jax.vmap(one)(state, params, t_next)

    state = jax.lax.cond(jnp.any(fired_px), px_taken,
                         lambda ops: ops[0], (state, params, t_next))

    # ---- NETWORK: static python gate (off = the source is inert) -----
    if net:
        def net_one(state, params, t_next, pack):
            ctx = _ctx(pack)
            src = _make_sources(fleet, params, n_users, ctx)
            state = src[pos[des.K_NETWORK]].apply(state, t_next)
            return (state, ctx[("count", des.K_NETWORK)],
                    ctx[("who", des.K_NETWORK)])

        state, c_net, w_net = jax.vmap(net_one)(state, params, t_next,
                                                pack)

    # ---- RETURN: always hot (it is what speculation feeds on) --------
    def ret_one(state, params, t_next, pack):
        ctx = _ctx(pack)
        src = _make_sources(fleet, params, n_users, ctx)
        state = src[pos[des.K_RETURN]].apply(state, t_next)
        return (state, ctx[("count", des.K_RETURN)],
                ctx[("who", des.K_RETURN)])

    state, c_ret, w_ret = jax.vmap(ret_one)(state, params, t_next, pack)

    # ---- BROKER: cond on any lane's poll firing ----------------------
    # (arr_pre -- the ARRIVAL > BROKER admission tie-break -- is
    # recorded lane-batched before the cond, exactly what broker_apply
    # snapshots first)
    arr_pre = ((state.g.status == IN_TRANSIT) &
               (state.g.t_event <= t_next[:, None]))
    fired_b = fired[:, pos[des.K_BROKER]]

    def broker_taken(ops):
        state, params, t_next, pack = ops

        def one(state, params, t_next, pack, f):
            ctx = _ctx(pack, fired_b=f)
            src = _make_sources(fleet, params, n_users, ctx)
            return src[pos[des.K_BROKER]].apply(state, t_next)

        return jax.vmap(one)(state, params, t_next, pack, fired_b)

    state = jax.lax.cond(
        jnp.any(fired_b), broker_taken, lambda ops: ops[0],
        (state, params, t_next, pack))

    # ---- ARRIVAL: cond on any in-transit gridlet due post-broker -----
    arr_due_any = jnp.any((state.g.status == IN_TRANSIT) &
                          (state.g.t_event <= t_next[:, None]))

    def arr_taken(ops):
        state, params, t_next, pack, pre = ops

        def one(state, params, t_next, pack, pre):
            ctx = _ctx(pack, arr_pre=pre)
            src = _make_sources(fleet, params, n_users, ctx)
            state = src[pos[des.K_ARRIVAL]].apply(state, t_next)
            return (state, dict(pack, qcarry=ctx["qcarry"],
                                newly=ctx["newly"]),
                    ctx[("count", des.K_ARRIVAL)],
                    ctx[("who", des.K_ARRIVAL)])

        return jax.vmap(one)(state, params, t_next, pack, pre)

    def arr_skip(ops):
        state, params, t_next, pack, pre = ops
        return state, pack, zero_i, zero_i

    state, pack, c_arr, w_arr = jax.lax.cond(
        arr_due_any, arr_taken, arr_skip,
        (state, params, t_next, pack, arr_pre))

    # CALENDAR applies as the identity: nothing to run.

    # ---- tail (vmapped): allocation, bookkeeping, the next slab ------
    c_by = {des.K_COMPLETION: pack["count_comp"],
            des.K_FAILURE: c_fail, des.K_RECOVERY: c_rec,
            des.K_RETURN: c_ret, des.K_ARRIVAL: c_arr}
    w_by = {des.K_COMPLETION: pack["who_comp"],
            des.K_FAILURE: w_fail, des.K_RECOVERY: w_rec,
            des.K_RETURN: w_ret, des.K_ARRIVAL: w_arr}
    if net:
        c_by[des.K_NETWORK] = c_net
        w_by[des.K_NETWORK] = w_net
    if params.fault_time is not None:
        c_by[des.K_TRACE] = c_trace
        w_by[des.K_TRACE] = w_trace
    no_who = jnp.full(t_next.shape, -1, jnp.int32)
    counts = jnp.stack(
        [c_by.get(k, fired[:, i].astype(jnp.int32))
         for i, k in enumerate(des.PRIORITY_ORDER)], axis=1)
    whos = jnp.stack([w_by.get(k, no_who)
                      for k in des.PRIORITY_ORDER], axis=1)
    fired_int = (fired[:, pos[des.K_FAILURE]]
                 | fired[:, pos[des.K_RECOVERY]]
                 | fired[:, pos[des.K_TRACE]]
                 | fired[:, pos[des.K_RESERVATION]])

    def tail(state, params, t_next, fired_int, pack, counts, whos, tel):
        ctx = _ctx(pack)
        state = _alloc_newly(state, ctx, n_resources, r_pad)
        if _net_on(state):
            state = _enqueue_new_transfers(state, params, n_resources,
                                           r_pad, select_free=True)
        kinds = jnp.asarray(des.PRIORITY_ORDER, jnp.int32)
        state, finished = _bookkeep(state, fleet, params, n_users,
                                    kinds, counts, whos, t_next)
        state = replace(state, n_steps=state.n_steps + 1)
        tel = telemetry_mod.record(tel, state, fleet, kinds, counts,
                                   t_next, spec=False)
        slab = _slab_after(state, ctx, ctx["scan"], fired_int, fleet,
                           n_resources, r_pad)
        return state, slab, finished, tel

    return jax.vmap(tail)(state, params, t_next, fired_int, pack,
                          counts, whos, tel)


def _step_sweep_lanes(state, fleet, params, n_users, batch, slab,
                      alive, tel=None):
    """One lane-batched while-loop iteration: a piece-wise committing
    superstep (:func:`_commit_lanes`) plus up to ``batch - 1``
    speculative micro-supersteps -- run in a ``while_loop`` that exits
    as soon as EVERY lane's micro declined (a declined
    :func:`_sweep_micro` is a bitwise no-op including its counters, so
    skipping the remaining iterations is exact).  ``alive`` seeds the
    per-lane micro gates so frozen (finished) lanes never count toward
    the any-lane exit test."""
    state, slab, finished, tel = _commit_lanes(state, fleet, params,
                                               n_users, slab, tel)
    if batch <= 1:
        return state, slab, finished, tel
    t_safe = jax.vmap(
        lambda s, p: _speculation_horizon(s, fleet, p, n_users))(
            state, params)

    def cond(c):
        i, _, fire, _, _, _ = c
        return (i < batch - 1) & jnp.any(fire)

    def body(c):
        i, s, fire, slab, fin, tel = c
        s, fire, slab, fin, tel = jax.vmap(
            lambda s, p, t, sl, f, a, tl: _sweep_micro(
                s, fleet, p, n_users, t, sl, f, a, tl))(
                    s, params, t_safe, slab, fin, fire, tel)
        return i + 1, s, fire, slab, fin, tel

    _, state, _, slab, finished, tel = jax.lax.while_loop(
        cond, body,
        (jnp.asarray(0, jnp.int32), state, alive, slab, finished, tel))
    return state, slab, finished, tel


def run_sweep_lanes(gridlets, fleet, params: SimParams, n_users: int,
                    max_events: int, max_jobs: int | None = None,
                    batch: int = DEFAULT_BATCH, net_cap: int = 0,
                    telemetry: int | None = None) -> SimResult:
    """The lane-batched sweep engine: run one scenario per lane of
    ``params`` (every leaf carries a leading lane axis L, e.g. from
    ``vmap(_scenario_point)``), with the lane axis INSIDE the while
    loop rather than a vmap outside it.

    ``vmap(run_sweep)`` can never skip work a single lane needs: under
    vmap every ``lax.cond`` lowers to a both-branches select, which is
    why the select-free path exists at all -- but masked no-ops still
    *execute*.  Lifting the lane axis into the loop body restores real
    branches at the batch level: the reseed sort, the broker poll and
    the failure/reservation/arrival applies run only on iterations
    where at least one lane needs them (:func:`_commit_lanes`), and
    the speculation loop exits early once every lane declines
    (:func:`_step_sweep_lanes`).  The loop itself replicates the
    vmap-of-while lowering by hand -- body applied to every lane, then
    a per-lane freeze (:func:`_tree_where`) -- so results are
    bit-for-bit identical to ``vmap(run_sweep)`` and to the reference
    path (asserted by tests/test_sweep_engine.py); only the "how"
    counters may pack supersteps differently.

    Unjitted, like :func:`run_sweep`: callers jit (or ``shard_map``)
    around it -- see ``simulation.sweep`` / ``simulation.sweep_sharded``.
    """
    def mk(p):
        s = init_state(gridlets, fleet, n_users, max_jobs=max_jobs,
                       params=p, net_cap=net_cap)
        _, fin0 = _user_flags(s, p, fleet, n_users)
        tel0 = (telemetry_mod.init(telemetry, fleet.r)
                if telemetry else None)
        return s, _empty_slab(s), fin0, tel0

    state, slab, fin, tel = jax.vmap(mk)(params)

    def cond(c):
        state, _, fin, _ = c
        return jnp.any(jax.vmap(_continue, in_axes=(0, 0, None))(
            state, fin, max_events))

    def body(c):
        state, slab, fin, tel = c
        alive = jax.vmap(_continue, in_axes=(0, 0, None))(
            state, fin, max_events)
        s2, sl2, f2, tl2 = _step_sweep_lanes(state, fleet, params,
                                             n_users, batch, slab,
                                             alive, tel)
        return (_tree_where(alive, s2, state),
                _tree_where(alive, sl2, slab),
                _tree_where(alive, f2, fin),
                _tree_where(alive, tl2, tel))

    state, slab, fin, tel = jax.lax.while_loop(
        cond, body, (state, slab, fin, tel))
    return jax.vmap(_finalize)(state, tel)


def run_direct(gridlets, fleet, resource_idx, dispatch_time,
               max_events: int, reservations=None,
               batch: int = DEFAULT_BATCH, net_cap: int = 0,
               baud_rate=None, bg_flows=None) -> SimResult:
    """Broker-less mode: Gridlets are pre-routed into the fleet and the
    brokers stay inert -- the paper's Table 1 / Figs 9 and 12 scenario
    (arrivals straight into one resource).

    Parameters
    ----------
    gridlets : GridletBatch
        The jobs to run; status/resource/t_event are overwritten here.
    fleet : resource.Fleet
        Resource tables (policies, PEs, rates, load calendars).
    resource_idx : int or i32[N]
        Destination resource per gridlet (broadcast from a scalar).
    dispatch_time : float or f32[N]
        Instant each gridlet enters the network; it arrives after the
        input-file transfer delay at the resource's baud rate -- or,
        with the network subsystem on, after its fair share of the
        contended link has moved the payload.
    max_events : int
        Total-superstep bound (committing + speculative, not raw
        events) -- batch-independent.
    reservations : optional
        Advance-reservation windows -- a ReservationBook, an iterable of
        ``(resource, pes, start, end)`` tuples, or the 4-array table --
        blocking PE capacity exactly as in the broker-driven mode.
    batch : int, static
        Superstep batching factor k (see :func:`step_batched`); results
        are bit-for-bit identical for every k, k=1 disables speculation.
    net_cap : int, static
        Transfer slots per resource link for the contention-aware
        network subsystem; 0 (default) keeps the analytic links.
    baud_rate, bg_flows : optional
        Network-subsystem link overrides (default: ``fleet.baud_rate``
        and zero background flows); only consulted when ``net_cap > 0``.
    """
    from .types import replace
    n = gridlets.n
    r = jnp.broadcast_to(jnp.asarray(resource_idx, jnp.int32), (n,))
    t0 = jnp.broadcast_to(jnp.asarray(dispatch_time, jnp.float32), (n,))
    link_baud = fleet.baud_rate if baud_rate is None else \
        jnp.broadcast_to(jnp.asarray(baud_rate, jnp.float32), (fleet.r,))
    if net_cap:
        # Contending payloads hold their network-ENTRY instant in
        # t_event until the NETWORK source tables them at exactly t0;
        # everything else is instantaneous/never under the analytic
        # term at the subsystem's link rate.
        tabled = network.link_tabled(gridlets.in_bytes, link_baud[r])
        t_ev = jnp.where(
            tabled, t0,
            t0 + network.transfer_delay(gridlets.in_bytes, link_baud[r]))
    else:
        t_ev = t0 + network.transfer_delay(gridlets.in_bytes,
                                           fleet.baud_rate[r])
    g = replace(gridlets,
                status=jnp.full((n,), IN_TRANSIT, jnp.int32),
                resource=r, assigned=r, t_event=t_ev)
    params = default_params(jnp.asarray(-1.0), jnp.asarray(0.0),
                            jnp.asarray(0), 1, fleet.r,
                            reservations=reservations,  # brokers inert
                            link_baud=link_baud, bg_flows=bg_flows)
    return _run_jit(g, fleet, params, 1, max_events, None, batch,
                    net_cap, None)
