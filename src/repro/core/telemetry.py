"""Speculation-safe on-device telemetry: the metrics ring.

The paper's deliverables are *time series* -- per-resource utilisation
curves (Figs 9/12), spend/time breakdowns, trace tables -- but the
engine's loop only surfaces end-of-run scalars.  This module adds a
fixed-capacity metrics ring carried *alongside* ``SimState`` through
every engine loop (``run`` / ``run_inner`` / ``run_sweep`` /
``run_sweep_lanes``): one row per applied superstep (committing or
speculative), written with the same masked ``.at[pos].set(...,
mode="drop")`` idiom as the event-trace ring.

The hard invariant -- **telemetry never feeds back into simulation
arithmetic** -- is structural, not behavioural:

* :func:`record` is a *pure function of the post-superstep state* (plus
  the superstep's event counts); it returns a new ``Telemetry`` and
  nothing else.  No source, no advance, no bookkeeping ever reads a
  ``Telemetry`` field.
* The ring rides the loop carry as a separate element next to
  ``(state, slab, finished)``.  When telemetry is off the element is
  ``None`` -- an *empty pytree* -- so the traced program is exactly the
  pre-telemetry carry: zero extra arrays, zero extra ops.

Consequently telemetry-on runs are bitwise identical on
``SimState``/``SimResult`` to telemetry-off runs (asserted across the
fuzz corpus by tests/test_scenario_fuzz.py and gated per bench scenario
by ``telemetry_identical`` in CI).

Ring semantics mirror the event-trace ring: capacity is static, writes
past it are dropped (``mode="drop"``), and ``n`` keeps counting -- so
``n > cap`` detects truncation instead of silently wrapping.  Exporters
(:func:`to_jsonl`, :func:`to_chrome_trace`) and the paper-figure
post-processor (:func:`utilisation`) are host-side numpy; schema in
docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from . import des
from .types import QUEUED, RUNNING, pytree_dataclass

#: Human-readable names for the des.K_* codes (bit positions of the
#: ``kinds`` fired-kind bitmask column).
KIND_NAMES = {
    des.K_COMPLETION: "COMPLETION",
    des.K_FAILURE: "FAILURE",
    des.K_RECOVERY: "RECOVERY",
    des.K_TRACE: "TRACE",
    des.K_RESERVATION: "RESERVATION",
    des.K_MARKET: "MARKET",
    des.K_AUCTION: "AUCTION",
    des.K_NETWORK: "NETWORK",
    des.K_RETURN: "RETURN",
    des.K_ARRIVAL: "ARRIVAL",
    des.K_CALENDAR: "CALENDAR",
    des.K_BROKER: "BROKER",
}

#: JSONL row schema: key -> (kind, doc).  The golden schema test pins
#: this exact key set; extend it together with ``record`` and the docs.
SCHEMA = {
    "step": ("int", "row index (ring position)"),
    "t": ("float", "simulation time of the superstep commit"),
    "kinds": ("list[str]", "event kinds fired this superstep"),
    "events": ("int", "events applied this superstep"),
    "util": ("list[float]", "per-resource busy-PE fraction [R]"),
    "queue": ("list[int]", "per-resource QUEUED gridlets [R]"),
    "net_bytes": ("float", "bytes in flight on the fair-share links"),
    "price": ("list[float]", "posted per-resource G$/MI [R]"),
    "spent": ("float", "cumulative committed spend (all users)"),
    "depth": ("int", "slab depth: 0 = committing superstep, d = d-th "
                     "speculative micro-step of its slab"),
}


@pytree_dataclass
class Telemetry:
    """The on-device metrics ring (all leaves; capacity is static).

    One row per *applied* superstep; declined micro-steps write
    nothing (their masked row position lands past the ring and drops).
    ``n`` counts every applied superstep, written or dropped;
    ``cur_depth`` is the recorder's own slab-position carry (how many
    speculative micro-steps since the last commit) -- it never reaches
    the simulation.
    """
    n: jax.Array          # i32 rows recorded (monotonic; > cap = truncated)
    cur_depth: jax.Array  # i32 slab-depth carry for the next row
    t: jax.Array          # f32[cap] superstep commit instant
    kinds: jax.Array      # i32[cap] fired-kind bitmask (bit = des.K_*)
    events: jax.Array     # i32[cap] events applied this superstep
    util: jax.Array       # f32[cap, R] busy-PE fraction per resource
    queue: jax.Array      # i32[cap, R] QUEUED gridlets per resource
    net: jax.Array        # f32[cap] bytes in flight on modelled links
    price: jax.Array      # f32[cap, R] posted G$/MI per resource
    spent: jax.Array      # f32[cap] cumulative spend, summed over users
    depth: jax.Array      # i32[cap] slab depth (0 = committing superstep)


def init(cap: int, n_resources: int) -> Telemetry:
    """An empty ring of static capacity ``cap`` for an R-resource
    fleet.  Unwritten rows keep the sentinels (t = inf, kinds = 0)."""
    cap = int(cap)
    if cap <= 0:
        raise ValueError(f"telemetry capacity must be positive: {cap}")
    r = int(n_resources)
    return Telemetry(
        n=jnp.asarray(0, jnp.int32),
        cur_depth=jnp.asarray(0, jnp.int32),
        t=jnp.full((cap,), jnp.inf, jnp.float32),
        kinds=jnp.zeros((cap,), jnp.int32),
        events=jnp.zeros((cap,), jnp.int32),
        util=jnp.zeros((cap, r), jnp.float32),
        queue=jnp.zeros((cap, r), jnp.int32),
        net=jnp.zeros((cap,), jnp.float32),
        price=jnp.zeros((cap, r), jnp.float32),
        spent=jnp.zeros((cap,), jnp.float32),
        depth=jnp.zeros((cap,), jnp.int32),
    )


def record(tel, state, fleet, kinds, counts, t_next, *, spec,
           gate=None):
    """Append one metrics row for an applied superstep; ``tel is
    None`` is the static off-gate (returns None, traces nothing).

    Pure function of the *post-apply* state: utilisation / queue depth
    / in-flight bytes / prices / spend are read back from ``state``
    rather than threaded from the superstep's internals, so every
    engine path (commit, speculative micro, masked sweep micro,
    lane-batched tail) records through identical arithmetic and the
    recorder cannot perturb -- or depend on -- how the superstep was
    produced.

    ``kinds``/``counts`` are the superstep's aligned per-source event
    vectors (exactly what ``_bookkeep`` traced); ``spec`` (static) marks
    speculative micro-steps for the slab-depth column; ``gate``
    (optional bool) masks the write -- default: a row is written iff
    any event applied, which keeps declined/masked micro-steps rowless.
    """
    if tel is None:
        return None
    from .types import replace
    cap = tel.t.shape[0]
    r = tel.util.shape[1]
    if gate is None:
        gate = jnp.sum(counts) > 0
    g = state.g
    res = jnp.clip(g.resource, 0, r - 1)
    n_run = jnp.zeros((r,), jnp.float32).at[res].add(
        (g.status == RUNNING).astype(jnp.float32))
    n_q = jnp.zeros((r,), jnp.int32).at[res].add(
        (g.status == QUEUED).astype(jnp.int32))
    npe = fleet.num_pe.astype(jnp.float32)
    util = jnp.minimum(n_run, npe) / jnp.maximum(npe, 1.0)
    bitmask = jnp.sum(jnp.where(
        counts > 0, jnp.left_shift(jnp.int32(1), kinds.astype(jnp.int32)),
        0)).astype(jnp.int32)
    depth_row = tel.cur_depth + 1 if spec else jnp.asarray(0, jnp.int32)
    # Masked ring write: the same drop idiom as the event-trace ring.
    pos = jnp.where(gate, tel.n, cap)
    return replace(
        tel,
        n=tel.n + gate.astype(jnp.int32),
        cur_depth=jnp.where(gate, depth_row, tel.cur_depth),
        t=tel.t.at[pos].set(t_next, mode="drop"),
        kinds=tel.kinds.at[pos].set(bitmask, mode="drop"),
        events=tel.events.at[pos].set(
            jnp.sum(counts).astype(jnp.int32), mode="drop"),
        util=tel.util.at[pos].set(util, mode="drop"),
        queue=tel.queue.at[pos].set(n_q, mode="drop"),
        net=tel.net.at[pos].set(jnp.sum(state.link_rem), mode="drop"),
        price=tel.price.at[pos].set(state.price, mode="drop"),
        spent=tel.spent.at[pos].set(jnp.sum(state.spent), mode="drop"),
        depth=tel.depth.at[pos].set(depth_row, mode="drop"),
    )


# ----------------------------------------------------------------------
# Host-side exporters / post-processors (numpy; never traced)
# ----------------------------------------------------------------------

def _kind_names(bitmask: int) -> list:
    return [name for k, name in sorted(KIND_NAMES.items())
            if bitmask & (1 << k)]


def rows(tel) -> list:
    """The ring as a list of plain-python dicts (SCHEMA keys), valid
    rows only.  Rows past capacity were dropped at write time; the
    caller can detect truncation via ``n_recorded(tel) > len(rows)``."""
    import numpy as np
    n = min(int(np.asarray(tel.n)), tel.t.shape[0])
    t = np.asarray(tel.t)[:n]
    kinds = np.asarray(tel.kinds)[:n]
    events = np.asarray(tel.events)[:n]
    util = np.asarray(tel.util)[:n]
    queue = np.asarray(tel.queue)[:n]
    net = np.asarray(tel.net)[:n]
    price = np.asarray(tel.price)[:n]
    spent = np.asarray(tel.spent)[:n]
    depth = np.asarray(tel.depth)[:n]
    out = []
    for i in range(n):
        out.append({
            "step": i,
            "t": float(t[i]),
            "kinds": _kind_names(int(kinds[i])),
            "events": int(events[i]),
            "util": [float(x) for x in util[i]],
            "queue": [int(x) for x in queue[i]],
            "net_bytes": float(net[i]),
            "price": [float(x) for x in price[i]],
            "spent": float(spent[i]),
            "depth": int(depth[i]),
        })
    return out


def n_recorded(tel) -> int:
    """Total applied supersteps the recorder saw (written + dropped)."""
    import numpy as np
    return int(np.asarray(tel.n))


def truncated(tel) -> bool:
    """True when applied supersteps outran the ring capacity (later
    rows were dropped; size ``cap`` past the run's superstep count to
    keep the series complete)."""
    return n_recorded(tel) > tel.t.shape[0]


def to_jsonl(tel, path) -> int:
    """Write the ring as JSON Lines (one SCHEMA object per row).
    Returns the number of rows written."""
    rws = rows(tel)
    with open(path, "w") as f:
        for row in rws:
            f.write(json.dumps(row) + "\n")
    return len(rws)


def to_chrome_trace(tel, path, pid: str = "gridsim") -> int:
    """Write the ring in Chrome ``trace_event`` JSON (load in
    chrome://tracing or Perfetto).  Per-resource utilisation, queue
    depth, prices, spend and in-flight bytes render as counter tracks
    ("ph": "C"); each superstep's fired kinds render as instant events
    ("ph": "i").  Timestamps are simulation seconds scaled to
    microseconds.  Returns the number of trace events written."""
    events = []
    for row in rows(tel):
        ts = row["t"] * 1e6
        events.append({"name": "+".join(row["kinds"]) or "none",
                       "ph": "i", "ts": ts, "pid": pid, "tid": "events",
                       "s": "t", "args": {"events": row["events"],
                                          "depth": row["depth"]}})
        events.append({"name": "utilisation", "ph": "C", "ts": ts,
                       "pid": pid,
                       "args": {f"r{i}": u
                                for i, u in enumerate(row["util"])}})
        events.append({"name": "queue_depth", "ph": "C", "ts": ts,
                       "pid": pid,
                       "args": {f"r{i}": q
                                for i, q in enumerate(row["queue"])}})
        events.append({"name": "price", "ph": "C", "ts": ts, "pid": pid,
                       "args": {f"r{i}": p
                                for i, p in enumerate(row["price"])}})
        events.append({"name": "economy", "ph": "C", "ts": ts,
                       "pid": pid, "args": {"spent": row["spent"]}})
        events.append({"name": "network", "ph": "C", "ts": ts,
                       "pid": pid,
                       "args": {"in_flight_bytes": row["net_bytes"]}})
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)


def utilisation(tel):
    """The paper's per-resource utilisation series: ``(t [K], util
    [K, R])`` numpy arrays -- busy-PE fraction per resource sampled at
    every applied superstep (piecewise-constant between samples: the
    engine advances work at constant Fig 8 rates between events, so
    ``util[i]`` holds over ``[t[i], t[i+1])`` exactly).

    Time-weighted means (the single-number utilisation figures):
    ``numpy.sum(util[:-1] * numpy.diff(t)[:, None], 0) / (t[-1] - t[0])``.
    """
    import numpy as np
    n = min(int(np.asarray(tel.n)), tel.t.shape[0])
    return np.asarray(tel.t)[:n], np.asarray(tel.util)[:n]
