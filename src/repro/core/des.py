"""Discrete-event primitives: the array calendar (the SimJava substitute,
paper 3.2.1) and the :class:`EventSource` protocol the superstep engine
(engine.py) enumerates its event kinds through.

Array calendar
--------------
SimJava runs one Java thread per entity and a central timestamp-ordered
future-event queue; ``sim_schedule`` / ``sim_hold`` / ``sim_wait`` suspend
threads.  None of that exists under jit, so the toolkit's second layer is
re-founded on a fixed-capacity struct-of-arrays calendar:

  * ``schedule``   == sim_schedule: write an event row into a free slot.
  * ``pop_next``   == Sim_system advancing the clock: masked argmin on the
                      time column (vector-unit friendly O(C) instead of a
                      pointer heap; C is small and the reduction fuses).
  * ``sim_hold``   == scheduling an event to yourself at t+dt.
  * ``sim_wait``   == simply handling your next popped event.

The specialised engine (engine.py) keeps *forecast* events implicit --
recomputed from state instead of queued -- which is how it sidesteps the
paper's stale-internal-event discard rule (section 3.4); its superstep
loop additionally pops and applies *every* event sharing the earliest
timestamp in one iteration, where this calendar's ``pop_next`` stays
strictly one-event-at-a-time (the paper's Fig 2 semantics).  This
calendar is the general-purpose primitive for user-defined entities and
tests.  ``EventQueue.overflow`` counts events dropped because the
calendar was full -- callers size capacity so it stays 0 (asserted in
tests).

EventSource protocol
--------------------
The engine does not hard-code its event kinds; it takes the min over the
``next_time`` of every registered :class:`EventSource` and applies every
source due at the earliest timestamp in one superstep.  A source is any
object with

  * ``kind``  -- its trace code (the ``K_*`` constants below), which is
    also its rank in the fixed tie-break priority order
    ``PRIORITY_ORDER``:

        COMPLETION > FAILURE > RECOVERY > RESERVATION > RETURN
                   > ARRIVAL > CALENDAR_STEP > BROKER

  * ``next_time(state) -> f32[]`` -- the earliest pending instant of
    this kind (+inf when none); must be jit-traceable.
  * ``apply(state, now) -> state`` -- apply *every* event of this kind
    with time <= ``now``; must be jit-traceable and the identity when
    nothing is due (zero-rate sources then cost nothing and perturb
    no result -- the engine relies on this for bit-for-bit
    reproducibility of scenarios that do not use a source).
  * ``horizon(state, t_max) -> f32[]`` -- the **speculation-safety
    hook** (optional; defaults to ``next_time(state)``).  The engine's
    k-step batched superstep (engine.step_batched) speculatively
    applies several consecutive event timestamps inside one while-loop
    iteration; ``horizon`` must return a lower bound on every instant
    at which this source could fire -- or otherwise invalidate
    speculation -- during ``(state.t, t_max]``, *given that only
    speculation-safe events apply in between*.  The default (the
    source's own ``next_time``) is always safe because the batched path
    cuts speculation strictly before the earliest horizon: the source
    is then guaranteed to be applied by the ordinary superstep
    machinery, never skipped over.  A source whose firings commute with
    speculation (COMPLETION and RETURN: they change no other source's
    pending instant to an earlier value) overrides it with
    :func:`no_interference` to keep the horizon open.

:class:`FnSource` is the plain-closure implementation the engine and
user extensions build sources from; see docs/ARCHITECTURE.md for the
"add a new event source" walkthrough (including the ``horizon`` hook)
and docs/PERFORMANCE.md for the speculation-horizon safety argument.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .types import INF, pytree_dataclass

# ----------------------------------------------------------------------
# EventSource protocol: trace codes double as tie-break priorities.
# K_COMPLETION..K_BROKER keep the original 4-kind trace numbering so
# pre-refactor golden traces replay unchanged.
# ----------------------------------------------------------------------
K_COMPLETION = 0    # forecast completion materialises
K_RETURN = 1        # processed Gridlet reaches its broker
K_ARRIVAL = 2       # dispatched Gridlet reaches its resource
K_BROKER = 3        # periodic broker scheduling event
K_FAILURE = 4       # resource goes down (MTBF stream)
K_RECOVERY = 5      # resource comes back up (MTTR stream)
K_RESERVATION = 6   # advance-reservation window opens/closes
K_CALENDAR = 7      # local load calendar step (weekend boundary)

# Tie-break order among sources due at the same instant.  Application
# order inside a superstep differs in exactly one place: the engine
# applies BROKER before ARRIVAL so the broker's zero-delay dispatches
# arrive within the same superstep, while ARRIVAL keeps semantic
# priority (pre-broker arrivals hold admission precedence -- see
# engine._apply_arrivals).
PRIORITY_ORDER = (K_COMPLETION, K_FAILURE, K_RECOVERY, K_RESERVATION,
                  K_RETURN, K_ARRIVAL, K_CALENDAR, K_BROKER)


def no_interference(state, t_max) -> jax.Array:
    """``horizon_fn`` for speculation-safe sources: never cuts the
    speculation horizon.  Correct only for sources whose firings cannot
    pull any *other* source's pending instant earlier (COMPLETION and
    RETURN satisfy this; see docs/PERFORMANCE.md for the argument)."""
    return INF


@dataclasses.dataclass(frozen=True)
class FnSource:
    """An :class:`EventSource` built from closures.

    ``next_time``/``apply`` close over whatever static context they need
    (fleet arrays, params, the engine's per-superstep scratch dict);
    the engine only sees the uniform protocol.  ``horizon_fn`` is
    optional: when omitted, ``horizon`` falls back to ``next_time`` --
    the conservative choice that makes any firing of this source cut
    the k-step speculation horizon.
    """
    kind: int
    name: str
    next_time_fn: Callable
    apply_fn: Callable
    horizon_fn: Callable | None = None

    def next_time(self, state) -> jax.Array:
        return self.next_time_fn(state)

    def apply(self, state, now):
        return self.apply_fn(state, now)

    def horizon(self, state, t_max) -> jax.Array:
        """Earliest instant in ``(state.t, t_max]`` at which this source
        could interfere with speculative multi-timestamp batching; +inf
        when it cannot.  Defaults to ``next_time`` (conservative)."""
        if self.horizon_fn is None:
            return self.next_time_fn(state)
        return self.horizon_fn(state, t_max)


@pytree_dataclass
class EventQueue:
    time: jax.Array     # f32[C], INF = free slot
    src: jax.Array      # i32[C]
    dst: jax.Array      # i32[C]
    tag: jax.Array      # i32[C]
    data: jax.Array     # f32[C, K]
    seq: jax.Array      # i32[C] FIFO tiebreak among equal timestamps
    next_seq: jax.Array  # i32[]
    overflow: jax.Array  # i32[] events dropped on a full calendar

    @property
    def capacity(self) -> int:
        return self.time.shape[0]


def make_queue(capacity: int, payload: int = 1) -> EventQueue:
    return EventQueue(
        time=jnp.full((capacity,), INF, jnp.float32),
        src=jnp.zeros((capacity,), jnp.int32),
        dst=jnp.zeros((capacity,), jnp.int32),
        tag=jnp.zeros((capacity,), jnp.int32),
        data=jnp.zeros((capacity, payload), jnp.float32),
        seq=jnp.zeros((capacity,), jnp.int32),
        next_seq=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
    )


def schedule(q: EventQueue, time, src, dst, tag, data=None) -> EventQueue:
    """sim_schedule: place one event in the first free slot.

    A full calendar DROPS the event and increments ``overflow`` instead
    of silently overwriting a live slot (the previous behaviour --
    argmax over an all-False free mask returned slot 0).  Callers size
    the queue so this never happens; tests assert overflow == 0.
    """
    free = ~jnp.isfinite(q.time)
    has_free = free.any()
    slot = jnp.argmax(free)  # first free slot (garbage when full)
    data = jnp.zeros((q.data.shape[1],), jnp.float32) if data is None \
        else jnp.asarray(data, jnp.float32).reshape(q.data.shape[1])

    def put(new, old):
        return jnp.where(has_free, new, old)

    return EventQueue(
        time=put(q.time.at[slot].set(jnp.asarray(time, jnp.float32)),
                 q.time),
        src=put(q.src.at[slot].set(jnp.asarray(src, jnp.int32)), q.src),
        dst=put(q.dst.at[slot].set(jnp.asarray(dst, jnp.int32)), q.dst),
        tag=put(q.tag.at[slot].set(jnp.asarray(tag, jnp.int32)), q.tag),
        data=put(q.data.at[slot].set(data), q.data),
        seq=put(q.seq.at[slot].set(q.next_seq), q.seq),
        next_seq=q.next_seq + 1,
        overflow=q.overflow + (~has_free).astype(jnp.int32),
    )


def peek_time(q: EventQueue) -> jax.Array:
    return q.time.min()


def size(q: EventQueue) -> jax.Array:
    return jnp.isfinite(q.time).sum()


def pop_next(q: EventQueue):
    """Remove + return the earliest event (FIFO among ties).

    Returns (queue', (time, src, dst, tag, data, valid)).  ``valid`` is
    False when the calendar is empty (the END_OF_SIMULATION condition).
    """
    # Lexicographic (time, seq) argmin via a composite penalty on seq.
    tmin = q.time.min()
    at_min = q.time == tmin
    seq_key = jnp.where(at_min, q.seq, jnp.iinfo(jnp.int32).max)
    slot = jnp.argmin(seq_key)
    valid = jnp.isfinite(tmin)
    ev = (q.time[slot], q.src[slot], q.dst[slot], q.tag[slot],
          q.data[slot], valid)
    q2 = EventQueue(
        time=q.time.at[slot].set(INF), src=q.src, dst=q.dst, tag=q.tag,
        data=q.data, seq=q.seq, next_seq=q.next_seq, overflow=q.overflow)
    return q2, ev


def cancel(q: EventQueue, predicate) -> EventQueue:
    """Discard events matching a mask -- the paper's 'discard stale
    internal events' rule for user-defined entities."""
    mask = predicate(q)
    return EventQueue(
        time=jnp.where(mask, INF, q.time), src=q.src, dst=q.dst,
        tag=q.tag, data=q.data, seq=q.seq, next_seq=q.next_seq,
        overflow=q.overflow)
