"""Discrete-event primitives: the array calendar (the SimJava substitute,
paper 3.2.1) and the :class:`EventSource` protocol the superstep engine
(engine.py) enumerates its event kinds through.

Array calendar
--------------
SimJava runs one Java thread per entity and a central timestamp-ordered
future-event queue; ``sim_schedule`` / ``sim_hold`` / ``sim_wait`` suspend
threads.  None of that exists under jit, so the toolkit's second layer is
re-founded on a fixed-capacity struct-of-arrays calendar:

  * ``schedule``   == sim_schedule: write an event row into a free slot.
  * ``pop_next``   == Sim_system advancing the clock: masked argmin on the
                      time column (vector-unit friendly O(C) instead of a
                      pointer heap; C is small and the reduction fuses).
  * ``sim_hold``   == scheduling an event to yourself at t+dt.
  * ``sim_wait``   == simply handling your next popped event.

The specialised engine (engine.py) keeps *forecast* events implicit --
recomputed from state instead of queued -- which is how it sidesteps the
paper's stale-internal-event discard rule (section 3.4); its superstep
loop additionally pops and applies *every* event sharing the earliest
timestamp in one iteration, where this calendar's ``pop_next`` stays
strictly one-event-at-a-time (the paper's Fig 2 semantics).  This
calendar is the general-purpose primitive for user-defined entities and
tests.  ``EventQueue.overflow`` counts events dropped because the
calendar was full -- callers size capacity so it stays 0 (asserted in
tests).

EventSource protocol
--------------------
The engine does not hard-code its event kinds; every registered
:class:`EventSource` exposes its pending instants as an **array of
candidate times**, the engine concatenates all of them, and one fused
``kernels.ops.event_frontier`` pass answers "what fires next, who, and
how far is speculation safe" per superstep.  A source is any object
with

  * ``kind``  -- its trace code (the ``K_*`` constants below), which is
    also its rank in the fixed tie-break priority order
    ``PRIORITY_ORDER``:

        COMPLETION > FAILURE > RECOVERY > RESERVATION > MARKET
                   > AUCTION > NETWORK > RETURN > ARRIVAL
                   > CALENDAR_STEP > BROKER

  * ``candidates(state) -> f32[C]`` -- the source's pending instants as
    a fixed-shape vector of absolute times, ``+inf`` where nothing is
    pending (``C`` may be 0 and may differ per source: the failure
    source exposes one stream per resource, RETURN/ARRIVAL one slot per
    gridlet, the broker a single scalar).  Must be jit-traceable.  The
    engine takes the min *through the frontier op*, so a source never
    needs to pre-reduce -- exposing the raw per-stream instants is what
    lets the frontier treat streams individually (source-aware
    horizons below).
  * ``next_time(state) -> f32[]`` -- thin wrapper: the min over
    ``candidates`` (+inf when none).  Kept for tests, user entities and
    any caller that wants one source's scalar view; the engine hot path
    does not call it.
  * ``apply(state, now) -> state`` -- apply *every* event of this kind
    with time <= ``now``; must be jit-traceable and the identity when
    nothing is due (zero-rate sources then cost nothing and perturb
    no result -- the engine relies on this for bit-for-bit
    reproducibility of scenarios that do not use a source).
  * ``horizon(state, t_max) -> f32[]`` / ``horizon_candidates(state) ->
    f32[H]`` -- the **speculation-safety hook** (optional; defaults to
    ``candidates``).  The engine's k-step batched superstep
    (engine.step_batched) speculatively applies several consecutive
    event timestamps inside one while-loop iteration; the horizon
    candidates must lower-bound every instant at which this source
    could fire -- or otherwise invalidate speculation -- during
    ``(state.t, t_max]``, *given that only speculation-safe events
    apply in between*.  The default (the source's own candidates) is
    always safe because the batched path cuts speculation strictly
    before the earliest horizon: the source is then guaranteed to be
    applied by the ordinary superstep machinery, never skipped over.
    A source whose firings commute with speculation (COMPLETION and
    RETURN: they change no other source's pending instant to an
    earlier value) overrides ``horizon_fn`` with
    :func:`no_interference`, contributing no horizon candidates at
    all.  Because horizons are per *candidate*, a source can also be
    partially safe: each stream only cuts the horizon if it can
    actually interfere (a per-resource failure stream with ``mtbf = 0``
    is +inf and cuts nothing -- its row can never be hit), and
    ``horizon_candidates`` may return something strictly between
    "my every candidate" and "nothing".  Sources the speculative
    micro-steps *apply in-slab* (engine._speculative_step's slab-safe
    slice: COMPLETION, FAILURE, RECOVERY, NETWORK, RETURN) sharpen
    this further: their hooks expose only the firings the micro-steps
    can NOT reproduce -- a strike on a resource with resident work, a
    staging drain that matures an ARRIVAL, a pending link entry --
    under two obligations: (1) every exposed bound must stay a valid
    lower bound across any in-slab state evolution (the horizon is
    evaluated ONCE per slab, so bounds must be invariant under
    membership/rate changes the slab itself is allowed to make), and
    (2) every *non*-exposed firing must be exactly reproducible by the
    micro-step slice at its due instant, including trace rows, RNG
    consumption and the masked no-op contract when declined.

:class:`FnSource` is the plain-closure implementation the engine and
user extensions build sources from; see docs/ARCHITECTURE.md for the
"add a new event source" walkthrough (including the ``horizon`` hook)
and docs/PERFORMANCE.md for the speculation-horizon safety argument.

The masked-apply contract
-------------------------
The select-free sweep engine (engine.run_sweep / simulation.sweep)
never branches on whether a source fired: every source body executes
every superstep, and a source that is NOT due must behave as a
**bitwise no-op** under a boolean gate.  ``masked_apply(state, now,
fire)`` is that entry point.  The contract, for any source:

  * ``masked_apply(state, now, True)``  == ``apply(state, now)`` bitwise;
  * ``masked_apply(state, now, False)`` == ``state`` bitwise -- even
    when ``now`` is garbage (a masked superstep advances nothing, so
    the gated instant of a declined lane never leaks into state).

Most of the engine's built-in applications satisfy the contract
natively -- their writes are already ``jnp.where(due_mask, ...)``
selects and their due masks are derived from instants that the gate
zeroes out -- so gating the *due mask* is free.  For bodies that are
not naturally maskable (PRNG-key consuming streams, the broker's full
Fig 20 cycle), :func:`tree_select` provides the generic fallback: run
the body unconditionally and select every output leaf against the
ungated state.  That costs nothing extra under ``vmap``, where a
``lax.cond`` lowers to the very same both-branches select -- the point
of the contract is to make that cost explicit, shared, and absent from
the per-lane divergence path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .types import INF, pytree_dataclass

# ----------------------------------------------------------------------
# EventSource protocol: trace codes double as tie-break priorities.
# K_COMPLETION..K_BROKER keep the original 4-kind trace numbering so
# pre-refactor golden traces replay unchanged.
# ----------------------------------------------------------------------
K_COMPLETION = 0    # forecast completion materialises
K_RETURN = 1        # processed Gridlet reaches its broker
K_ARRIVAL = 2       # dispatched Gridlet reaches its resource
K_BROKER = 3        # periodic broker scheduling event
K_FAILURE = 4       # resource goes down (MTBF stream)
K_RECOVERY = 5      # resource comes back up (MTTR stream)
K_RESERVATION = 6   # advance-reservation window opens/closes
K_CALENDAR = 7      # local load calendar step (weekend boundary)
K_NETWORK = 8       # fair-share link event: a transfer completes its
                    # last byte, or a staged transfer enters its link
K_MARKET = 9        # commodity-market repricing round (posted-price
                    # adjustment from demand; economy.commodity_reprice)
K_AUCTION = 10      # sealed-bid auction/tender round (economy.
                    # auction_round; PRNG-keyed, see the masked contract)
K_TRACE = 11        # trace-driven fault-injection step: a scheduled
                    # (time, target, up/down) row from a replayable
                    # failure trace fires -- target is a resource or a
                    # shared trunk (every incident resource flips at once)

# Tie-break order among sources due at the same instant.  NETWORK sits
# between the pricing rounds and RETURN: a transfer that drains at t*
# releases its Gridlet's pending RETURN/ARRIVAL instant to t*, so the
# release must be applied before those sources collect their due
# batches (the released events then fold into the same superstep,
# exactly like the zero-delay analytic transfers always have).  MARKET
# and AUCTION sit with the other resource-state changes, crucially
# ABOVE BROKER -- a broker poll sharing an instant with a repricing
# round must observe the new posted prices (the engine's in-superstep
# application order moves only BROKER, so any rank above ARRIVAL keeps
# the pricing rounds ahead of the broker's dispatch batch).
# Application order inside a superstep differs from this ranking in
# exactly one place: the engine applies BROKER before ARRIVAL so the
# broker's zero-delay dispatches arrive within the same superstep,
# while ARRIVAL keeps semantic priority (pre-broker arrivals hold
# admission precedence -- see engine._apply_arrivals).
# TRACE sits directly after the stochastic FAILURE/RECOVERY pair: a
# trace step is the deterministic twin of those sources (it flips
# res_up for whole failure domains), so it must land before
# RESERVATION/pricing/NETWORK observe the superstep's resource-state.
PRIORITY_ORDER = (K_COMPLETION, K_FAILURE, K_RECOVERY, K_TRACE,
                  K_RESERVATION, K_MARKET, K_AUCTION, K_NETWORK,
                  K_RETURN, K_ARRIVAL, K_CALENDAR, K_BROKER)


def no_interference(state, t_max) -> jax.Array:
    """``horizon_fn`` for speculation-safe sources: never cuts the
    speculation horizon.  Correct only for sources whose firings cannot
    pull any *other* source's pending instant earlier (COMPLETION and
    RETURN satisfy this; see docs/PERFORMANCE.md for the argument)."""
    return INF


def tree_select(pred, on_true, on_false):
    """``jnp.where(pred, ...)`` over every leaf of a pytree pair -- the
    generic masked-apply fallback for source bodies that are not
    naturally maskable (see the module docstring's masked-apply
    contract).  ``pred`` is a scalar bool; the two trees must have
    identical structure.  Under ``vmap`` this is exactly what a
    ``lax.cond`` would have lowered to anyway, so using it costs
    nothing extra on the sweep path while keeping the body's execution
    unconditional (one execution, not both branches of a cond)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), on_true, on_false)


@dataclasses.dataclass(frozen=True)
class FnSource:
    """An :class:`EventSource` built from closures.

    ``candidates``/``apply`` close over whatever static context they
    need (fleet arrays, params, the engine's per-superstep scratch
    dict); the engine only sees the uniform protocol.  ``horizon_fn``
    is optional: when omitted, every candidate of this source cuts the
    k-step speculation horizon -- the conservative choice.  Setting it
    to :func:`no_interference` declares the source speculation-safe
    (no horizon candidates at all); any other callable is treated as a
    scalar ``(state, t_max) -> f32[]`` lower bound, and
    ``horizon_candidates_fn`` can instead supply a per-stream vector
    (the source-aware form the frontier op consumes directly).
    """
    kind: int
    name: str
    candidates_fn: Callable
    apply_fn: Callable
    horizon_fn: Callable | None = None
    horizon_candidates_fn: Callable | None = None

    def candidates(self, state) -> jax.Array:
        """Pending instants f32[C], +inf-padded (C may be 0)."""
        return jnp.atleast_1d(self.candidates_fn(state))

    def next_time(self, state) -> jax.Array:
        """Thin wrapper: earliest pending instant (+inf when none)."""
        c = self.candidates(state)
        return c.min() if c.shape[0] else jnp.asarray(INF, jnp.float32)

    def apply(self, state, now):
        return self.apply_fn(state, now)

    def masked_apply(self, state, now, fire):
        """Gated application for the select-free sweep engine (see the
        module docstring's masked-apply contract): bitwise ``apply``
        when ``fire`` is True, bitwise identity -- even under a garbage
        ``now`` -- when False.  The default runs the body
        unconditionally and selects every output leaf; sources whose
        bodies are naturally maskable (every write already gated on a
        due mask derived from ``now``) read the engine's gate from
        their shared scratch context instead and override nothing."""
        return tree_select(fire, self.apply_fn(state, now), state)

    def horizon_candidates(self, state) -> jax.Array:
        """Instants in ``(state.t, +inf]`` at which this source could
        interfere with speculative multi-timestamp batching, as a
        vector for the fused frontier pass; empty for speculation-safe
        sources.  Defaults to ``candidates`` (conservative)."""
        if self.horizon_fn is no_interference:
            return jnp.zeros((0,), jnp.float32)
        if self.horizon_candidates_fn is not None:
            return jnp.atleast_1d(self.horizon_candidates_fn(state))
        if self.horizon_fn is not None:
            return jnp.reshape(self.horizon_fn(state, INF), (1,))
        return self.candidates(state)

    def horizon(self, state, t_max) -> jax.Array:
        """Thin scalar wrapper over :meth:`horizon_candidates`."""
        if self.horizon_fn is not None:
            return self.horizon_fn(state, t_max)
        c = self.horizon_candidates(state)
        return c.min() if c.shape[0] else jnp.asarray(INF, jnp.float32)


@pytree_dataclass
class EventQueue:
    time: jax.Array     # f32[C], INF = free slot
    src: jax.Array      # i32[C]
    dst: jax.Array      # i32[C]
    tag: jax.Array      # i32[C]
    data: jax.Array     # f32[C, K]
    seq: jax.Array      # i32[C] FIFO tiebreak among equal timestamps
    next_seq: jax.Array  # i32[]
    overflow: jax.Array  # i32[] events dropped on a full calendar

    @property
    def capacity(self) -> int:
        return self.time.shape[0]


def make_queue(capacity: int, payload: int = 1) -> EventQueue:
    return EventQueue(
        time=jnp.full((capacity,), INF, jnp.float32),
        src=jnp.zeros((capacity,), jnp.int32),
        dst=jnp.zeros((capacity,), jnp.int32),
        tag=jnp.zeros((capacity,), jnp.int32),
        data=jnp.zeros((capacity, payload), jnp.float32),
        seq=jnp.zeros((capacity,), jnp.int32),
        next_seq=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
    )


def schedule(q: EventQueue, time, src, dst, tag, data=None) -> EventQueue:
    """sim_schedule: place one event in the first free slot.

    A full calendar DROPS the event and increments ``overflow`` instead
    of silently overwriting a live slot (the previous behaviour --
    argmax over an all-False free mask returned slot 0).  Callers size
    the queue so this never happens; tests assert overflow == 0.
    """
    free = ~jnp.isfinite(q.time)
    has_free = free.any()
    slot = jnp.argmax(free)  # first free slot (garbage when full)
    data = jnp.zeros((q.data.shape[1],), jnp.float32) if data is None \
        else jnp.asarray(data, jnp.float32).reshape(q.data.shape[1])

    def put(new, old):
        return jnp.where(has_free, new, old)

    return EventQueue(
        time=put(q.time.at[slot].set(jnp.asarray(time, jnp.float32)),
                 q.time),
        src=put(q.src.at[slot].set(jnp.asarray(src, jnp.int32)), q.src),
        dst=put(q.dst.at[slot].set(jnp.asarray(dst, jnp.int32)), q.dst),
        tag=put(q.tag.at[slot].set(jnp.asarray(tag, jnp.int32)), q.tag),
        data=put(q.data.at[slot].set(data), q.data),
        seq=put(q.seq.at[slot].set(q.next_seq), q.seq),
        next_seq=q.next_seq + 1,
        overflow=q.overflow + (~has_free).astype(jnp.int32),
    )


def peek_time(q: EventQueue) -> jax.Array:
    return q.time.min()


def size(q: EventQueue) -> jax.Array:
    return jnp.isfinite(q.time).sum()


def pop_next(q: EventQueue):
    """Remove + return the earliest event (FIFO among ties).

    Returns (queue', (time, src, dst, tag, data, valid)).  ``valid`` is
    False when the calendar is empty (the END_OF_SIMULATION condition).
    """
    # Lexicographic (time, seq) argmin via a composite penalty on seq.
    tmin = q.time.min()
    at_min = q.time == tmin
    seq_key = jnp.where(at_min, q.seq, jnp.iinfo(jnp.int32).max)
    slot = jnp.argmin(seq_key)
    valid = jnp.isfinite(tmin)
    ev = (q.time[slot], q.src[slot], q.dst[slot], q.tag[slot],
          q.data[slot], valid)
    q2 = EventQueue(
        time=q.time.at[slot].set(INF), src=q.src, dst=q.dst, tag=q.tag,
        data=q.data, seq=q.seq, next_seq=q.next_seq, overflow=q.overflow)
    return q2, ev


def cancel(q: EventQueue, predicate) -> EventQueue:
    """Discard events matching a mask -- the paper's 'discard stale
    internal events' rule for user-defined entities."""
    mask = predicate(q)
    return EventQueue(
        time=jnp.where(mask, INF, q.time), src=q.src, dst=q.dst,
        tag=q.tag, data=q.data, seq=q.seq, next_seq=q.next_seq,
        overflow=q.overflow)
