"""Array discrete-event calendar -- the SimJava substitute (paper 3.2.1).

SimJava runs one Java thread per entity and a central timestamp-ordered
future-event queue; ``sim_schedule`` / ``sim_hold`` / ``sim_wait`` suspend
threads.  None of that exists under jit, so the toolkit's second layer is
re-founded on a fixed-capacity struct-of-arrays calendar:

  * ``schedule``   == sim_schedule: write an event row into a free slot.
  * ``pop_next``   == Sim_system advancing the clock: masked argmin on the
                      time column (vector-unit friendly O(C) instead of a
                      pointer heap; C is small and the reduction fuses).
  * ``sim_hold``   == scheduling an event to yourself at t+dt.
  * ``sim_wait``   == simply handling your next popped event.

The specialised engine (engine.py) keeps *forecast* events implicit --
recomputed from state instead of queued -- which is how it sidesteps the
paper's stale-internal-event discard rule (section 3.4).  This calendar is
the general-purpose primitive for user-defined entities, tests and the
reservation system.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import INF, pytree_dataclass


@pytree_dataclass
class EventQueue:
    time: jax.Array     # f32[C], INF = free slot
    src: jax.Array      # i32[C]
    dst: jax.Array      # i32[C]
    tag: jax.Array      # i32[C]
    data: jax.Array     # f32[C, K]
    seq: jax.Array      # i32[C] FIFO tiebreak among equal timestamps
    next_seq: jax.Array  # i32[]

    @property
    def capacity(self) -> int:
        return self.time.shape[0]


def make_queue(capacity: int, payload: int = 1) -> EventQueue:
    return EventQueue(
        time=jnp.full((capacity,), INF, jnp.float32),
        src=jnp.zeros((capacity,), jnp.int32),
        dst=jnp.zeros((capacity,), jnp.int32),
        tag=jnp.zeros((capacity,), jnp.int32),
        data=jnp.zeros((capacity, payload), jnp.float32),
        seq=jnp.zeros((capacity,), jnp.int32),
        next_seq=jnp.zeros((), jnp.int32),
    )


def schedule(q: EventQueue, time, src, dst, tag, data=None) -> EventQueue:
    """sim_schedule: place one event.  Overwrites the oldest-free slot;
    callers size the queue so it never fills (asserted in tests)."""
    slot = jnp.argmax(~jnp.isfinite(q.time))  # first free slot
    data = jnp.zeros((q.data.shape[1],), jnp.float32) if data is None \
        else jnp.asarray(data, jnp.float32).reshape(q.data.shape[1])
    return EventQueue(
        time=q.time.at[slot].set(jnp.asarray(time, jnp.float32)),
        src=q.src.at[slot].set(jnp.asarray(src, jnp.int32)),
        dst=q.dst.at[slot].set(jnp.asarray(dst, jnp.int32)),
        tag=q.tag.at[slot].set(jnp.asarray(tag, jnp.int32)),
        data=q.data.at[slot].set(data),
        seq=q.seq.at[slot].set(q.next_seq),
        next_seq=q.next_seq + 1,
    )


def peek_time(q: EventQueue) -> jax.Array:
    return q.time.min()


def size(q: EventQueue) -> jax.Array:
    return jnp.isfinite(q.time).sum()


def pop_next(q: EventQueue):
    """Remove + return the earliest event (FIFO among ties).

    Returns (queue', (time, src, dst, tag, data, valid)).  ``valid`` is
    False when the calendar is empty (the END_OF_SIMULATION condition).
    """
    # Lexicographic (time, seq) argmin via a composite penalty on seq.
    tmin = q.time.min()
    at_min = q.time == tmin
    seq_key = jnp.where(at_min, q.seq, jnp.iinfo(jnp.int32).max)
    slot = jnp.argmin(seq_key)
    valid = jnp.isfinite(tmin)
    ev = (q.time[slot], q.src[slot], q.dst[slot], q.tag[slot],
          q.data[slot], valid)
    q2 = EventQueue(
        time=q.time.at[slot].set(INF), src=q.src, dst=q.dst, tag=q.tag,
        data=q.data, seq=q.seq, next_seq=q.next_seq)
    return q2, ev


def cancel(q: EventQueue, predicate) -> EventQueue:
    """Discard events matching a mask -- the paper's 'discard stale
    internal events' rule for user-defined entities."""
    mask = predicate(q)
    return EventQueue(
        time=jnp.where(mask, INF, q.time), src=q.src, dst=q.dst,
        tag=q.tag, data=q.data, seq=q.seq, next_seq=q.next_seq)
