"""Entity communication model (paper section 3.2.2, Fig 4).

GridSim gives every networked entity buffered Input and Output entities
so transfer delay is modelled transparently.  The vectorised adaptation
has two tiers:

* **Analytic links** (the default): transfer delay is the closed-form
  term bytes / baud_rate (+ fixed latency), folded into the Gridlet's
  IN_TRANSIT / RETURNING event timestamps by the engine at dispatch /
  completion time.  Two transfers on the same link never interfere.
* **Fair-share links** (the contention-aware network subsystem,
  enabled by the engine's static ``net_cap`` knob): each resource's
  link has finite bandwidth shared *equally* among its concurrent
  transfers (in-flight stagings and result returns), exactly mirroring
  the time-shared CPU machinery -- a ``[L, T]`` transfer-slot table
  with ``remaining_bytes`` per transfer, piecewise-constant rates
  between events, and per-link completion forecasts through
  ``kernels.ops.link_scan`` (= Fig 8 with one PE plus a
  background-traffic offset on the divisor).  The engine's NETWORK
  event source owns the table; see core/engine.py and
  docs/ARCHITECTURE.md ("The network layer").

Only transfers that can actually contend occupy a link slot:
:func:`link_tabled` is the routing predicate.  Zero-byte payloads and
infinite-baud links are *instantaneous* in both tiers (delay exactly
0.0), which is what keeps zero-contention configurations bit-for-bit
identical to the analytic path.

The "buffering" semantics (serialised in/out flows) are preserved
because the engine timestamps each transfer independently and resources
only observe the arrival events.
"""
from __future__ import annotations

import jax.numpy as jnp

LATENCY = 0.0   # fixed per-message latency in time units
BIG = 3.0e38    # finite "never arrives" horizon (matches kernels BIG)


def transfer_delay(nbytes, baud_rate):
    """Delay to move ``nbytes`` over a link of ``baud_rate`` bytes/unit.

    Total: finite, nonnegative and monotone non-decreasing in
    ``nbytes`` for every baud value (property-asserted in tests):
    bytes == 0 or baud == inf mean "instantaneous" (exactly 0.0 +
    LATENCY); a zero/denormal baud rate -- or an f32 overflow of the
    quotient -- clamps to the finite BIG horizon ("never arrives")
    instead of wrapping to inf or, worse, back to 0.
    """
    nbytes = jnp.asarray(nbytes, jnp.float32)
    baud = jnp.asarray(baud_rate, jnp.float32)
    safe = jnp.maximum(baud, 1e-30)
    d = jnp.minimum(nbytes / safe, BIG)       # overflow -> BIG, not inf
    d = jnp.where(jnp.isinf(baud) | (nbytes <= 0.0), 0.0, d)
    return d + LATENCY


def fastest_drain(nbytes, baud_rate, bg_flows):
    """Membership-invariant lower bound on the wall-clock time a
    *tabled* transfer with ``nbytes`` still in flight needs to drain.

    A fair-share link splits ``baud_rate`` equally over its m resident
    transfers plus ``bg_flows`` phantom background flows, so any single
    transfer's rate is at most ``baud / (1 + bg)`` (m >= 1) and never
    exceeds that bound no matter how membership evolves -- new stagings
    or result returns entering the link only *slow* existing drains.
    Hence no tabled transfer can complete before
    ``nbytes * (1 + bg) / baud`` elapses, which is what makes the bound
    safe as a slab speculation horizon (core/engine.py's NETWORK
    horizon uses it on the live ``[R_pad, T]`` table).  Clamping matches
    :func:`transfer_delay`: f32 overflow -> the finite BIG horizon,
    non-positive payloads or infinite baud -> exactly 0.0.
    """
    nbytes = jnp.asarray(nbytes, jnp.float32)
    baud = jnp.asarray(baud_rate, jnp.float32)
    bg = jnp.asarray(bg_flows, jnp.float32)
    safe = jnp.maximum(baud, 1e-30)
    d = jnp.minimum(nbytes * (1.0 + bg) / safe, BIG)
    return jnp.where(jnp.isinf(baud) | (nbytes <= 0.0), 0.0, d)


def link_tabled(nbytes, baud_rate):
    """True where a transfer contends for link bandwidth, i.e. belongs
    in the fair-share transfer-slot table: a positive payload over a
    link of positive capacity below the BIG horizon.  Everything else
    (empty payloads, infinite or BIG-fast links, dead zero-baud links)
    keeps the analytic delay -- instantaneous or never -- so the
    contended and analytic paths agree exactly wherever no contention
    is possible.  The upper threshold is ``baud < BIG``, matching the
    link kernel's live-row mask exactly: a transfer this predicate
    tables is guaranteed a nonzero drain rate."""
    nbytes = jnp.asarray(nbytes, jnp.float32)
    baud = jnp.asarray(baud_rate, jnp.float32)
    return (nbytes > 0.0) & (baud > 0.0) & (baud < BIG)


def submit_delay(gridlets, fleet, resource_idx):
    """User -> resource staging delay for each gridlet (input files)."""
    return transfer_delay(gridlets.in_bytes, fleet.baud_rate[resource_idx])


def return_delay(gridlets, fleet, resource_idx):
    """Resource -> user result delay for each gridlet (output files)."""
    return transfer_delay(gridlets.out_bytes, fleet.baud_rate[resource_idx])
