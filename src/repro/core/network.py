"""Entity communication model (paper section 3.2.2, Fig 4).

GridSim gives every networked entity buffered Input and Output entities
so transfer delay is modelled transparently.  The vectorised adaptation
has two tiers:

* **Analytic links** (the default): transfer delay is the closed-form
  term bytes / baud_rate (+ fixed latency), folded into the Gridlet's
  IN_TRANSIT / RETURNING event timestamps by the engine at dispatch /
  completion time.  Two transfers on the same link never interfere.
* **Fair-share links** (the contention-aware network subsystem,
  enabled by the engine's static ``net_cap`` knob): each resource's
  link has finite bandwidth shared *equally* among its concurrent
  transfers (in-flight stagings and result returns), exactly mirroring
  the time-shared CPU machinery -- a ``[L, T]`` transfer-slot table
  with ``remaining_bytes`` per transfer, piecewise-constant rates
  between events, and per-link completion forecasts through
  ``kernels.ops.link_scan`` (= Fig 8 with one PE plus a
  background-traffic offset on the divisor).  The engine's NETWORK
  event source owns the table; see core/engine.py and
  docs/ARCHITECTURE.md ("The network layer").

Only transfers that can actually contend occupy a link slot:
:func:`link_tabled` is the routing predicate.  Zero-byte payloads and
infinite-baud links are *instantaneous* in both tiers (delay exactly
0.0), which is what keeps zero-contention configurations bit-for-bit
identical to the analytic path.

The "buffering" semantics (serialised in/out flows) are preserved
because the engine timestamps each transfer independently and resources
only observe the arrival events.
"""
from __future__ import annotations

import jax.numpy as jnp

LATENCY = 0.0   # fixed per-message latency in time units
BIG = 3.0e38    # finite "never arrives" horizon (matches kernels BIG)


def transfer_delay(nbytes, baud_rate):
    """Delay to move ``nbytes`` over a link of ``baud_rate`` bytes/unit.

    Total: finite, nonnegative and monotone non-decreasing in
    ``nbytes`` for every baud value (property-asserted in tests):
    bytes == 0 or baud == inf mean "instantaneous" (exactly 0.0 +
    LATENCY); a zero/denormal baud rate -- or an f32 overflow of the
    quotient -- clamps to the finite BIG horizon ("never arrives")
    instead of wrapping to inf or, worse, back to 0.
    """
    nbytes = jnp.asarray(nbytes, jnp.float32)
    baud = jnp.asarray(baud_rate, jnp.float32)
    safe = jnp.maximum(baud, 1e-30)
    d = jnp.minimum(nbytes / safe, BIG)       # overflow -> BIG, not inf
    d = jnp.where(jnp.isinf(baud) | (nbytes <= 0.0), 0.0, d)
    return d + LATENCY


def fastest_drain(nbytes, baud_rate, bg_flows):
    """Membership-invariant lower bound on the wall-clock time a
    *tabled* transfer with ``nbytes`` still in flight needs to drain.

    A fair-share link splits ``baud_rate`` equally over its m resident
    transfers plus ``bg_flows`` phantom background flows, so any single
    transfer's rate is at most ``baud / (1 + bg)`` (m >= 1) and never
    exceeds that bound no matter how membership evolves -- new stagings
    or result returns entering the link only *slow* existing drains.
    Hence no tabled transfer can complete before
    ``nbytes * (1 + bg) / baud`` elapses, which is what makes the bound
    safe as a slab speculation horizon (core/engine.py's NETWORK
    horizon uses it on the live ``[R_pad, T]`` table).  Clamping matches
    :func:`transfer_delay`: f32 overflow -> the finite BIG horizon,
    non-positive payloads or infinite baud -> exactly 0.0.
    """
    nbytes = jnp.asarray(nbytes, jnp.float32)
    baud = jnp.asarray(baud_rate, jnp.float32)
    bg = jnp.asarray(bg_flows, jnp.float32)
    safe = jnp.maximum(baud, 1e-30)
    d = jnp.minimum(nbytes * (1.0 + bg) / safe, BIG)
    return jnp.where(jnp.isinf(baud) | (nbytes <= 0.0), 0.0, d)


def link_tabled(nbytes, baud_rate):
    """True where a transfer contends for link bandwidth, i.e. belongs
    in the fair-share transfer-slot table: a positive payload over a
    link of positive capacity below the BIG horizon.  Everything else
    (empty payloads, infinite or BIG-fast links, dead zero-baud links)
    keeps the analytic delay -- instantaneous or never -- so the
    contended and analytic paths agree exactly wherever no contention
    is possible.  The upper threshold is ``baud < BIG``, matching the
    link kernel's live-row mask exactly: a transfer this predicate
    tables is guaranteed a nonzero drain rate."""
    nbytes = jnp.asarray(nbytes, jnp.float32)
    baud = jnp.asarray(baud_rate, jnp.float32)
    return (nbytes > 0.0) & (baud > 0.0) & (baud < BIG)


# ----------------------------------------------------------------------
# Shared-trunk topology: the [L, R] link-incidence map collapsed to a
# per-resource trunk id.  Each resource keeps its private last-mile link
# (one [L, T] row as before); a trunk groups rows that additionally
# share an upstream WAN segment of finite capacity.  Because every
# resource sits behind at most one trunk, the full [L, R] incidence
# matrix is rank-structured enough to store as trunk_of: i32[R]
# (-1 = private-only) plus per-trunk baud/background-flow vectors --
# the one-hot expansion IS the incidence map, built on demand below.
# ----------------------------------------------------------------------

def trunk_topology(trunk_of, n_resources, trunk_baud=None, trunk_bg=None):
    """Build/validate a shared-trunk topology.

    trunk_of: per-resource trunk id (int sequence of length R; -1 =
        the resource hangs off its private link only).  Ids must be
        dense 0..n_trunks-1 (any subset of resources per trunk).
    trunk_baud: per-trunk capacity in bytes/time-unit (scalar or
        [n_trunks]; default BIG = trunks never bind, private-link
        behaviour).
    trunk_bg: per-trunk phantom background flows (scalar or
        [n_trunks]; default 0).

    Returns ``(trunk_of i32[R], trunk_baud f32[R], trunk_bg f32[R])``
    with the per-trunk vectors gathered out to per-resource form --
    the layout SimParams carries (resource-major like every other
    fleet table, so the engine's r_pad padding applies uniformly).
    """
    trunk_of = jnp.asarray(trunk_of, jnp.int32)
    if trunk_of.shape != (n_resources,):
        raise ValueError(
            f"trunk_of must have shape ({n_resources},), "
            f"got {trunk_of.shape}")
    n_trunks = int(trunk_of.max()) + 1 if int(trunk_of.max()) >= 0 else 0
    if int(trunk_of.min()) < -1:
        raise ValueError("trunk ids must be >= -1")
    if trunk_baud is None:
        trunk_baud = BIG
    if trunk_bg is None:
        trunk_bg = 0.0
    baud_t = jnp.broadcast_to(
        jnp.asarray(trunk_baud, jnp.float32), (max(n_trunks, 1),))
    bg_t = jnp.broadcast_to(
        jnp.asarray(trunk_bg, jnp.float32), (max(n_trunks, 1),))
    idx = jnp.clip(trunk_of, 0, max(n_trunks - 1, 0))
    private = trunk_of < 0
    baud_r = jnp.where(private, BIG, baud_t[idx])
    bg_r = jnp.where(private, 0.0, bg_t[idx])
    return trunk_of, baud_r, bg_r


def trunk_incidence(trunk_of, n_resources):
    """One-hot [R, R] trunk co-membership matrix: cell (i, j) is True
    when resources i and j share a trunk (diagonal True only for
    trunked rows).  This is the `[L, R]` incidence map contracted with
    itself -- what both the trunk fair-share divisor and the
    correlated-failure expansion gather through."""
    trunk_of = jnp.asarray(trunk_of, jnp.int32)
    same = trunk_of[:, None] == trunk_of[None, :]
    return same & (trunk_of >= 0)[:, None]


def trunk_rate_cap(occupancy, trunk_of, trunk_baud, trunk_bg):
    """Per-resource fair-share rate cap from trunk membership.

    occupancy: i32/f32[R] live transfer count per private link row;
    trunk_of/trunk_baud/trunk_bg: the per-resource topology vectors
    from :func:`trunk_topology` (r_pad-padded by the engine; padded
    rows carry trunk_of = -1).  A trunk with M total resident
    transfers across its member rows and bg phantom flows grants each
    of them at most ``trunk_baud / max(M + bg, 1)`` -- the same
    fair-share law as the private link, evaluated on the *summed*
    membership.  Private-only rows get a BIG cap (never binds).
    """
    occ = jnp.asarray(occupancy, jnp.float32)
    inc = trunk_incidence(trunk_of, occ.shape[0])
    m_trunk = jnp.sum(jnp.where(inc, occ[None, :], 0.0), axis=1)
    cap = trunk_baud / jnp.maximum(m_trunk + trunk_bg, 1.0)
    return jnp.where(trunk_of >= 0, cap, BIG)


def submit_delay(gridlets, fleet, resource_idx):
    """User -> resource staging delay for each gridlet (input files)."""
    return transfer_delay(gridlets.in_bytes, fleet.baud_rate[resource_idx])


def return_delay(gridlets, fleet, resource_idx):
    """Resource -> user result delay for each gridlet (output files)."""
    return transfer_delay(gridlets.out_bytes, fleet.baud_rate[resource_idx])
