"""Entity communication model (paper section 3.2.2, Fig 4).

GridSim gives every networked entity buffered Input and Output entities so
transfer delay is modelled transparently.  Vectorised adaptation: transfer
delay is the analytic term bytes / baud_rate (+ fixed latency), folded into
the Gridlet's IN_TRANSIT / RETURNING event timestamps by the engine.  The
"buffering" semantics (serialised in/out flows) are preserved because the
engine timestamps each transfer independently and resources only observe
the arrival events.
"""
from __future__ import annotations

import jax.numpy as jnp

LATENCY = 0.0  # fixed per-message latency in time units


def transfer_delay(nbytes, baud_rate):
    """Delay to move ``nbytes`` over a link of ``baud_rate`` bytes/unit."""
    nbytes = jnp.asarray(nbytes, jnp.float32)
    safe = jnp.maximum(jnp.asarray(baud_rate, jnp.float32), 1e-30)
    d = nbytes / safe
    # bytes == 0 or baud == inf both mean "instantaneous".
    return jnp.where(jnp.isfinite(d), d, 0.0) + LATENCY


def submit_delay(gridlets, fleet, resource_idx):
    """User -> resource staging delay for each gridlet (input files)."""
    return transfer_delay(gridlets.in_bytes, fleet.baud_rate[resource_idx])


def return_delay(gridlets, fleet, resource_idx):
    """Resource -> user result delay for each gridlet (output files)."""
    return transfer_delay(gridlets.out_bytes, fleet.baud_rate[resource_idx])
