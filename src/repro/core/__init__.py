"""GridSim-in-JAX: vectorised discrete-event grid scheduling simulation.

The paper's toolkit layers (section 3.2) map to:
  SimJava discrete events  -> core.des (array event calendar)
  GridSim entities         -> core.resource / core.gridlet / core.gis
  resource allocation      -> core.engine (Figs 7-12, vectorised)
  economic broker          -> core.broker (Fig 20 DBC algorithms)
  deadline/budget economy  -> core.economy (Eq 1 / Eq 2)
  statistics               -> core.stats
  experiment recipes       -> core.simulation
"""
from . import (broker, calendar, des, economy, engine, gis, gridlet,
               network, rand, reservation, resource, segments, simulation,
               stats, types)

__all__ = [
    "broker", "calendar", "des", "economy", "engine", "gis", "gridlet",
    "network", "rand", "reservation", "resource", "segments", "simulation",
    "stats", "types",
]
