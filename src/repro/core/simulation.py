"""High-level experiment drivers (the paper's section-4 "recipe").

`run_experiment` = create resources + users + brokers, start the clock,
collect statistics -- one call, one jit.  `sweep` vmaps a whole grid of
(deadline, budget) scenarios, which is how the repo regenerates the
paper's Figures 21-38 in seconds instead of one simulation per point.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import economy, engine, gridlet
from .types import DONE, OPT_COST


class ExperimentResult(NamedTuple):
    n_done: jax.Array        # f32[U] gridlets completed per user
    spent: jax.Array         # f32[U] budget spent per user
    term_time: jax.Array     # f32[U] broker termination time
    time_utilization: jax.Array   # f32[U] term_time / deadline
    budget_utilization: jax.Array  # f32[U] spent / budget
    per_resource_done: jax.Array  # f32[U,R] completions by resource
    gridlets: object
    n_events: jax.Array      # i32 events applied by the engine
    n_steps: jax.Array       # i32 engine supersteps (loop iterations)
    overflow: jax.Array      # i32 job-slot allocation failures (== 0)


def _max_events(n_gridlets: int, n_users: int, horizon: float,
                min_period: float) -> int:
    # 4 events per gridlet lifecycle + broker polls over the horizon.
    return int(4 * n_gridlets + horizon / max(min_period, 1e-6) + 64)


def summarize(res: engine.SimResult, params, n_users: int,
              n_resources: int) -> ExperimentResult:
    g = res.gridlets
    done = (g.status == DONE).astype(jnp.float32)
    n_done = jax.ops.segment_sum(done, g.user, num_segments=n_users)
    ur = g.user * n_resources + jnp.clip(g.resource, 0, n_resources - 1)
    per_res = jax.ops.segment_sum(
        done, ur, num_segments=n_users * n_resources
    ).reshape(n_users, n_resources)
    return ExperimentResult(
        n_done=n_done,
        spent=res.spent,
        term_time=res.term_time,
        time_utilization=res.term_time / jnp.maximum(params.deadline, 1e-30),
        budget_utilization=res.spent / jnp.maximum(params.budget, 1e-30),
        per_resource_done=per_res,
        gridlets=g,
        n_events=res.n_events,
        n_steps=res.n_steps,
        overflow=res.overflow,
    )


def safe_max_jobs(gridlets_batch, params, fleet) -> int:
    """Static bound on concurrently RUNNING gridlets per resource: the
    broker stages at most max_gridlet_per_pe * num_pe in-flight jobs per
    (user, resource), so the engine's job-slot table never needs more
    than U * that many columns (capped at N)."""
    limit = int(params.max_gridlet_per_pe) * fleet.max_pe
    return min(gridlets_batch.n, params.deadline.shape[0] * limit)


def run_experiment(gridlets_batch, fleet, deadline, budget,
                   opt=OPT_COST, n_users: int = 1,
                   max_events: int | None = None) -> ExperimentResult:
    params = engine.default_params(deadline, budget, opt, n_users, fleet.r)
    if max_events is None:
        horizon = float(jnp.max(params.deadline)) * 2.0 + 100.0
        max_events = _max_events(gridlets_batch.n, n_users, horizon, 1.0)
    res = engine.run(gridlets_batch, fleet, params, n_users, max_events,
                     max_jobs=safe_max_jobs(gridlets_batch, params, fleet))
    return summarize(res, params, n_users, fleet.r)


def run_experiment_factors(gridlets_batch, fleet, d_factor, b_factor,
                           opt=OPT_COST, n_users: int = 1,
                           max_events: int | None = None):
    """Paper 4.2.3: derive absolute deadline/budget from D-/B-factors."""
    total_mi = gridlets_batch.length_mi.sum()
    deadline = economy.deadline_from_factor(fleet, total_mi, d_factor)
    budget = economy.budget_from_factor(fleet, total_mi, b_factor)
    return run_experiment(gridlets_batch, fleet, deadline, budget, opt,
                          n_users, max_events), (deadline, budget)


def sweep(gridlets_batch, fleet, deadlines, budgets, opt=OPT_COST,
          n_users: int = 1, max_events: int | None = None):
    """vmap over the full deadline x budget grid (paper Figs 21-24).

    deadlines: [D], budgets: [B] -> every field gains leading [D, B] dims.
    """
    deadlines = jnp.asarray(deadlines, jnp.float32)
    budgets = jnp.asarray(budgets, jnp.float32)
    if max_events is None:
        horizon = float(deadlines.max()) * 2.0 + 100.0
        max_events = _max_events(gridlets_batch.n, n_users, horizon, 1.0)
    params0 = engine.default_params(1.0, 1.0, opt, n_users, fleet.r)
    max_jobs = safe_max_jobs(gridlets_batch, params0, fleet)  # static

    def one(d, b):
        params = engine.default_params(d, b, opt, n_users, fleet.r)
        res = engine.run_inner(gridlets_batch, fleet, params, n_users,
                               max_events, max_jobs)
        return summarize(res, params, n_users, fleet.r)

    f = jax.vmap(jax.vmap(one, in_axes=(None, 0)), in_axes=(0, None))
    return jax.jit(f)(deadlines, budgets)
