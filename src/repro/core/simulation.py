"""High-level experiment drivers (the paper's section-4 "recipe").

`run_experiment` = create resources + users + brokers, start the clock,
collect statistics -- one call, one jit.  `sweep` vmaps a whole grid of
(deadline, budget) scenarios, which is how the repo regenerates the
paper's Figures 21-38 in seconds instead of one simulation per point.

`Scenario` bundles the dynamic-resource knobs the pluggable event
sources consume: per-resource MTBF/MTTR failure streams, advance
reservations, and the RNG seed for the failure draws.  The default
(all-zero) scenario registers every source with nothing to do, which is
bit-for-bit identical to not registering them at all -- asserted by
tests/test_superstep.py.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import economy, engine, gridlet
from .types import DONE, OPT_COST
from .types import replace as treplace


class Scenario(NamedTuple):
    """Dynamic-resource scenario knobs (all optional).

    mtbf: per-resource mean time between failures (scalar or [R]);
        0 or None disables the failure source entirely,
    mttr: per-resource mean time to recovery; 0 or None means instant
        recovery (failures still kill, refund and resubmit the
        resource's in-flight gridlets -- zero-downtime "blips"),
    reservations: a reservation.ReservationBook, an iterable of
        (resource, pes, start, end) tuples, or the exported 4-array
        table (``reservation.maintenance`` builds full-resource
        maintenance windows in this form),
    seed: PRNG seed for the MTBF/MTTR streams,
    baud_rate: per-resource link capacity override for the
        contention-aware network subsystem (scalar or [R]; default:
        ``fleet.baud_rate``) -- consulted when ``run_experiment`` runs
        with ``net_cap > 0``,
    bg_flows: per-resource phantom background flows sharing each link
        (scalar or [R], may be fractional; default 0) -- standing
        non-grid traffic that takes its fair share of the link without
        ever completing; net mode only,
    sched_min_period: broker poll-period floor in simulation time
        (default None = the engine default 1.0, the paper's setting),
    sched_frac: broker poll period as a fraction of the remaining
        deadline (default None = the engine default 0.01).  The broker
        re-evaluates its schedule every ``max(sched_min_period,
        sched_frac * deadline_left)`` simulated seconds; coarser
        polling trades scheduling reactivity for fewer pure-poll
        supersteps and deeper speculation horizons (see
        docs/PERFORMANCE.md, "Profiling checklist"),
    policy: broker optimisation strategy override (an OPT_* code;
        default None = the ``opt`` argument of the driver call).  Makes
        the strategy a first-class scenario axis: stack Scenario-built
        params over lanes and the same sweep compares policies,
    pricing_model: "static" (default), "commodity", or "auction" (or a
        PRICE_* code) -- selects which dynamic-pricing event source
        runs (see core/economy.py),
    market_period / market_gain: commodity-market repricing period and
        demand gain (defaults: engine defaults 10.0 / 0.25),
    auction_period: sealed-bid round period (default 10.0),
    auction_seed: PRNG seed for the auction bid draws (default: the
        scenario ``seed``, so auctions are deterministic per scenario),
    plan_ahead: enable the cs/0203020 plan-ahead DBC dispatch --
        reservation windows and link queueing delay priced into the
        capacity prediction, and the exact grouped cost-time key
        (default False = the legacy reactive broker),
    trunk_of: per-resource shared-trunk id ([R] ints; -1 = private
        link only; default None = no trunks, the bitwise-frozen legacy
        topology).  Resources sharing a trunk id form one failure
        domain AND split the trunk's bandwidth (net mode),
    trunk_baud: per-trunk capacity (scalar or [n_trunks]; default
        "never binds") -- the upstream WAN segment's fair share caps
        every member transfer's rate at ``trunk_baud / (M + trunk_bg)``
        with M the total resident transfers across the trunk,
    trunk_bg: per-trunk phantom background flows (scalar or
        [n_trunks]; default 0),
    fault_trace: replayable fault-injection schedule -- an iterable of
        ``(time, target, up)`` rows or an equivalent [K, 3] array;
        ``target`` is a resource index (0..R-1) or ``R + trunk_id`` to
        hit a whole trunk (every incident resource fails/recovers in
        one superstep).  ``up=0`` fails the target (in-flight gridlets
        refunded and resubmitted), ``up=1`` brings it back.  Rows are
        applied in time order; default None = no injection,
    retry_limit: max per-gridlet failure-resubmission count before the
        broker abandons it (default: unlimited, the legacy behaviour),
    backoff_base: exponential-backoff base delay after a failure; a
        gridlet's n-th failure blocks re-dispatch until
        ``t_fail + backoff_base * 2**(n-1)`` (default 0 = immediate),
    blacklist_cooldown: how long the broker shuns a freshly recovered
        resource (default 0 = dispatch immediately on recovery).
    """
    mtbf: Any = None
    mttr: Any = None
    reservations: Any = None
    seed: int = 0
    baud_rate: Any = None
    bg_flows: Any = None
    sched_min_period: Any = None
    sched_frac: Any = None
    policy: Any = None
    pricing_model: Any = None
    market_period: Any = None
    market_gain: Any = None
    auction_period: Any = None
    auction_seed: Any = None
    plan_ahead: Any = None
    trunk_of: Any = None
    trunk_baud: Any = None
    trunk_bg: Any = None
    fault_trace: Any = None
    retry_limit: Any = None
    backoff_base: Any = None
    blacklist_cooldown: Any = None


class ExperimentResult(NamedTuple):
    n_done: jax.Array        # f32[U] gridlets completed per user
    spent: jax.Array         # f32[U] budget spent per user
    term_time: jax.Array     # f32[U] broker termination time
    time_utilization: jax.Array   # f32[U] term_time / deadline
    budget_utilization: jax.Array  # f32[U] spent / budget
    per_resource_done: jax.Array  # f32[U,R] completions by resource
    gridlets: object
    n_events: jax.Array      # i32 events applied by the engine
    n_steps: jax.Array       # i32 engine while-loop iterations
    overflow: jax.Array      # i32 job-slot allocation failures (== 0)
    n_failed: jax.Array      # i32 gridlets hit by a resource failure
    n_resubmits: jax.Array   # i32 FAILED gridlets re-dispatched
    downtime: jax.Array      # f32[R] accumulated down intervals
    truncated: jax.Array     # bool: loop hit max_events before finishing
    n_spec: jax.Array        # i32 speculative supersteps folded into
                             #     the n_steps iterations (k-step batch)
    n_reseeds: jax.Array     # i32 scans that had to re-sort the
                             #     job-slot table (slab carry miss;
                             #     the rest ran sort-free)
    n_scans: jax.Array       # i32 scans performed (committing +
                             #     speculative supersteps, incl.
                             #     declined micro-steps)
    telemetry: Any = None    # telemetry.Telemetry metrics ring when the
                             # run recorded one (observability only --
                             # never part of result identity)


def _max_events(n_gridlets: int, n_users: int, horizon: float,
                min_period: float) -> int:
    # 4 events per gridlet lifecycle + broker polls over the horizon.
    # Failure scenarios can repeat lifecycles (fail -> refund ->
    # resubmit); the horizon term usually dominates, but failure-heavy
    # runs should pass an explicit max_events and check
    # ExperimentResult.truncated.
    return int(4 * n_gridlets + horizon / max(min_period, 1e-6) + 64)


def summarize(res: engine.SimResult, params, n_users: int,
              n_resources: int,
              max_events: int | None = None) -> ExperimentResult:
    g = res.gridlets
    done = (g.status == DONE).astype(jnp.float32)
    n_done = jax.ops.segment_sum(done, g.user, num_segments=n_users)
    ur = g.user * n_resources + jnp.clip(g.resource, 0, n_resources - 1)
    per_res = jax.ops.segment_sum(
        done, ur, num_segments=n_users * n_resources
    ).reshape(n_users, n_resources)
    return ExperimentResult(
        n_done=n_done,
        spent=res.spent,
        term_time=res.term_time,
        time_utilization=res.term_time / jnp.maximum(params.deadline, 1e-30),
        budget_utilization=res.spent / jnp.maximum(params.budget, 1e-30),
        per_resource_done=per_res,
        gridlets=g,
        n_events=res.n_events,
        n_steps=res.n_steps,
        overflow=res.overflow,
        n_failed=res.n_failed,
        n_resubmits=res.n_resubmits,
        downtime=res.downtime,
        truncated=(res.n_steps + res.n_spec >= max_events
                   if max_events is not None else jnp.asarray(False)),
        n_spec=res.n_spec,
        n_reseeds=res.n_reseeds,
        n_scans=res.n_scans,
        telemetry=res.telemetry,
    )


def safe_max_jobs(gridlets_batch, params, fleet) -> int:
    """Static bound on concurrently RUNNING gridlets per resource: the
    broker stages at most max_gridlet_per_pe * num_pe in-flight jobs per
    (user, resource), so the engine's job-slot table never needs more
    than U * that many columns (capped at N)."""
    limit = int(params.max_gridlet_per_pe) * fleet.max_pe
    return min(gridlets_batch.n, params.deadline.shape[0] * limit)


def safe_net_cap(gridlets_batch, params, fleet, n_users: int = 1) -> int:
    """Static bound on concurrent transfers per resource link: the
    broker keeps at most max_gridlet_per_pe * num_pe gridlets in flight
    per (user, resource), and every one of them holds at most one
    transfer (staging or return) at a time -- so U * that many slots
    per link always suffice (capped at N, the broker-less worst case of
    everything routed onto one link)."""
    limit = int(params.max_gridlet_per_pe) * fleet.max_pe
    return min(gridlets_batch.n, n_users * limit)


def _scenario_params(fleet, deadline, budget, opt, n_users,
                     scenario: Scenario | None) -> engine.SimParams:
    s = scenario or Scenario()
    p = engine.default_params(
        deadline, budget,
        opt if s.policy is None else s.policy,
        n_users, fleet.r,
        mtbf=s.mtbf, mttr=s.mttr, reservations=s.reservations,
        fail_key=jax.random.PRNGKey(s.seed),
        link_baud=(fleet.baud_rate if s.baud_rate is None
                   else s.baud_rate),
        bg_flows=s.bg_flows,
        pricing_model=economy.as_pricing_model(s.pricing_model),
        market_period=s.market_period,
        market_gain=s.market_gain,
        auction_period=s.auction_period,
        auction_key=jax.random.PRNGKey(
            s.seed if s.auction_seed is None else s.auction_seed),
        plan_ahead=bool(s.plan_ahead) if s.plan_ahead is not None
        else False,
        trunk_of=s.trunk_of, trunk_baud=s.trunk_baud,
        trunk_bg=s.trunk_bg, fault_trace=s.fault_trace,
        retry_limit=s.retry_limit, backoff_base=s.backoff_base,
        blacklist_cooldown=s.blacklist_cooldown)
    if s.sched_min_period is not None:
        p = treplace(p, sched_min_period=jnp.asarray(
            s.sched_min_period, jnp.float32))
    if s.sched_frac is not None:
        p = treplace(p, sched_frac=jnp.asarray(s.sched_frac, jnp.float32))
    return p


def run_experiment(gridlets_batch, fleet, deadline, budget,
                   opt=OPT_COST, n_users: int = 1,
                   max_events: int | None = None,
                   scenario: Scenario | None = None,
                   batch: int = engine.DEFAULT_BATCH,
                   net_cap: int | None = 0,
                   telemetry: int | None = None) -> ExperimentResult:
    """``batch`` is the engine's k-step superstep batching factor
    (static; see engine.step_batched) -- results are bit-for-bit
    identical for every value, ``batch=1`` disables speculation.

    ``net_cap`` (static) enables the contention-aware network
    subsystem: 0 (default) keeps the analytic links, ``None`` sizes the
    transfer-slot table automatically (:func:`safe_net_cap`), any
    positive int is the explicit transfer-slot count per link.  The
    scenario's ``baud_rate``/``bg_flows`` knobs configure the links.

    ``telemetry`` (static) enables the observability metrics ring: a
    positive row capacity records per-superstep time series into
    ``ExperimentResult.telemetry`` (see :mod:`repro.core.telemetry`).
    Purely observational -- results are bitwise identical on or off."""
    params = _scenario_params(fleet, deadline, budget, opt, n_users,
                              scenario)
    if net_cap is None:
        net_cap = safe_net_cap(gridlets_batch, params, fleet, n_users)
    if max_events is None:
        horizon = float(jnp.max(params.deadline)) * 2.0 + 100.0
        max_events = _max_events(gridlets_batch.n, n_users, horizon, 1.0)
    res = engine.run(gridlets_batch, fleet, params, n_users, max_events,
                     max_jobs=safe_max_jobs(gridlets_batch, params, fleet),
                     batch=batch, net_cap=net_cap, telemetry=telemetry)
    return summarize(res, params, n_users, fleet.r, max_events)


def run_experiment_factors(gridlets_batch, fleet, d_factor, b_factor,
                           opt=OPT_COST, n_users: int = 1,
                           max_events: int | None = None,
                           scenario: Scenario | None = None):
    """Paper 4.2.3: derive absolute deadline/budget from D-/B-factors."""
    total_mi = gridlets_batch.length_mi.sum()
    deadline = economy.deadline_from_factor(fleet, total_mi, d_factor)
    budget = economy.budget_from_factor(fleet, total_mi, b_factor)
    return run_experiment(gridlets_batch, fleet, deadline, budget, opt,
                          n_users, max_events, scenario), (deadline, budget)


def _scenario_point(template: engine.SimParams, d, b,
                    n_users: int) -> engine.SimParams:
    """Instantiate one grid point from the sweep's params template."""
    return treplace(template,
                    deadline=jnp.broadcast_to(d, (n_users,)),
                    budget=jnp.broadcast_to(b, (n_users,)))


def _run_point(gridlets_batch, fleet, template, d, b, *, n_users,
               max_events, max_jobs, batch, net_cap, select_free):
    params = _scenario_point(template, d, b, n_users)
    runner = engine.run_sweep if select_free else engine.run_inner
    res = runner(gridlets_batch, fleet, params, n_users, max_events,
                 max_jobs, batch=batch, net_cap=net_cap)
    return summarize(res, params, n_users, fleet.r, max_events)


def _run_lanes_flat(gridlets_batch, fleet, template, dd, bb, *, n_users,
                    max_events, max_jobs, batch, net_cap):
    """Run a flat vector of scenario lanes through the lane-batched
    sweep engine (:func:`engine.run_sweep_lanes`) and summarize each.
    The lane axis lives inside the engine's while loop, so rarely-due
    superstep bodies run under real any-lane ``lax.cond``s instead of
    per-lane masked no-ops -- the batched-throughput term of the sweep
    bench."""
    p_lanes = jax.vmap(
        lambda d, b: _scenario_point(template, d, b, n_users))(dd, bb)
    res = engine.run_sweep_lanes(gridlets_batch, fleet, p_lanes, n_users,
                                 max_events, max_jobs, batch=batch,
                                 net_cap=net_cap)
    return jax.vmap(
        lambda r, p: summarize(r, p, n_users, fleet.r, max_events))(
            res, p_lanes)


@functools.partial(jax.jit, static_argnames=(
    "n_users", "max_events", "max_jobs", "batch", "net_cap",
    "select_free"))
def _sweep_grid(gridlets_batch, fleet, template, deadlines, budgets,
                n_users: int, max_events: int, max_jobs: int,
                batch: int, net_cap: int, select_free: bool):
    """Jitted deadline x budget grid runner.

    Module-level (not a per-call closure) so repeated sweeps over the
    same static shapes hit jax's jit cache instead of retracing -- the
    scenario knobs travel in ``template`` as traced arrays.

    The select-free path flattens the grid deadline-major and runs the
    lane-batched engine loop (see :func:`_run_lanes_flat`); the
    reference path keeps the plain nested vmap.
    """
    if select_free:
        d_grid, b_grid = deadlines.shape[0], budgets.shape[0]
        out = _run_lanes_flat(
            gridlets_batch, fleet, template,
            jnp.repeat(deadlines, b_grid), jnp.tile(budgets, d_grid),
            n_users=n_users, max_events=max_events, max_jobs=max_jobs,
            batch=batch, net_cap=net_cap)
        return jax.tree_util.tree_map(
            lambda x: x.reshape((d_grid, b_grid) + x.shape[1:]), out)

    def one(d, b):
        return _run_point(gridlets_batch, fleet, template, d, b,
                          n_users=n_users, max_events=max_events,
                          max_jobs=max_jobs, batch=batch,
                          net_cap=net_cap, select_free=select_free)

    f = jax.vmap(jax.vmap(one, in_axes=(None, 0)), in_axes=(0, None))
    return f(deadlines, budgets)


def _sweep_statics(gridlets_batch, fleet, deadlines, opt, n_users,
                   max_events, scenario, batch, net_cap, select_free):
    """Shared static-argument resolution for sweep / sweep_sharded."""
    if batch is None:
        batch = engine.DEFAULT_BATCH if select_free else 1
    if max_events is None:
        horizon = float(deadlines.max()) * 2.0 + 100.0
        max_events = _max_events(gridlets_batch.n, n_users, horizon, 1.0)
    template = _scenario_params(fleet, 0.0, 0.0, opt, n_users, scenario)
    max_jobs = safe_max_jobs(gridlets_batch, template, fleet)  # static
    if net_cap is None:
        net_cap = safe_net_cap(gridlets_batch, template, fleet, n_users)
    return template, max_events, max_jobs, batch, net_cap


def sweep(gridlets_batch, fleet, deadlines, budgets, opt=OPT_COST,
          n_users: int = 1, max_events: int | None = None,
          scenario: Scenario | None = None, batch: int | None = None,
          net_cap: int | None = 0, select_free: bool = True):
    """vmap over the full deadline x budget grid (paper Figs 21-24).

    deadlines: [D], budgets: [B] -> every field gains leading [D, B] dims.

    ``select_free`` (default) routes every lane through the sweep
    engine (:func:`engine.run_sweep`): supersteps are committed
    unconditionally with masked no-ops in place of every cond/fallback,
    so under vmap each lane pays only for the work it commits and
    ``batch`` defaults to ``engine.DEFAULT_BATCH``.  With
    ``select_free=False`` the reference path runs instead and ``batch``
    defaults to 1 (under vmap its ``lax.cond`` speculation lowers to
    selects that evaluate both branches, so k > 1 saves nothing).
    Results are bit-for-bit identical either way (asserted by
    tests/test_sweep_engine.py).  ``net_cap`` as in
    :func:`run_experiment` (None = auto-size).
    """
    deadlines = jnp.asarray(deadlines, jnp.float32)
    budgets = jnp.asarray(budgets, jnp.float32)
    template, max_events, max_jobs, batch, net_cap = _sweep_statics(
        gridlets_batch, fleet, deadlines, opt, n_users, max_events,
        scenario, batch, net_cap, select_free)
    return _sweep_grid(gridlets_batch, fleet, template, deadlines,
                       budgets, n_users=n_users, max_events=max_events,
                       max_jobs=max_jobs, batch=batch, net_cap=net_cap,
                       select_free=select_free)


def sweep_sharded(gridlets_batch, fleet, deadlines, budgets,
                  opt=OPT_COST, n_users: int = 1,
                  max_events: int | None = None,
                  scenario: Scenario | None = None,
                  batch: int | None = None, net_cap: int | None = 0,
                  select_free: bool = True, devices=None):
    """:func:`sweep` with the scenario axis sharded across devices.

    The [D, B] grid is flattened deadline-major into one scenario axis
    of S = D*B lanes, padded up to a device multiple, and split across
    ``devices`` (default: all of them) with ``shard_map`` -- each
    device runs its contiguous slice of lanes as an independent vmap,
    so lanes that finish early stop costing while-loop iterations on
    *other* devices (the single-vmap convoy effect).  Inputs are passed
    as replicated operands (no closure capture) and the flattened
    deadline/budget vectors are donated.  Falls back to ``pmap`` when
    ``shard_map`` is unavailable.  Results are bit-for-bit identical to
    :func:`sweep` (asserted by tests/test_sweep_engine.py).
    """
    deadlines = jnp.asarray(deadlines, jnp.float32)
    budgets = jnp.asarray(budgets, jnp.float32)
    template, max_events, max_jobs, batch, net_cap = _sweep_statics(
        gridlets_batch, fleet, deadlines, opt, n_users, max_events,
        scenario, batch, net_cap, select_free)
    d_grid, b_grid = deadlines.shape[0], budgets.shape[0]
    s = d_grid * b_grid
    devices = jax.devices() if devices is None else list(devices)
    n_dev = max(1, len(devices))
    s_pad = -(-s // n_dev) * n_dev
    dd = jnp.repeat(deadlines, b_grid)   # deadline-major flatten [S]
    bb = jnp.tile(budgets, d_grid)
    if s_pad != s:   # pad with copies of the last lane (discarded below)
        dd = jnp.concatenate([dd, jnp.broadcast_to(dd[-1:], (s_pad - s,))])
        bb = jnp.concatenate([bb, jnp.broadcast_to(bb[-1:], (s_pad - s,))])

    def run_lanes(g, f, tmpl, dd_l, bb_l):
        if select_free:
            # Lane-batched engine loop per shard: each device's
            # any-lane cond predicates see only ITS lanes, so a shard
            # whose lanes never poll/reseed skips work other shards pay
            # for -- on top of the convoy-effect win.
            return _run_lanes_flat(g, f, tmpl, dd_l, bb_l,
                                   n_users=n_users,
                                   max_events=max_events,
                                   max_jobs=max_jobs, batch=batch,
                                   net_cap=net_cap)

        def one(d, b):
            return _run_point(g, f, tmpl, d, b, n_users=n_users,
                              max_events=max_events, max_jobs=max_jobs,
                              batch=batch, net_cap=net_cap,
                              select_free=select_free)
        return jax.vmap(one)(dd_l, bb_l)

    out = None
    if n_dev > 1:
        try:
            import numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec as P
            mesh = Mesh(np.asarray(devices), ("s",))
            fn = shard_map(run_lanes, mesh=mesh,
                           in_specs=(P(), P(), P(), P("s"), P("s")),
                           out_specs=P("s"), check_rep=False)
            out = jax.jit(fn, donate_argnums=(3, 4))(
                gridlets_batch, fleet, template, dd, bb)
        except (ImportError, AttributeError):
            fn = jax.pmap(run_lanes, in_axes=(None, None, None, 0, 0),
                          devices=devices)
            out = fn(gridlets_batch, fleet, template,
                     dd.reshape(n_dev, -1), bb.reshape(n_dev, -1))
            out = jax.tree_util.tree_map(
                lambda x: x.reshape((s_pad,) + x.shape[2:]), out)
    if out is None:     # single device: plain jit, same lane layout
        out = jax.jit(run_lanes)(gridlets_batch, fleet, template, dd, bb)
    return jax.tree_util.tree_map(
        lambda x: x[:s].reshape((d_grid, b_grid) + x.shape[1:]), out)
