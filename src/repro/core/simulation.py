"""High-level experiment drivers (the paper's section-4 "recipe").

`run_experiment` = create resources + users + brokers, start the clock,
collect statistics -- one call, one jit.  `sweep` vmaps a whole grid of
(deadline, budget) scenarios, which is how the repo regenerates the
paper's Figures 21-38 in seconds instead of one simulation per point.

`Scenario` bundles the dynamic-resource knobs the pluggable event
sources consume: per-resource MTBF/MTTR failure streams, advance
reservations, and the RNG seed for the failure draws.  The default
(all-zero) scenario registers every source with nothing to do, which is
bit-for-bit identical to not registering them at all -- asserted by
tests/test_superstep.py.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import economy, engine, gridlet
from .types import DONE, OPT_COST


class Scenario(NamedTuple):
    """Dynamic-resource scenario knobs (all optional).

    mtbf: per-resource mean time between failures (scalar or [R]);
        0 or None disables the failure source entirely,
    mttr: per-resource mean time to recovery; 0 or None means instant
        recovery (failures still kill, refund and resubmit the
        resource's in-flight gridlets -- zero-downtime "blips"),
    reservations: a reservation.ReservationBook, an iterable of
        (resource, pes, start, end) tuples, or the exported 4-array
        table (``reservation.maintenance`` builds full-resource
        maintenance windows in this form),
    seed: PRNG seed for the MTBF/MTTR streams,
    baud_rate: per-resource link capacity override for the
        contention-aware network subsystem (scalar or [R]; default:
        ``fleet.baud_rate``) -- consulted when ``run_experiment`` runs
        with ``net_cap > 0``,
    bg_flows: per-resource phantom background flows sharing each link
        (scalar or [R], may be fractional; default 0) -- standing
        non-grid traffic that takes its fair share of the link without
        ever completing; net mode only.
    """
    mtbf: Any = None
    mttr: Any = None
    reservations: Any = None
    seed: int = 0
    baud_rate: Any = None
    bg_flows: Any = None


class ExperimentResult(NamedTuple):
    n_done: jax.Array        # f32[U] gridlets completed per user
    spent: jax.Array         # f32[U] budget spent per user
    term_time: jax.Array     # f32[U] broker termination time
    time_utilization: jax.Array   # f32[U] term_time / deadline
    budget_utilization: jax.Array  # f32[U] spent / budget
    per_resource_done: jax.Array  # f32[U,R] completions by resource
    gridlets: object
    n_events: jax.Array      # i32 events applied by the engine
    n_steps: jax.Array       # i32 engine while-loop iterations
    overflow: jax.Array      # i32 job-slot allocation failures (== 0)
    n_failed: jax.Array      # i32 gridlets hit by a resource failure
    n_resubmits: jax.Array   # i32 FAILED gridlets re-dispatched
    downtime: jax.Array      # f32[R] accumulated down intervals
    truncated: jax.Array     # bool: loop hit max_events before finishing
    n_spec: jax.Array        # i32 speculative supersteps folded into
                             #     the n_steps iterations (k-step batch)
    n_reseeds: jax.Array     # i32 scans that had to re-sort the
                             #     job-slot table (slab carry miss;
                             #     the rest ran sort-free)
    n_scans: jax.Array       # i32 scans performed (committing +
                             #     speculative supersteps, incl.
                             #     declined micro-steps)


def _max_events(n_gridlets: int, n_users: int, horizon: float,
                min_period: float) -> int:
    # 4 events per gridlet lifecycle + broker polls over the horizon.
    # Failure scenarios can repeat lifecycles (fail -> refund ->
    # resubmit); the horizon term usually dominates, but failure-heavy
    # runs should pass an explicit max_events and check
    # ExperimentResult.truncated.
    return int(4 * n_gridlets + horizon / max(min_period, 1e-6) + 64)


def summarize(res: engine.SimResult, params, n_users: int,
              n_resources: int,
              max_events: int | None = None) -> ExperimentResult:
    g = res.gridlets
    done = (g.status == DONE).astype(jnp.float32)
    n_done = jax.ops.segment_sum(done, g.user, num_segments=n_users)
    ur = g.user * n_resources + jnp.clip(g.resource, 0, n_resources - 1)
    per_res = jax.ops.segment_sum(
        done, ur, num_segments=n_users * n_resources
    ).reshape(n_users, n_resources)
    return ExperimentResult(
        n_done=n_done,
        spent=res.spent,
        term_time=res.term_time,
        time_utilization=res.term_time / jnp.maximum(params.deadline, 1e-30),
        budget_utilization=res.spent / jnp.maximum(params.budget, 1e-30),
        per_resource_done=per_res,
        gridlets=g,
        n_events=res.n_events,
        n_steps=res.n_steps,
        overflow=res.overflow,
        n_failed=res.n_failed,
        n_resubmits=res.n_resubmits,
        downtime=res.downtime,
        truncated=(res.n_steps + res.n_spec >= max_events
                   if max_events is not None else jnp.asarray(False)),
        n_spec=res.n_spec,
        n_reseeds=res.n_reseeds,
        n_scans=res.n_scans,
    )


def safe_max_jobs(gridlets_batch, params, fleet) -> int:
    """Static bound on concurrently RUNNING gridlets per resource: the
    broker stages at most max_gridlet_per_pe * num_pe in-flight jobs per
    (user, resource), so the engine's job-slot table never needs more
    than U * that many columns (capped at N)."""
    limit = int(params.max_gridlet_per_pe) * fleet.max_pe
    return min(gridlets_batch.n, params.deadline.shape[0] * limit)


def safe_net_cap(gridlets_batch, params, fleet, n_users: int = 1) -> int:
    """Static bound on concurrent transfers per resource link: the
    broker keeps at most max_gridlet_per_pe * num_pe gridlets in flight
    per (user, resource), and every one of them holds at most one
    transfer (staging or return) at a time -- so U * that many slots
    per link always suffice (capped at N, the broker-less worst case of
    everything routed onto one link)."""
    limit = int(params.max_gridlet_per_pe) * fleet.max_pe
    return min(gridlets_batch.n, n_users * limit)


def _scenario_params(fleet, deadline, budget, opt, n_users,
                     scenario: Scenario | None) -> engine.SimParams:
    s = scenario or Scenario()
    return engine.default_params(
        deadline, budget, opt, n_users, fleet.r,
        mtbf=s.mtbf, mttr=s.mttr, reservations=s.reservations,
        fail_key=jax.random.PRNGKey(s.seed),
        link_baud=(fleet.baud_rate if s.baud_rate is None
                   else s.baud_rate),
        bg_flows=s.bg_flows)


def run_experiment(gridlets_batch, fleet, deadline, budget,
                   opt=OPT_COST, n_users: int = 1,
                   max_events: int | None = None,
                   scenario: Scenario | None = None,
                   batch: int = engine.DEFAULT_BATCH,
                   net_cap: int | None = 0) -> ExperimentResult:
    """``batch`` is the engine's k-step superstep batching factor
    (static; see engine.step_batched) -- results are bit-for-bit
    identical for every value, ``batch=1`` disables speculation.

    ``net_cap`` (static) enables the contention-aware network
    subsystem: 0 (default) keeps the analytic links, ``None`` sizes the
    transfer-slot table automatically (:func:`safe_net_cap`), any
    positive int is the explicit transfer-slot count per link.  The
    scenario's ``baud_rate``/``bg_flows`` knobs configure the links."""
    params = _scenario_params(fleet, deadline, budget, opt, n_users,
                              scenario)
    if net_cap is None:
        net_cap = safe_net_cap(gridlets_batch, params, fleet, n_users)
    if max_events is None:
        horizon = float(jnp.max(params.deadline)) * 2.0 + 100.0
        max_events = _max_events(gridlets_batch.n, n_users, horizon, 1.0)
    res = engine.run(gridlets_batch, fleet, params, n_users, max_events,
                     max_jobs=safe_max_jobs(gridlets_batch, params, fleet),
                     batch=batch, net_cap=net_cap)
    return summarize(res, params, n_users, fleet.r, max_events)


def run_experiment_factors(gridlets_batch, fleet, d_factor, b_factor,
                           opt=OPT_COST, n_users: int = 1,
                           max_events: int | None = None,
                           scenario: Scenario | None = None):
    """Paper 4.2.3: derive absolute deadline/budget from D-/B-factors."""
    total_mi = gridlets_batch.length_mi.sum()
    deadline = economy.deadline_from_factor(fleet, total_mi, d_factor)
    budget = economy.budget_from_factor(fleet, total_mi, b_factor)
    return run_experiment(gridlets_batch, fleet, deadline, budget, opt,
                          n_users, max_events, scenario), (deadline, budget)


def sweep(gridlets_batch, fleet, deadlines, budgets, opt=OPT_COST,
          n_users: int = 1, max_events: int | None = None,
          scenario: Scenario | None = None, batch: int = 1,
          net_cap: int | None = 0):
    """vmap over the full deadline x budget grid (paper Figs 21-24).

    deadlines: [D], budgets: [B] -> every field gains leading [D, B] dims.
    ``batch`` defaults to 1 (no superstep speculation): under vmap the
    speculative path lowers to selects that evaluate both branches, so
    k > 1 saves nothing for swept grids; results are identical anyway.
    ``net_cap`` as in :func:`run_experiment` (None = auto-size).
    """
    deadlines = jnp.asarray(deadlines, jnp.float32)
    budgets = jnp.asarray(budgets, jnp.float32)
    if max_events is None:
        horizon = float(deadlines.max()) * 2.0 + 100.0
        max_events = _max_events(gridlets_batch.n, n_users, horizon, 1.0)
    params0 = engine.default_params(1.0, 1.0, opt, n_users, fleet.r)
    max_jobs = safe_max_jobs(gridlets_batch, params0, fleet)  # static
    if net_cap is None:
        net_cap = safe_net_cap(gridlets_batch, params0, fleet, n_users)

    def one(d, b):
        params = _scenario_params(fleet, d, b, opt, n_users, scenario)
        res = engine.run_inner(gridlets_batch, fleet, params, n_users,
                               max_events, max_jobs, batch=batch,
                               net_cap=net_cap)
        return summarize(res, params, n_users, fleet.r, max_events)

    f = jax.vmap(jax.vmap(one, in_axes=(None, 0)), in_axes=(0, None))
    return jax.jit(f)(deadlines, budgets)
