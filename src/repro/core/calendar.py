"""``gridsim.ResourceCalendar`` -- local (non-grid) load by local time.

The paper models non-grid workload through the resource's time zone,
weekends and holidays.  Vectorised adaptation: the calendar is a pure
function ``load(fleet, t) -> [R]`` giving the instantaneous background load
factor in [0, 1); effective PE capacity is ``mips * (1 - load)``.

Simulation time is interpreted in HOURS_PER_UNIT hours for calendar
purposes (the paper leaves the time unit abstract; experiments in section 5
use load = 0, which is our default as well).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

HOURS_PER_UNIT = 1.0
SATURDAY = 5  # day index with epoch t=0 == Monday 00:00 local at UTC+0
SUNDAY = 6


def local_day_and_hour(t, time_zone):
    """Day-of-week index [0..6] and hour-of-day at the resource's zone."""
    local_hours = t * HOURS_PER_UNIT + time_zone
    day = jnp.floor(local_hours / 24.0).astype(jnp.int32) % 7
    hour = jnp.mod(local_hours, 24.0)
    return day, hour


def load(fleet, t) -> jax.Array:
    """Background load factor per resource at simulation time ``t``."""
    day, _ = local_day_and_hour(t, fleet.time_zone)
    weekend = (day == SATURDAY) | (day == SUNDAY)
    l = fleet.base_load + jnp.where(weekend, fleet.weekend_load, 0.0)
    return jnp.clip(l, 0.0, 0.95)


def effective_mips(fleet, t) -> jax.Array:
    """Per-PE MIPS actually available to grid jobs at time ``t``."""
    return fleet.mips_per_pe * (1.0 - load(fleet, t))
