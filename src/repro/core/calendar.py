"""``gridsim.ResourceCalendar`` -- local (non-grid) load by local time.

The paper models non-grid workload through the resource's time zone,
weekends and holidays.  Vectorised adaptation: the calendar is a pure
function ``load(fleet, t) -> [R]`` giving the instantaneous background load
factor in [0, 1); effective PE capacity is ``mips * (1 - load)``.

Simulation time is interpreted in HOURS_PER_UNIT hours for calendar
purposes (the paper leaves the time unit abstract; experiments in section 5
use load = 0, which is our default as well).

``load`` is piecewise constant between weekday/weekend boundaries, so the
engine integrates PE shares exactly as long as no superstep spans a
boundary.  :func:`next_boundary` gives the first boundary strictly after
``t`` for every resource whose weekend load is nonzero -- the engine's
CALENDAR_STEP event source (see core.des) uses it so boundaries are
first-class events instead of only mattering when another event happens
to land nearby.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

HOURS_PER_UNIT = 1.0
SATURDAY = 5  # day index with epoch t=0 == Monday 00:00 local at UTC+0
SUNDAY = 6


def local_day_and_hour(t, time_zone):
    """Day-of-week index [0..6] and hour-of-day at the resource's zone."""
    local_hours = t * HOURS_PER_UNIT + time_zone
    day = jnp.floor(local_hours / 24.0).astype(jnp.int32) % 7
    hour = jnp.mod(local_hours, 24.0)
    return day, hour


def load(fleet, t) -> jax.Array:
    """Background load factor per resource at simulation time ``t``."""
    day, _ = local_day_and_hour(t, fleet.time_zone)
    weekend = (day == SATURDAY) | (day == SUNDAY)
    l = fleet.base_load + jnp.where(weekend, fleet.weekend_load, 0.0)
    return jnp.clip(l, 0.0, 0.95)


def effective_mips(fleet, t) -> jax.Array:
    """Per-PE MIPS actually available to grid jobs at time ``t``."""
    return fleet.mips_per_pe * (1.0 - load(fleet, t))


# Local week positions (hours since Monday 00:00) of the two load steps:
# Saturday 00:00 (weekend load switches on) and Monday 00:00 (off).
_WEEK = 7 * 24.0
_SAT = float(SATURDAY) * 24.0


def next_boundary(fleet, t) -> jax.Array:
    """Earliest load-calendar step strictly after ``t``, per resource.

    Returns f32[R]; +inf for resources whose ``weekend_load`` is zero
    (their load never steps, so they generate no events -- this is what
    keeps zero-rate scenarios bit-for-bit identical to runs without the
    calendar source).  Boundaries are computed in each resource's local
    time; the strict ``> t`` guard uses the *following* boundary whenever
    f32 rounding would re-land the engine on the instant it just left.
    """
    local = jnp.asarray(t, jnp.float32) * HOURS_PER_UNIT + fleet.time_zone
    w = jnp.mod(local, _WEEK)                       # [R] hours into week
    dh = jnp.where(w < _SAT, _SAT - w, _WEEK - w)   # to next step
    dh2 = jnp.where(w < _SAT, _WEEK - w, _WEEK + _SAT - w)  # the one after
    t_b = t + dh / HOURS_PER_UNIT
    t_b = jnp.where(t_b > t, t_b, t + dh2 / HOURS_PER_UNIT)
    return jnp.where(fleet.weekend_load != 0.0, t_b, jnp.inf)
