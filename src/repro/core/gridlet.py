"""Gridlet batches (struct-of-arrays form of ``gridsim.Gridlet``).

A Gridlet is the unit of schedulable work: job length in MI (million
instructions), input/output payload sizes in bytes, and the originating
user.  The SoA layout is the vectorised analogue of ``gridsim.GridletList``:
one fixed-capacity table holds every Gridlet of every user in the
simulation, which is what lets the whole experiment run inside one jit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rand
from .types import CREATED, INF, pytree_dataclass


@pytree_dataclass
class GridletBatch:
    """All per-gridlet state. Shape [N] everywhere."""

    # --- immutable description (gridsim.Gridlet fields) ---
    length_mi: jax.Array      # f32: processing requirement in MI
    in_bytes: jax.Array       # f32: input file size
    out_bytes: jax.Array      # f32: output file size
    user: jax.Array           # i32: originating user entity
    created: jax.Array        # f32: submission time at the broker

    # --- mutable lifecycle state (gridsim.ResGridlet fields) ---
    status: jax.Array         # i32: types.CREATED .. FAILED
    resource: jax.Array       # i32: assigned resource (-1 = none)
    assigned: jax.Array       # i32: broker's planned resource (-1 = none)
    remaining: jax.Array      # f32: remaining MI
    t_event: jax.Array        # f32: pending arrival/return timestamp (else inf)
    start: jax.Array          # f32: first execution instant at the resource
    finish: jax.Array         # f32: completion instant at the resource
    returned: jax.Array       # f32: instant the result reached the broker
    cost: jax.Array           # f32: committed processing cost (G$)
    n_retries: jax.Array      # i32: times this gridlet was failed+refunded
    retry_at: jax.Array       # f32: earliest re-dispatch instant (backoff)

    @property
    def n(self) -> int:
        return self.length_mi.shape[0]


def make_batch(length_mi, in_bytes=None, out_bytes=None, user=None,
               created=None) -> GridletBatch:
    length_mi = jnp.asarray(length_mi, jnp.float32)
    n = length_mi.shape[0]
    zeros = jnp.zeros((n,), jnp.float32)

    def arr(x, default, dtype=jnp.float32):
        if x is None:
            return default
        return jnp.broadcast_to(jnp.asarray(x, dtype), (n,))

    return GridletBatch(
        length_mi=length_mi,
        in_bytes=arr(in_bytes, zeros),
        out_bytes=arr(out_bytes, zeros),
        user=arr(user, jnp.zeros((n,), jnp.int32), jnp.int32),
        created=arr(created, zeros),
        status=jnp.full((n,), CREATED, jnp.int32),
        resource=jnp.full((n,), -1, jnp.int32),
        assigned=jnp.full((n,), -1, jnp.int32),
        remaining=length_mi,
        t_event=jnp.full((n,), INF, jnp.float32),
        start=jnp.full((n,), INF, jnp.float32),
        finish=jnp.full((n,), INF, jnp.float32),
        returned=jnp.full((n,), INF, jnp.float32),
        cost=zeros,
        n_retries=jnp.zeros((n,), jnp.int32),
        retry_at=zeros,
    )


def task_farm(key: jax.Array, n_jobs: int, n_users: int = 1,
              base_mi: float = 10_000.0, noise: float = 0.10,
              in_bytes: float = 0.0, out_bytes: float = 0.0) -> GridletBatch:
    """Paper section 5.2 application model.

    ``n_jobs`` Gridlets per user, each at least ``base_mi`` MI with a random
    0..``noise`` variation on the positive side (GridSimRandom.real with
    f_L=0, f_M=noise).  base_mi=10,000 MI == 100 time units on the standard
    100-MIPS PE (gridsim.GridSimStandardPE).
    """
    n = n_jobs * n_users
    mi = rand.real(key, jnp.full((n,), base_mi, jnp.float32), 0.0, noise)
    user = jnp.repeat(jnp.arange(n_users, dtype=jnp.int32), n_jobs)
    return make_batch(mi, in_bytes=in_bytes, out_bytes=out_bytes, user=user)
