"""Advance reservation (paper feature list: "Resources can be booked").

Launch-level (non-jit) capacity calendar: bookings hold PEs on a resource
over [start, end).  The engine consumes reservations as a background-load
term; the launcher uses it to hold slices for scheduled jobs.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import List


@dataclasses.dataclass(frozen=True)
class Reservation:
    rid: int
    resource: int
    pes: int
    start: float
    end: float
    user: int = 0


class ReservationBook:
    """Per-resource booking calendar with conflict detection."""

    def __init__(self, num_pe: List[int]):
        self.num_pe = list(num_pe)
        self._by_resource: List[List[Reservation]] = \
            [[] for _ in self.num_pe]
        self._ids = itertools.count()

    def peak_usage(self, resource: int, start: float, end: float) -> int:
        """Max PEs simultaneously booked on [start, end)."""
        events = []
        for r in self._by_resource[resource]:
            if r.end <= start or r.start >= end:
                continue
            events.append((max(r.start, start), r.pes))
            events.append((min(r.end, end), -r.pes))
        events.sort()
        peak = cur = 0
        for _, d in events:
            cur += d
            peak = max(peak, cur)
        return peak

    def book(self, resource: int, pes: int, start: float,
             end: float, user: int = 0) -> Reservation:
        if not 0 <= resource < len(self.num_pe):
            raise ValueError(f"no such resource {resource}")
        if pes <= 0 or end <= start:
            raise ValueError("reservation must hold >0 PEs over >0 time")
        if self.peak_usage(resource, start, end) + pes \
                > self.num_pe[resource]:
            raise ValueError("reservation conflict: not enough free PEs")
        res = Reservation(next(self._ids), resource, pes, start, end, user)
        bisect.insort(self._by_resource[resource], res,
                      key=lambda r: r.start)
        return res

    def cancel(self, res: Reservation) -> None:
        self._by_resource[res.resource].remove(res)

    def reserved_pes(self, resource: int, t: float) -> int:
        return sum(r.pes for r in self._by_resource[resource]
                   if r.start <= t < r.end)

    def load_factor(self, resource: int, t: float) -> float:
        """Reservation-induced load for calendar.effective_mips."""
        return self.reserved_pes(resource, t) / max(self.num_pe[resource], 1)
