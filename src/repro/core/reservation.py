"""Advance reservation (paper feature list: "Resources can be booked").

Two layers:

* ``ReservationBook`` -- the launch-level (non-jit) booking calendar with
  conflict detection.  Drivers build bookings here, then export them with
  :meth:`ReservationBook.as_tables` / :func:`as_tables`.
* jit-side helpers over the exported ``(resource, pes, start, end)``
  arrays (shape ``[K]`` each, ``K`` may be 0).  The engine's RESERVATION
  event source (see core.des) uses :func:`next_boundary` to wake the
  superstep loop exactly when a committed window opens or closes, and
  :func:`active_pes` to know how many PEs are blocked *now*: blocked PEs
  are subtracted from the capacity the ``[R, J]`` job-slot table exposes
  -- time-shared rows compute Fig 8 shares over the unreserved PEs
  (kernels.event_scan's ``pe_blocked`` input), space-shared rows admit
  only onto unreserved PEs.  Windows are half-open ``[start, end)``.
  Reservations gate *admission*; jobs already running when a window
  opens are not preempted (drivers that need a hard guarantee size
  bookings against ``peak_usage`` before the run).
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import List

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Reservation:
    rid: int
    resource: int
    pes: int
    start: float
    end: float
    user: int = 0


class ReservationBook:
    """Per-resource booking calendar with conflict detection."""

    def __init__(self, num_pe: List[int]):
        self.num_pe = list(num_pe)
        self._by_resource: List[List[Reservation]] = \
            [[] for _ in self.num_pe]
        self._ids = itertools.count()

    def peak_usage(self, resource: int, start: float, end: float) -> int:
        """Max PEs simultaneously booked on [start, end)."""
        events = []
        for r in self._by_resource[resource]:
            if r.end <= start or r.start >= end:
                continue
            events.append((max(r.start, start), r.pes))
            events.append((min(r.end, end), -r.pes))
        events.sort()
        peak = cur = 0
        for _, d in events:
            cur += d
            peak = max(peak, cur)
        return peak

    def book(self, resource: int, pes: int, start: float,
             end: float, user: int = 0) -> Reservation:
        if not 0 <= resource < len(self.num_pe):
            raise ValueError(f"no such resource {resource}")
        if pes <= 0 or end <= start:
            raise ValueError("reservation must hold >0 PEs over >0 time")
        if self.peak_usage(resource, start, end) + pes \
                > self.num_pe[resource]:
            raise ValueError("reservation conflict: not enough free PEs")
        res = Reservation(next(self._ids), resource, pes, start, end, user)
        bisect.insort(self._by_resource[resource], res,
                      key=lambda r: r.start)
        return res

    def cancel(self, res: Reservation) -> None:
        self._by_resource[res.resource].remove(res)

    def reserved_pes(self, resource: int, t: float) -> int:
        return sum(r.pes for r in self._by_resource[resource]
                   if r.start <= t < r.end)

    def load_factor(self, resource: int, t: float) -> float:
        """Reservation-induced load for calendar.effective_mips."""
        return self.reserved_pes(resource, t) / max(self.num_pe[resource], 1)

    def book_maintenance(self, resource: int, start: float,
                         end: float) -> Reservation:
        """Book a maintenance window: every PE of ``resource`` held
        over [start, end) -- planned downtime as sugar over the
        reservation machinery (conflict detection included: grid
        bookings overlapping the window raise)."""
        return self.book(resource, self.num_pe[resource], start, end)

    def as_tables(self):
        """Export all bookings as the engine's (res, pes, start, end)
        i32/i32/f32/f32 arrays, each shape [K]."""
        rows = sorted((r for per in self._by_resource for r in per),
                      key=lambda r: (r.start, r.rid))
        return as_tables([(r.resource, r.pes, r.start, r.end)
                          for r in rows])


def as_tables(bookings):
    """(resource, pes, start, end) tuples -> the engine's array form."""
    bookings = list(bookings or [])
    res = jnp.asarray([b[0] for b in bookings], jnp.int32)
    pes = jnp.asarray([b[1] for b in bookings], jnp.int32)
    start = jnp.asarray([b[2] for b in bookings], jnp.float32)
    end = jnp.asarray([b[3] for b in bookings], jnp.float32)
    return res, pes, start, end


def empty_tables():
    """The K=0 no-reservations table (the default scenario)."""
    return as_tables([])


def maintenance(num_pe, windows):
    """Maintenance windows as booking tuples: each ``(resource, start,
    end)`` window holds ALL PEs of its resource over [start, end) --
    planned downtime as sugar over the reservation event source (the
    deterministic cousin of the MTBF failure stream: admission stops,
    residents are not preempted, queued work re-admits at ``end``).

    ``num_pe`` is the fleet's per-resource PE count (``fleet.num_pe``
    or a plain list).  The result plugs straight into
    ``simulation.Scenario(reservations=...)`` or ``engine.run_direct``;
    combine with other bookings by concatenating the lists (or use
    :meth:`ReservationBook.book_maintenance` for conflict checking).
    """
    pes = [int(p) for p in num_pe]
    return [(int(r), pes[int(r)], float(s), float(e))
            for r, s, e in windows]


def active_pes(resv_res, resv_pes, resv_start, resv_end, t,
               n_resources: int) -> jax.Array:
    """PEs blocked by committed windows at time ``t``: i32[R].

    Windows are half-open, so at exactly ``t == end`` the PEs are free
    again (the engine's RESERVATION event at ``end`` re-admits queued
    work at that instant).  K = 0 returns all-zeros.
    """
    active = (resv_start <= t) & (t < resv_end)
    return jax.ops.segment_sum(
        jnp.where(active, resv_pes, 0),
        jnp.clip(resv_res, 0, n_resources - 1),
        num_segments=n_resources)


def boundary_candidates(resv_start, resv_end, t) -> jax.Array:
    """Window open/close instants strictly after ``t`` as an f32[2K]
    candidate vector (+inf where already passed) -- the engine's
    RESERVATION event-source `candidates` contract (see core.des); the
    fused frontier pass takes the min."""
    cand = jnp.concatenate([resv_start, resv_end])
    return jnp.where(cand > t, cand, jnp.inf)


def next_boundary(resv_start, resv_end, t) -> jax.Array:
    """Earliest window open/close instant strictly after ``t`` (f32
    scalar; +inf when no boundary remains -- in particular for the K=0
    table).  Thin min-wrapper over :func:`boundary_candidates`."""
    cand = jnp.concatenate([boundary_candidates(resv_start, resv_end, t),
                            jnp.full((1,), jnp.inf, jnp.float32)])
    return cand.min()
