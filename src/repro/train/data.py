"""Deterministic synthetic data pipeline with checkpointable state.

Generates a document-structured token stream (Zipf-ish unigram draws with
BOS-delimited documents, packed to fixed length), sharded by data-parallel
rank so every host produces disjoint data -- the standard multi-host
pattern.  The pipeline is a pure function of (seed, step, rank), so
restarts resume bit-identically from any step (no iterator state to save
beyond the step counter already in the checkpoint).
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 rank: int = 0, world: int = 1, bos: int = 1,
                 mean_doc_len: int = 64):
        assert batch % world == 0
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed, self.rank, self.world = seed, rank, world
        self.bos = bos
        self.mean_doc_len = mean_doc_len
        # Zipf-like unigram distribution (stable across steps)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int) -> dict:
        """Pure function of step: {tokens, targets} for this rank."""
        rng = np.random.RandomState(
            ((self.seed * 1_000_003 + step) * 65_537 + self.rank)
            % (2**32 - 1))
        local = self.batch // self.world
        toks = rng.choice(self.vocab, size=(local, self.seq + 1),
                          p=self._probs).astype(np.int32)
        # BOS-delimited documents (packing)
        doc_break = rng.rand(local, self.seq + 1) < 1.0 / self.mean_doc_len
        toks = np.where(doc_break, self.bos, toks)
        toks[:, 0] = self.bos
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iter_from(self, step: int) -> Iterator[dict]:
        while True:
            yield self.batch_at(step)
            step += 1


def for_model(cfg, batch: int, seq: int, seed: int = 0, rank: int = 0,
              world: int = 1, extras_key: Optional[jax.Array] = None):
    """Iterator adding per-family extra fields (vision / audio stubs)."""
    base = SyntheticLM(cfg.vocab, batch, seq, seed, rank, world)

    def gen():
        step = 0
        for b in base.iter_from(0):
            if cfg.family == "vlm":
                nv = cfg.n_vision_tokens
                rng = np.random.RandomState(seed * 77 + step)
                b["tokens"] = b["tokens"][:, : seq - nv]
                b["vision_embeds"] = jnp.asarray(
                    rng.randn(b["targets"].shape[0], nv,
                              cfg.d_model).astype(np.float32))
                pos = np.tile(np.arange(seq)[None, None],
                              (3, b["targets"].shape[0], 1))
                b["positions3"] = jnp.asarray(pos.astype(np.int32))
            elif cfg.family == "encdec":
                rng = np.random.RandomState(seed * 77 + step)
                b["audio_embed"] = jnp.asarray(
                    rng.randn(b["targets"].shape[0], cfg.enc_seq,
                              cfg.d_model).astype(np.float32))
            yield b
            step += 1

    return gen()
