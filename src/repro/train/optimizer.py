"""Pure-JAX AdamW with global-norm clipping and warmup-cosine schedule.

Optimizer state is a pytree shaped like params (m, v), so the same
sharding specs apply -- fully sharded optimizer state under FSDP.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params):
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": zeros,
             "v": jax.tree_util.tree_map(jnp.copy, zeros),
             "step": jnp.zeros((), jnp.int32)}
    # Mixed precision: bf16 working params keep an fp32 master copy so
    # gradients reduce in bf16 (and FSDP gathers move bf16 shards) while
    # updates accumulate in fp32 (Megatron-style distributed optimizer).
    if any(l.dtype != jnp.float32
           for l in jax.tree_util.tree_leaves(params)):
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        base = p.astype(jnp.float32) if master is None else master
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * delta
        return new_master.astype(p.dtype), m, v, new_master

    has_master = "master" in opt_state
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_ma = tdef.flatten_up_to(opt_state["master"]) if has_master \
        else [None] * len(flat_p)
    new = [upd(p, g, m, v, ma) for p, g, m, v, ma in
           zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    new_params = tdef.unflatten([x[0] for x in new])
    new_opt = {"m": tdef.unflatten([x[1] for x in new]),
               "v": tdef.unflatten([x[2] for x in new]),
               "step": step}
    if has_master:
        new_opt["master"] = tdef.unflatten([x[3] for x in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_opt, metrics
