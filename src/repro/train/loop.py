"""Training step + loop: mixed-precision, remat, optional gradient
compression, checkpoint/restart, straggler accounting.

``make_train_step`` builds the pure (state, batch) -> (state, metrics)
function the dry-run lowers; ``fit`` is the CPU-scale driver used by the
examples (100M-class models for a few hundred steps).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import checkpoint as ckpt_mod
from . import compression as comp_mod
from . import optimizer as opt_mod


def init_state(api, key, opt_cfg: opt_mod.AdamWConfig):
    params = api.init(key)
    return {"params": params, "opt": opt_mod.init(params)}


def make_train_step(api, opt_cfg: opt_mod.AdamWConfig,
                    compress: str = "none", k_frac: float = 0.01):
    """Returns train_step(state, batch) -> (state, metrics)."""
    use_ef = compress != "none"

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(api.loss)(state["params"], batch)
        if use_ef:
            grads, ef = comp_mod.compress(grads, state["ef"],
                                          method=compress, k_frac=k_frac)
        params, opt, metrics = opt_mod.update(opt_cfg, grads,
                                              state["opt"],
                                              state["params"])
        new_state = {"params": params, "opt": opt}
        if use_ef:
            new_state["ef"] = ef
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def fit(api, data_iter, opt_cfg: opt_mod.AdamWConfig, steps: int,
        seed: int = 0, ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100, compress: str = "none",
        log_every: int = 10, log_fn: Callable = print,
        resume: bool = True) -> Dict[str, Any]:
    """CPU-scale training driver with checkpoint/restart."""
    state = init_state(api, jax.random.PRNGKey(seed), opt_cfg)
    if compress != "none":
        state["ef"] = comp_mod.init_error_feedback(state["params"])
    start = 0
    saver = None
    if ckpt_dir:
        saver = ckpt_mod.AsyncCheckpointer(ckpt_dir, keep=3)
        last = ckpt_mod.latest_step(ckpt_dir) if resume else None
        if last is not None:
            state = ckpt_mod.restore(ckpt_dir, last, state)
            start = last
            log_fn(f"resumed from step {last}")

    step_fn = jax.jit(make_train_step(api, opt_cfg, compress))
    history = []
    durations = []
    for step in range(start, steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        durations.append(time.perf_counter() - t0)
        history.append(metrics)
        if log_every and (step + 1) % log_every == 0:
            log_fn(f"step {step + 1}: loss={metrics['loss']:.4f} "
                   f"gnorm={metrics['grad_norm']:.3f} "
                   f"lr={metrics['lr']:.2e}")
        if saver and (step + 1) % ckpt_every == 0:
            saver.submit(step + 1, state)
    if saver:
        saver.submit(steps, state)
        saver.close()
    return {"state": state, "history": history, "durations": durations}
