"""Fault-tolerant checkpointing: atomic, sharded, restart-capable.

Layout (one directory per step):
    <dir>/step_000010.tmp.<nonce>/   -- staging (crash leaves only tmp)
    <dir>/step_000010/
        manifest.json                -- tree structure, shapes, dtypes
        arr_00000.npy ...            -- one file per leaf
Atomicity: staging dir + os.rename (POSIX-atomic within a filesystem).
Restore reshards onto the current mesh via device_put with the target
shardings, so a checkpoint written on one mesh restarts on another
(elastic re-mesh path; see dist.fault).  Async saves run on a daemon
thread pool of 1 (ordered), and ``keep`` bounds retained checkpoints.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import uuid
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths_of(tree):
    return [jax.tree_util.keystr(p) for p, _ in
            jax.tree_util.tree_flatten_with_path(tree)[0]]


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Blocking atomic save; returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp.{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "paths": _paths_of(tree),
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "treedef": str(treedef),
        "n_leaves": len(leaves),
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"arr_{i:05d}.npy"),
                np.asarray(jax.device_get(leaf)))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    # clean stale staging dirs from crashed saves
    for name in os.listdir(directory):
        if ".tmp." in name:
            shutil.rmtree(os.path.join(directory, name),
                          ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp." not in name and \
                os.path.exists(os.path.join(directory, name,
                                            "manifest.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (pytree matching ``like``) to reshard onto a new mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    like_leaves, treedef = _flatten(like)
    if manifest["n_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"target structure has {len(like_leaves)}")
    leaves = [np.load(os.path.join(path, f"arr_{i:05d}.npy"))
              for i in range(manifest["n_leaves"])]
    for got, want in zip(leaves, like_leaves):
        if tuple(got.shape) != tuple(want.shape):
            raise ValueError(
                f"shape mismatch {got.shape} vs {want.shape}")
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class AsyncCheckpointer:
    """Ordered background saves; ``wait()`` drains before shutdown."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.directory, step, tree, self.keep)
            except BaseException as e:  # surfaced on wait()
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any):
        # device_get now so the saved snapshot is consistent
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err is not None:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
