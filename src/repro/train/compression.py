"""Gradient compression with error feedback (cross-pod DCN saver).

Two codecs:
  * int8 per-tensor-scaled quantisation (8x over fp32 wire format, 2x
    over bf16),
  * top-k magnitude sparsification (rate = k_frac).

Both keep an error-feedback residual (Stich et al., "Sparsified SGD with
memory") so compression error is re-injected next step instead of lost.

``compress`` is a pure function applied to gradients before the optimizer;
on a multi-pod mesh the intent is that the pod-axis reduction runs on the
compressed representation -- ``pod_allreduce_int8`` does exactly that with
an explicit shard_map + psum over the "pod" axis.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _topk_mask(g, k_frac: float):
    flat = jnp.abs(g.reshape(-1))
    k = max(int(k_frac * flat.size), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress(grads, ef, method: str = "int8", k_frac: float = 0.01):
    """(grads', ef'): error-feedback compressed gradients."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if method == "int8":
            sent = _dequant_int8(*_quant_int8(gf))
        elif method == "topk":
            sent = gf * _topk_mask(gf, k_frac)
        elif method == "none":
            sent = gf
        else:
            raise ValueError(method)
        return sent.astype(g.dtype), gf - sent

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])


def compression_ratio(method: str, k_frac: float = 0.01,
                      dtype_bits: int = 32) -> float:
    """Wire-bytes ratio vs uncompressed fp32 (for the roofline model)."""
    if method == "int8":
        return 8 / dtype_bits
    if method == "topk":
        return k_frac * (1 + 32 / dtype_bits)  # values + indices
    return 1.0


def pod_allreduce_int8(grads, mesh):
    """Explicit compressed all-reduce over the 'pod' (DCN) axis.

    Each pod quantises its partial gradient to int8, the psum runs on the
    int8 payload (widened to int32 for exact accumulation), and the result
    is dequantised locally: wire bytes are 1/4 of fp32.  Intra-pod (ICI)
    reduction stays full precision.
    """
    if "pod" not in mesh.axis_names:
        return grads
    npods = mesh.shape["pod"]

    def reduce_one(g):
        q, scale = _quant_int8(g.astype(jnp.float32))
        total = jax.lax.psum(q.astype(jnp.int32), "pod")
        smax = jax.lax.pmax(scale, "pod")  # conservative shared scale
        return (total.astype(jnp.float32) * smax / npods).astype(g.dtype)

    spec = P()  # gradients replicated across pods at this point

    @functools.partial(
        jax.shard_map, mesh=mesh, in_specs=(spec,), out_specs=spec)
    def run(g):
        return jax.tree_util.tree_map(reduce_one, g)

    return run(grads)
