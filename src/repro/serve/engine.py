"""Continuous-batching serving engine.

A fixed pool of decode slots (static shapes for jit): requests prefill
into a free slot, every ``step()`` decodes one token for all active slots,
finished sequences free their slot immediately for the next queued
request (slot-level continuous batching, vLLM-style but with dense
per-slot caches -- paged KV is out of scope for this paper's layer).

CPU-scale by design: the examples serve smoke-sized models; the dry-run
lowers the same ``prefill``/``decode`` step functions at production shape.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, api, params, *, slots: int = 4, max_len: int = 128,
                 temperature: float = 0.0, seed: int = 0):
        if api.cfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                "demo server handles decoder-only LMs")
        self.api = api
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: "collections.deque[Request]" = collections.deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.lengths = np.zeros((slots,), np.int32)
        self.cache = api.init_cache(slots, max_len, dtype=jnp.float32)
        self.last_token = np.zeros((slots, 1), np.int32)

        # per-slot prefill (batch=1) + batched decode, both jitted once
        self._prefill1 = jax.jit(
            lambda params, cache, tokens: api.prefill(
                params, {"tokens": tokens, "cache": cache}))
        self._decode = jax.jit(api.decode)

    # -- bookkeeping -----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue.popleft()
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            cache1 = jax.tree_util.tree_map(
                lambda a: a[..., slot:slot + 1, :, :, :]
                if False else a, self.cache)
            # prefill with batch=1 into a scratch cache, then copy in
            scratch = self.api.init_cache(1, self.max_len,
                                          dtype=jnp.float32)
            logits, scratch = self._prefill1(self.params, scratch, toks)
            self.cache = jax.tree_util.tree_map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot,
                    axis=self._batch_axis(full)), self.cache, scratch)
            self.active[slot] = req
            self.lengths[slot] = len(req.prompt)
            self.last_token[slot, 0] = int(jnp.argmax(logits[0, -1]))
            req.generated.append(int(self.last_token[slot, 0]))

    def _batch_axis(self, leaf) -> int:
        # caches are stacked [n_layers_stack, B, ...]: batch axis == 1
        return 1

    # -- main loop -------------------------------------------------------
    def step(self) -> List[Request]:
        """Admit, decode one token for all active slots, retire finished.
        Returns requests finished this step."""
        self._admit()
        if not self.active:
            return []
        ci = jnp.asarray(int(self.lengths[list(self.active)].max()),
                         jnp.int32)
        # NOTE: per-slot lengths differ; dense demo uses the max index and
        # relies on causal masking via kv_valid (acceptable CPU demo
        # semantics; production uses per-slot cache_index vectors).
        batch = {"tokens": jnp.asarray(self.last_token),
                 "cache_index": ci}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = jax.random.categorical(
                sub, logits[:, -1] / self.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        nxt = np.asarray(nxt)

        finished = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.lengths[slot] += 1
            self.last_token[slot, 0] = tok
            if (req.eos is not None and tok == req.eos) or \
                    len(req.generated) >= req.max_new_tokens or \
                    self.lengths[slot] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                del self.active[slot]
        return finished

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        done = []
        for _ in range(max_steps):
            done += self.step()
            if not self.active and not self.queue:
                break
        return done
