"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 class).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is the
DCN dimension -- data parallelism with gradient compression attaches
there, while "model" stays inside the ICI domain.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1,
                   axis_names=("data", "model")):
    """Small mesh over available (host) devices for tests/examples."""
    return jax.make_mesh((data, model), axis_names)
