"""Training launcher: pick an architecture, mesh and scale; run.

On this CPU container it trains reduced configs on a host mesh; pointed
at a real TPU slice the same code paths run the production mesh (the
dry-run proves every assigned config compiles there).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b \
      [--steps 100] [--batch 8] [--seq 128] [--data N --model M] \
      [--full] [--compress int8] [--ckpt DIR]
"""
import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (TPU-scale)")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro import configs
    from repro.dist import sharding as sh
    from repro.launch.mesh import make_host_mesh
    from repro.models import count_params, make
    from repro.train import data as data_mod
    from repro.train import loop, optimizer as opt_mod

    cfg = configs.get(args.arch) if args.full else configs.SMOKES[args.arch]
    total, active = count_params(cfg)
    print(f"{cfg.name}: {total/1e6:.1f}M params "
          f"({active/1e6:.1f}M active)")

    api = make(cfg)
    it = data_mod.for_model(cfg, batch=args.batch, seq=args.seq, seed=0)
    ocfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=10,
                               total_steps=args.steps)

    if args.data * args.model > 1:
        mesh = make_host_mesh(args.data, args.model)
        print(f"mesh {dict(mesh.shape)}")
        with mesh:
            out = loop.fit(api, it, ocfg, steps=args.steps,
                           ckpt_dir=args.ckpt, compress=args.compress)
    else:
        out = loop.fit(api, it, ocfg, steps=args.steps,
                       ckpt_dir=args.ckpt, compress=args.compress)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
