"""HLO-text analysis: execution-weighted collective-transfer bytes.

``cost_analysis()`` gives FLOPs/bytes but not collective traffic, so the
roofline's third term is derived here: parse the compiled (partitioned)
HLO module, walk the computation graph from ENTRY, multiply everything
inside a ``while`` body by its trip count (jax scans lower to whiles whose
condition compares the induction variable against a constant), and charge
each collective a ring-model transfer cost per participating chip:

  all-gather         bytes_out * (g-1)/g
  reduce-scatter     bytes_out * (g-1)        (output is the shard)
  all-reduce         2 * bytes * (g-1)/g      (reduce-scatter + all-gather)
  all-to-all         bytes * (g-1)/g
  collective-permute bytes

g = replica-group size.  Byte counts are per-chip (the HLO is the
per-partition module after GSPMD).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of all array literals in an HLO type string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [n_groups, group_size]
        return int(m.group(2))
    return total_devices


def _split_computations(text: str) -> Dict[str, List[str]]:
    """computation name -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{$",
                     line.rstrip())
        if m and ("->" in line or line.startswith("ENTRY")
                  or re.match(r"^%[\w\.\-]+", line)):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None and s:
            comps[cur].append(s)
    return comps


_CALLSITE_RE = re.compile(
    r"(?:condition|body|branch_computations|called_computations|to_apply|"
    r"calls)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")


def _trip_count(cond_lines: List[str]) -> int:
    """Largest s32 constant in the while condition == scan length bound."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(text: str, total_devices: int) -> Dict[str, float]:
    """Execution-weighted per-chip transfer bytes by collective kind."""
    comps = _split_computations(text)
    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str, depth: int = 0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 40:
            return {}
        memo[name] = {}  # break cycles
        out: Dict[str, float] = defaultdict(float)
        for line in comps[name]:
            # result type = first shape literal(s) before the op name
            opm = re.search(r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
                            r"(?:\{[^}]*\})?))\s+([\w\-]+)", line)
            if not opm:
                continue
            rtype, op = opm.group(1), opm.group(2)
            base = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if base and not op.endswith("-done"):
                g = _group_size(line, total_devices)
                b = _shape_bytes(rtype)
                if base == "all-gather":
                    out[base] += b * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    out[base] += b * (g - 1)
                elif base == "all-reduce":
                    out[base] += 2 * b * (g - 1) / max(g, 1)
                elif base == "all-to-all":
                    out[base] += b * (g - 1) / max(g, 1)
                else:  # collective-permute
                    out[base] += b
            if op == "while":
                callees = _CALLSITE_RE.findall(line)
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                for k, v in walk(body, depth + 1).items() if body else ():
                    out[k] += v * trips
            elif op == "conditional":
                branches = re.search(
                    r"branch_computations=\{([^}]*)\}", line)
                names = []
                if branches:
                    names = [n.strip().lstrip("%")
                             for n in branches.group(1).split(",")]
                else:
                    names = [n.strip().lstrip("%") for grp in
                             re.findall(r"(?:true|false)_computation="
                                        r"%?([\w\.\-]+)", line) for n in
                             [grp]]
                agg: Dict[str, float] = defaultdict(float)
                for n in names:
                    for k, v in walk(n, depth + 1).items():
                        agg[k] = max(agg[k], v)
                for k, v in agg.items():
                    out[k] += v
            elif op in ("call", "custom-call", "fusion", "async-start",
                        "all-reduce-start"):
                m = re.search(r"(?:to_apply|called_computations=\{)"
                              r"%?([\w\.\-]+)", line)
                if m:
                    for k, v in walk(m.group(1), depth + 1).items():
                        out[k] += v
        memo[name] = dict(out)
        return memo[name]

    entry = "__entry__"
    if entry not in comps:
        # fall back: treat whole text as one computation
        comps[entry] = [l.strip() for l in text.splitlines()]
    return dict(walk(entry))


def total_collective_bytes(text: str, total_devices: int) -> float:
    return float(sum(collective_bytes(text, total_devices).values()))


_SKIP_BYTES_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+"
    r"\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)")


def _symtab(lines: List[str]) -> Dict[str, str]:
    tab: Dict[str, str] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            tab[m.group(1)] = m.group(2)
    return tab


def _dot_flops(line: str, result_type: str, tab: Dict[str, str]) -> float:
    args = re.search(r"\bdot\(([^)]*)\)", line)
    if not args:
        return 0.0
    ops = re.findall(r"%([\w\.\-]+)", args.group(1))
    if not ops or ops[0] not in tab:
        return 0.0
    lhs = tab[ops[0]]
    md = _SHAPE_RE.search(lhs)
    if not md:
        return 0.0
    dims = [int(d) for d in md.group(2).split(",")] if md.group(2) else []
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if cd and cd.group(1):
        for i in cd.group(1).split(","):
            if int(i) < len(dims):
                contract *= dims[int(i)]
    rd = _SHAPE_RE.search(result_type)
    numel = 1
    if rd and rd.group(2):
        for d in rd.group(2).split(","):
            numel *= int(d)
    return 2.0 * numel * contract


def weighted_cost(text: str) -> Dict[str, float]:
    """Execution-weighted per-chip dot-FLOPs and HBM traffic bytes.

    Unlike ``compiled.cost_analysis()`` (which visits every instruction
    once), this multiplies `while` bodies by their trip counts -- jax
    scans over layers / attention block pairs / loss chunks otherwise
    undercount by the scan length.  HBM bytes are counted at top-level
    instruction boundaries (fusion internals are VMEM-resident).
    """
    comps = _split_computations(text)
    tabs = {name: _symtab(lines) for name, lines in comps.items()}
    # computations reached via fusion `calls=` hold no HBM traffic
    fused: set = set()
    for lines in comps.values():
        for line in lines:
            if re.search(r"\bfusion\(", line):
                m = re.search(r"calls=%?([\w\.\-]+)", line)
                if m:
                    fused.add(m.group(1))
    memo: Dict[str, Tuple[float, float]] = {}

    def _fusion_bytes(line: str, rtype: str, tab: Dict[str, str]) -> float:
        """HBM traffic of one fusion call.

        A fusion that only *slices* an operand reads the slice, not the
        buffer (the flash pair-scan's dynamic-slice+einsum fusions would
        otherwise look ~100x more HBM-bound than they are).  Charge each
        operand by how the called computation consumes its parameter:
        slice-family consumers -> 2x the largest slice; otherwise the
        full buffer.  A dynamic-update-slice root writes only the update
        region.
        """
        m = re.search(r"calls=%?([\w\.\-]+)", line)
        callee = comps.get(m.group(1), []) if m else []
        ctab = tabs.get(m.group(1), {}) if m else {}
        args = re.search(r"\bfusion\(([^)]*)\)", line)
        ops_ = re.findall(r"%([\w\.\-]+)", args.group(1)) if args else []
        # map parameter index -> param name in callee
        params = {}
        for cl in callee:
            pm = re.match(r"%?([\w\.\-]+)\s*=\s*[^=]*parameter\((\d+)\)",
                          cl.replace("ROOT ", ""))
            if pm:
                params[int(pm.group(2))] = pm.group(1)
        total = 0.0
        for idx, opname in enumerate(ops_):
            full = _shape_bytes(tab.get(opname, ""))
            pname = params.get(idx)
            if pname is None:
                total += full
                continue
            slice_only, largest = True, 0.0
            used = False
            dus_target_only = True
            for cl in callee:
                if re.search(r"%" + re.escape(pname) + r"\b", cl) and \
                        not re.match(r"(ROOT\s+)?%" + re.escape(pname)
                                     + r"\s*=", cl):
                    used = True
                    dm = _DEF_RE.match(cl.replace("ROOT ", ""))
                    cop = dm.group(3) if dm else ""
                    if cop in ("dynamic-slice", "slice", "gather"):
                        largest = max(largest,
                                      _shape_bytes(dm.group(2)))
                        dus_target_only = False
                    elif cop == "dynamic-update-slice":
                        da = re.search(r"dynamic-update-slice\(([^)]*)\)",
                                       cl)
                        dops = re.findall(r"%([\w\.\-]+)",
                                          da.group(1)) if da else []
                        if dops and dops[0] == pname:
                            continue  # in-place update target: no read
                        slice_only = False
                        dus_target_only = False
                        break
                    else:
                        slice_only = False
                        dus_target_only = False
                        break
            if used and slice_only and largest > 0:
                total += 2.0 * largest
            elif used and slice_only and dus_target_only:
                total += 0.0  # pure in-place DUS target
            else:
                total += full
        # output side: peel unary chains (convert/bitcast/copy) off the
        # root to find an underlying in-place dynamic-update-slice
        root = next((cl for cl in callee if cl.startswith("ROOT")), "")
        line_of = {}
        for cl in callee:
            dm = _DEF_RE.match(cl.replace("ROOT ", ""))
            if dm:
                line_of[dm.group(1)] = cl.replace("ROOT ", "")
        cur = root.replace("ROOT ", "")
        for _ in range(8):
            dm = _DEF_RE.match(cur)
            if not dm:
                break
            cop = dm.group(3)
            if cop == "dynamic-update-slice":
                ra = re.search(r"dynamic-update-slice\(([^)]*)\)", cur)
                rops = re.findall(r"%([\w\.\-]+)",
                                  ra.group(1)) if ra else []
                upd = _shape_bytes(ctab.get(rops[1], "")) \
                    if len(rops) > 1 else 0.0
                return total + 2.0 * upd
            if cop in ("convert", "bitcast", "copy", "transpose",
                       "reshape"):
                oa = re.search(r"\(([^)]*)\)", cur)
                nxt = re.findall(r"%([\w\.\-]+)", oa.group(1)) \
                    if oa else []
                if nxt and nxt[0] in line_of:
                    cur = line_of[nxt[0]]
                    continue
            break
        total += _shape_bytes(rtype)
        return total

    def walk(name: str, depth: int = 0) -> Tuple[float, float]:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 40:
            return (0.0, 0.0)
        memo[name] = (0.0, 0.0)
        tab = tabs[name]
        flops = bytes_ = 0.0
        in_fusion = name in fused
        for line in comps[name]:
            m = _DEF_RE.match(line)
            if not m:
                continue
            _, rtype, op = m.groups()
            if op == "dot":
                flops += _dot_flops(line, rtype, tab)
            if not in_fusion and op not in _SKIP_BYTES_OPS:
                # slice/gather-family ops touch only the slice, not the
                # whole operand buffer (counting operands naively made
                # the flash pair-scan look 20x more HBM-bound than it is)
                if op == "fusion":
                    b = _fusion_bytes(line, rtype, tab)
                elif op in ("dynamic-slice", "slice", "gather"):
                    b = 2.0 * _shape_bytes(rtype)        # read + write
                elif op in ("dynamic-update-slice", "scatter"):
                    args = re.search(r"\(([^)]*)\)", line)
                    ops_ = re.findall(r"%([\w\.\-]+)",
                                      args.group(1)) if args else []
                    upd = _shape_bytes(tab.get(ops_[1], "")) \
                        if len(ops_) > 1 else 0.0
                    b = 3.0 * upd                        # r/w region + upd
                else:
                    b = _shape_bytes(rtype)
                    args = re.search(r"\b" + re.escape(op) +
                                     r"\(([^)]*)\)", line)
                    if args:
                        for o in re.findall(r"%([\w\.\-]+)",
                                            args.group(1)):
                            if o in tab:
                                b += _shape_bytes(tab[o])
                bytes_ += b
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trips = _trip_count(comps.get(mc.group(1), [])) \
                    if mc else 1
                if mb:
                    f2, b2 = walk(mb.group(1), depth + 1)
                    flops += f2 * trips
                    bytes_ += b2 * trips
            elif op == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", line)
                if mbr:
                    best = (0.0, 0.0)
                    for n in mbr.group(1).split(","):
                        f2, b2 = walk(n.strip().lstrip("%"), depth + 1)
                        best = (max(best[0], f2), max(best[1], b2))
                    flops += best[0]
                    bytes_ += best[1]
            else:
                mcall = re.search(r"(?:to_apply=|calls=)%?([\w\.\-]+)",
                                  line)
                if mcall:
                    f2, b2 = walk(mcall.group(1), depth + 1)
                    flops += f2
                    bytes_ += b2
        memo[name] = (flops, bytes_)
        return memo[name]

    f, b = walk("__entry__")
    return {"dot_flops": f, "hbm_bytes": b}


def top_collectives(text: str, total_devices: int, k: int = 15):
    """Top-k collective op sites by execution-weighted transfer bytes.

    Returns [(weighted_bytes, kind, result_type, trips, computation)].
    Weighting walks the call graph from ENTRY like ``collective_bytes``.
    """
    comps = _split_computations(text)

    # computation -> execution multiplier, via one walk from entry
    mult: Dict[str, float] = defaultdict(float)

    def walk(name: str, weight: float, depth: int = 0):
        if name not in comps or depth > 40 or weight <= 0:
            return
        mult[name] += weight
        for line in comps[name]:
            opm = re.search(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
                            r"(?:\{[^}]*\})?)\s+([\w\-]+)", line)
            if not opm:
                continue
            op = opm.group(1)
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trips = _trip_count(comps.get(mc.group(1), [])) \
                    if mc else 1
                if mb:
                    walk(mb.group(1), weight * trips, depth + 1)
                if mc:
                    walk(mc.group(1), weight, depth + 1)
            elif op == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", line)
                if m:
                    for n in m.group(1).split(","):
                        walk(n.strip().lstrip("%"), weight, depth + 1)
            else:
                m = re.search(r"(?:to_apply=|calls=|called_computations="
                              r"\{)%?([\w\.\-]+)", line)
                if m:
                    walk(m.group(1), weight, depth + 1)

    entry = "__entry__" if "__entry__" in comps else None
    if entry:
        walk(entry, 1.0)

    rows = []
    seen_entry_alias = comps.get("__entry__")
    for cname, lines in comps.items():
        if mult.get(cname, 0) == 0:
            continue
        if lines is seen_entry_alias and cname != "__entry__":
            continue  # real entry counted under the __entry__ alias
        w = mult[cname]
        for line in lines:
            opm = re.search(r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
                            r"(?:\{[^}]*\})?))\s+([\w\-]+)", line)
            if not opm:
                continue
            rtype, op = opm.group(1), opm.group(2)
            base = next((c for c in _COLLECTIVES if op.startswith(c)),
                        None)
            if not base or op.endswith("-done"):
                continue
            g = _group_size(line, total_devices)
            b = _shape_bytes(rtype)
            if base == "all-gather":
                byt = b * (g - 1) / max(g, 1)
            elif base == "reduce-scatter":
                byt = b * (g - 1)
            elif base == "all-reduce":
                byt = 2 * b * (g - 1) / max(g, 1)
            elif base == "all-to-all":
                byt = b * (g - 1) / max(g, 1)
            else:
                byt = b
            rows.append((byt * w, base, rtype[:90], w, cname[:40]))
    rows.sort(key=lambda r: -r[0])
    return rows[:k]
