import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step for training
shapes, prefill/decode serve steps for inference shapes), attaches the
production shardings from repro.dist.sharding, and runs
``.lower().compile()`` on the target mesh -- 16x16 single-pod and 2x16x16
multi-pod.  Sharding mismatches, unsupported collectives, or compile-time
OOMs are failures of the framework and fail the cell.

Artifacts (memory analysis, cost analysis, execution-weighted collective
bytes) are written to benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json;
the roofline table (benchmarks/roofline.py, EXPERIMENTS.md section
Roofline) is derived from them.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "benchmarks", "artifacts", "dryrun")


def _cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    d = os.path.abspath(os.path.join(ARTIFACTS, mesh_name))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def build_lowerable(arch: str, shape: str, mesh, overrides=None):
    """Returns (fn, args, in_shardings, out_shardings, donate, meta)."""
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.dist import sharding as sh
    from repro.models import api as api_mod, count_params
    from repro.train import loop as loop_mod, optimizer as opt_mod

    cfg = configs.get(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    spec = configs.SHAPES[shape]
    kind, seq, batch = spec["kind"], spec["seq_len"], spec["global_batch"]
    total, active = count_params(cfg)
    meta = {"arch": arch, "shape": shape, "kind": kind, "seq_len": seq,
            "global_batch": batch, "params_total": int(total),
            "params_active": int(active)}

    key = jax.random.PRNGKey(0)

    if kind == "train":
        api = api_mod.make(cfg)
        opt_cfg = opt_mod.AdamWConfig()
        state_shape = jax.eval_shape(
            lambda k: loop_mod.init_state(api, k, opt_cfg), key)
        pspecs = sh.param_specs(state_shape["params"], mesh)
        opt_spec = {"m": pspecs, "v": pspecs,
                    "step": jax.sharding.PartitionSpec()}
        if "master" in state_shape["opt"]:
            opt_spec["master"] = pspecs
        state_spec = {"params": pspecs, "opt": opt_spec}
        batch_shape = api.input_specs("train", batch, seq)
        batch_spec = sh.batch_specs(batch_shape, mesh)
        fn = loop_mod.make_train_step(api, opt_cfg)
        return (fn, (state_shape, batch_shape),
                (state_spec, batch_spec), (state_spec, None), (0,), meta)

    # serving shapes use bf16 parameters
    cfg = cfg.scaled(param_dtype="bfloat16")
    api = api_mod.make(cfg)
    params_shape = jax.eval_shape(api.init, key)
    pspecs = sh.param_specs(params_shape, mesh)

    if kind == "prefill":
        cache_shape = jax.eval_shape(
            lambda: api.init_cache(batch, seq, jnp.bfloat16))
        cache_spec = sh.cache_specs(cache_shape, mesh)
        batch_shape = dict(api.input_specs("prefill", batch, seq))
        batch_spec = dict(sh.batch_specs(batch_shape, mesh))
        batch_shape["cache"] = cache_shape
        batch_spec["cache"] = cache_spec

        def fn(params, b):
            return api.prefill(params, b)

        return (fn, (params_shape, batch_shape), (pspecs, batch_spec),
                (None, cache_spec), (1,), meta)

    if kind == "decode":
        cache_shape = jax.eval_shape(
            lambda: api.init_cache(batch, seq, jnp.bfloat16))
        cache_spec = sh.cache_specs(cache_shape, mesh)
        batch_shape = api.input_specs("decode", batch, seq)
        batch_spec = sh.batch_specs(batch_shape, mesh)

        def fn(params, cache, b):
            return api.decode(params, cache, b)

        return (fn, (params_shape, cache_shape, batch_shape),
                (pspecs, cache_spec, batch_spec), (None, cache_spec),
                (1,), meta)

    raise ValueError(kind)


def run_cell(arch: str, shape: str, multi_pod: bool,
             save: bool = True) -> dict:
    import jax
    from repro.configs import cells
    from repro.dist import sharding as sh
    from repro.launch import hlo
    from repro.launch.mesh import make_production_mesh

    skip = next((sk for a, s, _, sk in cells()
                 if a == arch and s == shape), None)
    record = {"arch": arch, "shape": shape,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    if skip:
        record.update(ok=True, skipped=True, skip_reason=skip)
        if save:
            with open(_cell_path(arch, shape, multi_pod), "w") as f:
                json.dump(record, f, indent=1)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        fn, args, in_specs, out_specs, donate, meta = build_lowerable(
            arch, shape, mesh)
        record.update(meta)
        in_sh = jax.tree_util.tree_map(
            lambda s: jax.NamedSharding(mesh, s), in_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        out_sh = jax.tree_util.tree_map(
            lambda s: jax.NamedSharding(mesh, s), out_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()

            mem = {}
            try:
                ma = compiled.memory_analysis()
                for attr in ("argument_size_in_bytes",
                             "output_size_in_bytes",
                             "temp_size_in_bytes",
                             "generated_code_size_in_bytes",
                             "alias_size_in_bytes"):
                    if hasattr(ma, attr):
                        mem[attr] = int(getattr(ma, attr))
            except Exception as e:  # backend-dependent
                mem["error"] = str(e)

            cost = {}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                for k in ("flops", "transcendentals", "bytes accessed"):
                    if k in ca:
                        cost[k] = float(ca[k])
            except Exception as e:
                cost["error"] = str(e)

            text = compiled.as_text()
            coll = hlo.collective_bytes(text, n_dev)
            weighted = hlo.weighted_cost(text)
            record.update(
                ok=True, skipped=False,
                lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
                memory=mem, cost=cost, collective_bytes=coll,
                collective_total=float(sum(coll.values())),
                weighted=weighted,
                hlo_bytes=len(text), n_devices=int(n_dev),
            )
            print(compiled.memory_analysis())
            try:
                print({k: v for k, v in (compiled.cost_analysis() or
                                         {}).items()
                       if k in ("flops", "bytes accessed")})
            except Exception:
                pass
    except Exception as e:
        record.update(ok=False, skipped=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    if save:
        with open(_cell_path(arch, shape, multi_pod), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in a subprocess each")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        from repro.configs import cells
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = 0
        for multi in meshes:
            for arch, shape, _, _ in cells():
                path = _cell_path(arch, shape, multi)
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape]
                if multi:
                    cmd.append("--multi-pod")
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                with open(path) as f:
                    rec = json.load(f) if os.path.exists(path) else {}
                ok = rec.get("ok", False)
                failures += 0 if ok else 1
                print(f"[{'OK' if ok else 'FAIL'}] "
                      f"{'2x16x16' if multi else '16x16'} {arch} {shape} "
                      f"({time.time() - t0:.0f}s)"
                      + ("" if ok else f"\n  {rec.get('error', r.stderr[-500:])}"),
                      flush=True)
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=1))
    if not rec.get("ok"):
        print(rec.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
