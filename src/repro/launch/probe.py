import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Perf-iteration profiler: compile one cell and report the dominant
collective sites (execution-weighted) and the weighted dot-FLOP count.
This is the dry-run-world replacement for a wall-clock profile
(see EXPERIMENTS.md section Perf).

Usage:
  python -m repro.launch.probe --arch deepseek-67b --shape train_4k
         [--multi-pod] [--set key=value ...] [--dump /tmp/x.hlo]
"""
import argparse
import json
import sys


def parse_overrides(pairs):
    out = {}
    for p in pairs or ():
        k, v = p.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        if isinstance(v, list):
            v = tuple(v)
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--dump")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    import jax
    from repro.launch import hlo
    from repro.launch.dryrun import build_lowerable
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n_dev = mesh.devices.size
    overrides = parse_overrides(args.set)
    fn, fargs, in_specs, out_specs, donate, meta = build_lowerable(
        args.arch, args.shape, mesh, overrides or None)
    in_sh = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), in_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    out_sh = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), out_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*fargs).compile()
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)
    ma = compiled.memory_analysis()
    print(f"temp/device   {ma.temp_size_in_bytes/1e9:10.2f} GB")
    print(f"args/device   {ma.argument_size_in_bytes/1e9:10.2f} GB")
    coll = hlo.collective_bytes(text, n_dev)
    for k, v in sorted(coll.items(), key=lambda kv: -kv[1]):
        print(f"{k:20s} {v/1e9:12.2f} GB/device")
    print("--- top collective sites (weighted) ---")
    for byt, kind, rtype, trips, comp in hlo.top_collectives(
            text, n_dev, args.top):
        print(f"{byt/1e9:10.2f} GB  {kind:18s} x{trips:6.0f} {rtype:60s}"
              f" in {comp}")


if __name__ == "__main__":
    main()
