"""Serving launcher: continuous batching on a reduced (or full) config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
      [--requests 8] [--slots 4] [--max-len 96]
"""
import argparse
import time

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from repro import configs
    from repro.models import make
    from repro.serve.engine import Request, Server

    cfg = configs.get(args.arch) if args.full else configs.SMOKES[args.arch]
    api = make(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, slots=args.slots, max_len=args.max_len)

    key = jax.random.PRNGKey(1)
    for rid in range(args.requests):
        key, sub = jax.random.split(key)
        plen = int(jax.random.randint(sub, (), 4, 20))
        srv.submit(Request(
            rid=rid,
            prompt=jax.random.randint(sub, (plen,), 2,
                                      cfg.vocab).tolist(),
            max_new_tokens=12))
    t0 = time.perf_counter()
    done = srv.run_until_done()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {wall:.1f}s")


if __name__ == "__main__":
    main()
