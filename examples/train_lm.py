"""End-to-end training driver: a reduced-width qwen2-family LM on the
synthetic pipeline with checkpoint/restart and gradient compression.

Defaults are sized for this 1-core CPU container (a few minutes); the
full assigned config is selectable and the same driver is what the
dry-run lowers at production shape:

  PYTHONPATH=src python examples/train_lm.py                  # demo
  PYTHONPATH=src python examples/train_lm.py --arch qwen2-7b \
      --width-scale 1.0 --steps 300 --batch 8 --seq 2048       # 100M+
"""
import argparse

import jax

from repro import configs
from repro.models import count_params, make
from repro.train import data as data_mod
from repro.train import loop, optimizer as opt_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b",
                    choices=configs.names())
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (TPU-scale)")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full:
        smoke = configs.SMOKES[args.arch]
        pat = len(smoke.pattern)
        cfg = smoke.scaled(
            d_model=args.d_model, d_ff=args.d_model * 4,
            vocab=args.vocab,
            n_layers=max(args.layers // pat, 1) * pat)
    total, active = count_params(cfg)
    print(f"arch={cfg.name} params={total/1e6:.1f}M "
          f"(active {active/1e6:.1f}M) layers={cfg.n_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab}")

    api = make(cfg)
    it = data_mod.for_model(cfg, batch=args.batch, seq=args.seq, seed=0)
    ocfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=20,
                               total_steps=args.steps)
    out = loop.fit(api, it, ocfg, steps=args.steps, ckpt_dir=args.ckpt,
                   ckpt_every=25, compress=args.compress, log_every=10)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
              f"{len(losses)} steps "
              f"({sum(out['durations'])/len(out['durations']):.2f}s/step)")
        assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
