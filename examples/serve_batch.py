"""Serving demo: continuous batching of LM requests.

A reduced qwen2-family model behind the slot-based engine: requests with
different prompt/output lengths arrive together; slots free as sequences
finish and queued requests are admitted immediately.

  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax

from repro import configs
from repro.models import make
from repro.serve.engine import Request, Server


def main():
    cfg = configs.SMOKES["qwen2-7b"].scaled(d_model=128, d_ff=512,
                                            vocab=2048, n_layers=2)
    api = make(cfg)
    params = api.init(jax.random.PRNGKey(0))
    server = Server(api, params, slots=4, max_len=96, temperature=0.0)

    rng = jax.random.PRNGKey(1)
    for rid in range(10):
        rng, sub = jax.random.split(rng)
        plen = int(jax.random.randint(sub, (), 4, 24))
        prompt = jax.random.randint(sub, (plen,), 2, cfg.vocab).tolist()
        server.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=8 + (rid % 3) * 8))

    t0 = time.perf_counter()
    steps = 0
    finished = []
    while server.active or server.queue:
        finished += server.step()
        steps += 1
        if steps > 500:
            break
    wall = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests, {total_tokens} tokens, "
          f"{steps} engine steps in {wall:.1f}s "
          f"({total_tokens / max(wall, 1e-9):.1f} tok/s on 1 CPU core)")
    for r in finished[:4]:
        print(f"  req {r.rid}: prompt {len(r.prompt)} tokens -> "
              f"{len(r.generated)} generated {r.generated[:6]}...")
    assert len(finished) == 10


if __name__ == "__main__":
    main()
