"""Contended-links demo: file staging over fair-share wide-area links.

Drives the engine's contention-aware network subsystem end-to-end (the
Nimrod-G concern the analytic bytes/baud model cannot express): a
3-resource grid runs a 30-job task farm whose Gridlets carry real input
and output files, first over analytic links (every transfer gets the
whole link to itself) and then over fair-share links (``net_cap``:
concurrent stagings and result returns on the same resource link split
its baud rate equally, with one phantom background flow of non-grid
traffic per link).  Contention stretches the transfer phase, so the
same broker schedule finishes later -- and a bandwidth-starved link
changes which resources are worth buying.

Also prints the physics on a minimal two-transfer example (two 128-byte
stagings over a 16 B/unit link arrive at t=16, not t=8), then asserts
the engine's identity contracts: batched == single-step on the
contended run, and infinite-baud fair-share links == the analytic path
superstep-for-superstep.

  PYTHONPATH=src python examples/network_contention.py [baud]

Expected output with the default baud 24000 (deterministic; asserted
below, and smoke-run by the CI docs job):

  two 128 B stagings over a 16 B/unit link: arrivals [16. 16.] (analytic: [8. 8.])
  ...
  analytic links:    completed 30/30  finished at t=369.1
  fair-share links:  completed 30/30  finished at t=593.4

The contended farm completes the same work later: transfer time is now
part of the simulated timeline, not a per-transfer constant.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gridlet, resource, simulation, types


def main():
    baud = float(sys.argv[1]) if len(sys.argv) > 1 else 24_000.0

    # -- the physics, minimally: two transfers halve each other -------
    tiny_fleet = resource.make_fleet([2], 1.0, 1.0, types.TIME_SHARED,
                                     baud_rate=16.0)
    tiny = gridlet.make_batch([8.0, 8.0], in_bytes=128.0)
    shared = engine.run_direct(tiny, tiny_fleet, 0, 0.0, max_events=64,
                               net_cap=2)
    alone = engine.run_direct(tiny, tiny_fleet, 0, 0.0, max_events=64)
    print("two 128 B stagings over a 16 B/unit link: arrivals "
          f"{np.asarray(shared.gridlets.start)} "
          f"(analytic: {np.asarray(alone.gridlets.start)})")
    np.testing.assert_allclose(np.asarray(shared.gridlets.start), 16.0)
    np.testing.assert_allclose(np.asarray(alone.gridlets.start), 8.0)

    # -- a broker-driven farm with real file payloads -----------------
    fleet = resource.make_fleet(
        num_pe=[4, 2, 2], mips_per_pe=[500.0, 400.0, 380.0],
        cost_per_sec=[8.0, 4.0, 2.0], policy=types.TIME_SHARED,
        baud_rate=baud)
    farm = gridlet.task_farm(jax.random.PRNGKey(7), n_jobs=30,
                             base_mi=10_000.0, in_bytes=300_000.0,
                             out_bytes=150_000.0)
    sc = simulation.Scenario(bg_flows=1.0)    # standing non-grid flow
    kw = dict(deadline=900.0, budget=12_000.0, opt=types.OPT_COST)

    analytic = simulation.run_experiment(farm, fleet, **kw, scenario=sc)
    contended = simulation.run_experiment(farm, fleet, **kw, scenario=sc,
                                          net_cap=None)   # auto-sized

    print(f"\n30-gridlet farm, 3 resources, {baud:.0f} B/unit links, "
          "300 kB in / 150 kB out per gridlet, 1 background flow")
    for name, res in (("analytic links:  ", analytic),
                      ("fair-share links:", contended)):
        print(f"  {name} completed {int(res.n_done[0])}/30  "
              f"finished at t={float(res.term_time[0]):.1f}")

    # -- identity contracts -------------------------------------------
    assert int(analytic.overflow) == 0 and int(contended.overflow) == 0
    assert not bool(contended.truncated)
    # contention can only stretch a transfer, never shrink it
    assert float(contended.term_time[0]) >= float(analytic.term_time[0])

    single = simulation.run_experiment(farm, fleet, **kw, scenario=sc,
                                       net_cap=None, batch=1)
    for f in ("n_done", "spent", "term_time", "n_events"):
        assert np.array_equal(np.asarray(getattr(single, f)),
                              np.asarray(getattr(contended, f))), f
    assert int(single.n_steps) == \
        int(contended.n_steps) + int(contended.n_spec)
    print("batched engine bit-identical to single-step on the "
          f"contended run: OK ({int(single.n_steps)} -> "
          f"{int(contended.n_steps)} iterations)")

    # infinite links: the subsystem tables nothing and the run is
    # identical to the analytic engine, superstep for superstep
    inf_fleet = resource.make_fleet(
        num_pe=[4, 2, 2], mips_per_pe=[500.0, 400.0, 380.0],
        cost_per_sec=[8.0, 4.0, 2.0], policy=types.TIME_SHARED,
        baud_rate=jnp.inf)
    a = simulation.run_experiment(farm, inf_fleet, **kw)
    b = simulation.run_experiment(farm, inf_fleet, **kw, net_cap=None)
    for f in ("n_done", "spent", "term_time", "n_events", "n_steps",
              "n_spec"):
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f
    print("infinite-baud fair-share links bit-identical to the "
          "analytic path: OK")

    if len(sys.argv) == 1:     # deterministic default (header block)
        assert int(contended.n_done[0]) == 30
        assert float(contended.term_time[0]) >= \
            float(analytic.term_time[0])


if __name__ == "__main__":
    main()
