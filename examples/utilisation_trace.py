"""Telemetry demo: per-resource utilisation curves from the metrics ring.

The paper's headline deliverables are *time series* -- Figs 9/12 plot
per-resource utilisation and spend over the run -- but the engine's
result is end-of-run scalars.  This demo drives a contended 20-user
farm with the speculation-safe telemetry ring enabled
(``run_experiment(..., telemetry=cap)``), exports the ring as a
structured JSONL event trace plus a Chrome ``trace_event`` file
(loadable in Perfetto / chrome://tracing), and prints the paper-style
time-weighted per-resource utilisation figures.

Then it *audits the ring against the engine's own counters* -- the
telemetry series is not decorative, it must integrate back to the
simulation's ground truth:

* the per-row event counts sum to ``n_events``;
* the last spend sample equals the engine's final committed spend;
* the utilisation series, left-Riemann-integrated as
  ``sum_r min(running_r, P_r) * MIPS_r dt``, recovers the total MI the
  farm actually executed (the engine advances work at constant Fig 8
  rates between events, so the piecewise-constant integral is exact on
  this load-free, failure-free fleet).

  PYTHONPATH=src python examples/utilisation_trace.py [out_dir]

Deterministic; asserted below and smoke-run by the CI docs job (which
uploads the exported trace as an Actions artifact).
"""
import os
import sys

import jax
import numpy as np

from repro.core import gridlet, resource, simulation, telemetry, types


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "/tmp/telemetry_trace"
    os.makedirs(out_dir, exist_ok=True)

    # A deliberately contended grid: 20 users x 10 jobs over 3 small
    # time-shared resources, so queues form and utilisation saturates.
    # Load-free fleet (no calendar load, no failures, analytic links):
    # between events every resource executes exactly
    # min(running, P) * MIPS instructions per time unit, which is what
    # makes the utilisation integral below exact rather than approximate.
    fleet = resource.make_fleet(
        num_pe=[4, 2, 2], mips_per_pe=[200.0, 150.0, 100.0],
        cost_per_sec=[9.0, 5.0, 3.0], policy=types.TIME_SHARED)
    n_users = 20
    farm = gridlet.task_farm(jax.random.PRNGKey(7), n_jobs=10,
                             n_users=n_users, base_mi=2000.0)
    res = simulation.run_experiment(
        farm, fleet, deadline=600.0, budget=1e6, opt=types.OPT_COST,
        n_users=n_users, telemetry=2048)

    tel = res.telemetry
    assert tel is not None and not telemetry.truncated(tel), \
        "ring truncated: raise the telemetry capacity"
    rows = telemetry.rows(tel)
    n_done = int(np.asarray(res.n_done).sum())
    print(f"completed {n_done}/{farm.n} gridlets in "
          f"{len(rows)} recorded supersteps")

    # -- export: structured JSONL + Chrome trace_event ----------------
    jsonl = os.path.join(out_dir, "trace.jsonl")
    chrome = os.path.join(out_dir, "trace_chrome.json")
    print(f"wrote {telemetry.to_jsonl(tel, jsonl)} rows to {jsonl}")
    print(f"wrote {telemetry.to_chrome_trace(tel, chrome)} trace events "
          f"to {chrome}")

    # -- the paper's utilisation figures ------------------------------
    t, util = telemetry.utilisation(tel)
    dt = np.diff(t)
    mean_util = (util[:-1] * dt[:, None]).sum(0) / (t[-1] - t[0])
    for r in range(fleet.r):
        bar = "#" * int(round(40 * mean_util[r]))
        print(f"  resource {r} ({int(fleet.num_pe[r])} PE @ "
              f"{float(fleet.mips_per_pe[r]):.0f} MIPS): "
              f"{100 * mean_util[r]:5.1f}% |{bar}")

    # -- audit the ring against the engine's own counters -------------
    assert sum(r["events"] for r in rows) == int(np.asarray(res.n_events))
    np.testing.assert_allclose(rows[-1]["spent"],
                               float(np.asarray(res.spent).sum()),
                               rtol=1e-6)
    # Utilisation integrates to executed MI: sum_r util_r * P_r * MIPS_r
    # over each inter-sample interval == total MI of completed work.
    npe = np.asarray(fleet.num_pe, np.float64)
    mips = np.asarray(fleet.mips_per_pe, np.float64)
    mi_rate = (util[:-1].astype(np.float64) * npe * mips).sum(1)
    mi_integral = float((mi_rate * dt).sum())
    done = np.asarray(res.gridlets.status) == types.DONE
    mi_done = float(np.asarray(res.gridlets.length_mi,
                               np.float64)[done].sum())
    print(f"utilisation integral: {mi_integral:.1f} MI "
          f"(engine executed {mi_done:.1f} MI)")
    np.testing.assert_allclose(mi_integral, mi_done, rtol=1e-3)
    assert n_done == farm.n, "farm did not finish: tighten budget/deadline consistently"
    print("OK: trace integrates to the engine's counters")


if __name__ == "__main__":
    main()
