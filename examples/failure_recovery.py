"""Failure/recovery demo: resources fail mid-run, the broker resubmits.

Drives the engine's pluggable FAILURE/RECOVERY event sources end-to-end
(the paper's "resources are dynamic" scenario): a 3-resource grid runs a
40-job task farm while every resource fails with MTBF = 150 time units
and repairs with MTTR = 15.  When a resource goes down its in-flight
Gridlets move to the FAILED state and their committed cost is refunded;
the economic broker re-plans and re-dispatches them (billing only the
new dispatch), so the farm still completes -- just later and, when the
cheap resource was down at the wrong moment, at a different cost.

Prints per-resource downtime and the resubmission count, then checks the
no-double-billing invariant: total spend == the committed cost of the
Gridlets that completed.  Both runs use the engine's default k-step
superstep batching; the failure run is additionally re-executed with
``batch=1`` to assert the speculative path is bit-for-bit identical
under dense interference (the horizon degrades, the results don't).

A third run demonstrates *planned* downtime: a maintenance window
(``reservation.maintenance`` -- sugar over the advance-reservation
source that holds every PE of a resource) takes the cheapest resource
offline for [100, 160).  Unlike a failure, nothing is killed or
refunded: admission just stops, and queued work resumes when the window
closes.

  PYTHONPATH=src python examples/failure_recovery.py [seed]

Expected output with the default seed 0 (deterministic; asserted below,
and smoke-run by the CI docs job):

  baseline (no failures):
    completed 40/40  spent 2301 G$  finished at t=528.2
  with failures:
    completed 40/40  spent 2879 G$  finished at t=555.9
    gridlets hit by failures: 12, resubmitted: 12
  with R2 maintenance [100, 160):
    completed 40/40  spent 5177 G$  finished at t=232.5
    gridlets hit by failures: 0, resubmitted: 0

Failures push the finish past the baseline's t=528.2 and the re-planned
dispatches land on costlier resources -- same completions, higher spend.
Maintenance kills nothing, but with the cheap R2 dark mid-run the
cost-optimising broker buys the expensive fast resources instead:
double the spend, half the makespan -- planned downtime trades G$ for
time where a failure trades both.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gridlet, reservation, resource, simulation, types


def main():
    seed = int(sys.argv[1]) if len(sys.argv) > 1 else 0

    fleet = resource.make_fleet(
        num_pe=[4, 2, 2], mips_per_pe=[500.0, 400.0, 380.0],
        cost_per_sec=[8.0, 4.0, 2.0], policy=types.TIME_SHARED,
        baud_rate=jnp.inf)
    farm = gridlet.task_farm(jax.random.PRNGKey(7), n_jobs=40,
                             base_mi=10_000.0)

    baseline = simulation.run_experiment(
        farm, fleet, deadline=600.0, budget=12000.0, opt=types.OPT_COST)
    faulty = simulation.run_experiment(
        farm, fleet, deadline=600.0, budget=12000.0, opt=types.OPT_COST,
        scenario=simulation.Scenario(mtbf=150.0, mttr=15.0, seed=seed))
    # Planned downtime: the cheapest resource (R2) goes dark over
    # [100, 160) -- a maintenance window blocking all of its PEs.
    maint = simulation.run_experiment(
        farm, fleet, deadline=600.0, budget=12000.0, opt=types.OPT_COST,
        scenario=simulation.Scenario(
            reservations=reservation.maintenance(fleet.num_pe,
                                                 [(2, 100.0, 160.0)])))

    print("40-gridlet task farm, 3 resources, MTBF=150 MTTR=15 "
          f"(seed {seed})\n")
    print("resource  PEs  G$/s   downtime")
    downtime = np.asarray(faulty.downtime)
    for r in range(fleet.r):
        print(f"R{r:<8d} {int(fleet.num_pe[r]):3d} "
              f"{float(fleet.cost_per_sec[r]):5.1f} {downtime[r]:9.1f}")

    for name, res in (("baseline (no failures)", baseline),
                      ("with failures", faulty),
                      ("with R2 maintenance [100, 160)", maint)):
        print(f"\n{name}:")
        print(f"  completed {int(res.n_done[0])}/40  "
              f"spent {float(res.spent[0]):.0f} G$  "
              f"finished at t={float(res.term_time[0]):.1f}")
        print(f"  gridlets hit by failures: {int(res.n_failed)}, "
              f"resubmitted: {int(res.n_resubmits)}")

    # no double billing: spend equals committed cost of completed jobs
    status = np.asarray(faulty.gridlets.status)
    cost_done = float(np.asarray(faulty.gridlets.cost)
                      [status == types.DONE].sum())
    assert abs(float(faulty.spent[0]) - cost_done) < 1e-3 * max(cost_done,
                                                                1.0)
    # every failed gridlet was resubmitted, or (if the broker had
    # already deactivated) refunded: abandoned FAILED gridlets carry no
    # committed cost.
    assert int(faulty.n_failed) > 0
    assert np.all(np.asarray(faulty.gridlets.cost)
                  [status == types.FAILED] == 0.0)
    print("\nevery failed gridlet resubmitted or refunded: OK")

    # k-step speculation must be bit-identical to the single-step
    # engine even with failures cutting the horizon mid-run.
    single = simulation.run_experiment(
        farm, fleet, deadline=600.0, budget=12000.0, opt=types.OPT_COST,
        scenario=simulation.Scenario(mtbf=150.0, mttr=15.0, seed=seed),
        batch=1)
    for f in ("n_done", "spent", "term_time", "n_events", "n_failed",
              "n_resubmits"):
        assert np.array_equal(np.asarray(getattr(single, f)),
                              np.asarray(getattr(faulty, f))), f
    assert int(single.n_steps) == int(faulty.n_steps) + int(faulty.n_spec)
    print(f"batched engine bit-identical to single-step: OK "
          f"({int(single.n_steps)} -> {int(faulty.n_steps)} iterations)")
    # maintenance is planned downtime: nothing killed, nothing
    # refunded -- but steering the broker off the cheap resource
    # mid-run costs real G$ (it buys the fast expensive ones instead)
    assert int(maint.n_failed) == 0 and int(maint.n_resubmits) == 0
    assert int(maint.n_done[0]) == 40
    assert float(maint.spent[0]) > float(baseline.spent[0])
    if seed == 0:              # deterministic default (header block)
        assert int(faulty.n_done[0]) == 40
        assert int(faulty.n_failed) == 12 and int(faulty.n_resubmits) == 12


if __name__ == "__main__":
    main()
