"""Fault-tolerance demo: train, lose devices, shrink the mesh, resume.

Runs in a single process with 8 virtual devices (set before importing
jax).  A reduced LM trains on a (4 data x 2 model) mesh with async
checkpointing; "hosts fail", the elastic policy rebuilds the largest
mesh that still holds a full model replica (2 x 2), the last checkpoint
reshards onto it, and training continues -- the checkpoint/restart +
elastic path the GridSim layer assumes when it reschedules jobs after a
GIS deregistration.

  PYTHONPATH=src python examples/failure_recovery.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.dist import fault  # noqa: E402
from repro.models import make  # noqa: E402
from repro.train import checkpoint as ckpt  # noqa: E402
from repro.train import data as data_mod  # noqa: E402
from repro.train import loop, optimizer as opt_mod  # noqa: E402

CKPT = "/tmp/repro_failure_demo"


def main():
    cfg = configs.SMOKES["qwen2-7b"].scaled(d_model=128, d_ff=512,
                                            vocab=2048)
    api = make(cfg)
    ocfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    step_fn = jax.jit(loop.make_train_step(api, ocfg))
    data = data_mod.for_model(cfg, batch=8, seq=64, seed=0)

    monitor = fault.HealthMonitor(n_workers=8, straggler_factor=2.0)
    saver = ckpt.AsyncCheckpointer(CKPT, keep=2)

    mesh = fault.elastic_mesh(jax.devices(), model_parallel=2)
    print(f"phase 1: mesh {dict(mesh.shape)} "
          f"({mesh.devices.size} devices)")
    state = loop.init_state(api, jax.random.PRNGKey(0), ocfg)
    state = fault.reshard(state, mesh)
    losses = []
    with mesh:
        for step in range(10):
            state, m = step_fn(state, next(data))
            losses.append(float(m["loss"]))
    saver.submit(10, state)
    saver.wait()
    print(f"  steps 1-10: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"checkpoint saved at step 10")

    # --- 3 devices "fail" -------------------------------------------------
    survivors = jax.devices()[:5]
    mesh2 = fault.elastic_mesh(survivors, model_parallel=2)
    print(f"phase 2: lost 3 devices -> elastic mesh {dict(mesh2.shape)} "
          f"({mesh2.devices.size} devices)")
    last = ckpt.latest_step(CKPT)
    like = loop.init_state(api, jax.random.PRNGKey(0), ocfg)
    state = ckpt.restore(CKPT, last, like)
    state = fault.reshard(state, mesh2)
    with mesh2:
        for step in range(last, 20):
            state, m = step_fn(state, next(data))
            losses.append(float(m["loss"]))
    print(f"  steps 11-20 on the shrunken mesh: loss {losses[-1]:.3f}")
    assert int(state["opt"]["step"]) == 20
    assert losses[-1] < losses[0]
    saver.close()
    print("recovered and converging: OK")


if __name__ == "__main__":
    main()
