"""Quickstart: the paper's section-4.1 recipe in ~30 lines.

Creates the WWG testbed fleet (Table 2), a 200-job task-farming
application (section 5.2), runs the Nimrod-G-like economic broker with
DBC cost-optimisation (k-step superstep batching on, the engine
default), and prints the per-resource allocation -- the repeatable,
controllable experiment the paper was built for.

  PYTHONPATH=src python examples/quickstart.py [deadline] [budget]

Expected output with the default arguments (deterministic; asserted
below, and smoke-run by the CI docs job):

  fleet: 11 resources, 68 PEs, T_min=76 T_max=5555 C_min=5511 C_max=32530
  ...
  R8          2   1.0    380     38   <- cheapest G$/MI
  ...
  completed 182/200  spent 11993/12000 G$  terminated at t=548/600

The broker drains the cheap resources (R2-R4, R8) and leaves the
expensive ones idle; 18 Gridlets stay undispatched when the remaining
budget no longer covers the cheapest possible job.
"""
import sys

import jax
import numpy as np

from repro.core import economy, gridlet, resource, simulation, types


def main():
    deadline = float(sys.argv[1]) if len(sys.argv) > 1 else 600.0
    budget = float(sys.argv[2]) if len(sys.argv) > 2 else 12000.0

    fleet = resource.wwg_fleet()
    farm = gridlet.task_farm(jax.random.PRNGKey(7), n_jobs=200)
    total_mi = float(farm.length_mi.sum())

    print(f"fleet: {fleet.r} resources, "
          f"{int(fleet.num_pe.sum())} PEs, "
          f"T_min={float(economy.t_min(fleet, total_mi)):.0f} "
          f"T_max={float(economy.t_max(fleet, total_mi)):.0f} "
          f"C_min={float(economy.c_min(fleet, total_mi)):.0f} "
          f"C_max={float(economy.c_max(fleet, total_mi)):.0f}")
    print(f"experiment: 200 Gridlets, deadline={deadline:.0f}, "
          f"budget={budget:.0f} G$, cost-optimisation\n")

    res = simulation.run_experiment(farm, fleet, deadline=deadline,
                                    budget=budget, opt=types.OPT_COST)

    per = np.asarray(res.per_resource_done[0], int)
    cost_mi = np.asarray(fleet.cost_per_mi())
    print("resource  PEs  G$/s   MIPS  gridlets")
    for r in range(fleet.r):
        print(f"R{r:<8d} {int(fleet.num_pe[r]):3d} "
              f"{float(fleet.cost_per_sec[r]):5.1f} "
              f"{float(fleet.mips_per_pe[r]):6.0f} {per[r]:6d}"
              + ("   <- cheapest G$/MI" if r == cost_mi.argmin() else ""))
    print(f"\ncompleted {int(res.n_done[0])}/200  "
          f"spent {float(res.spent[0]):.0f}/{budget:.0f} G$  "
          f"terminated at t={float(res.term_time[0]):.0f}/{deadline:.0f}")

    # Real smoke assertions (CI runs this file): the run is healthy and
    # the k-step batched engine actually engaged.
    assert int(res.overflow) == 0 and not bool(res.truncated)
    assert float(res.spent[0]) <= budget + 1e-3
    if len(sys.argv) == 1:     # deterministic defaults (header block)
        assert int(res.n_done[0]) == 182
        assert per[cost_mi.argmin()] == 38
        assert round(float(res.spent[0])) == 11993
        # a real workload must actually exercise the k-step batched path
        # (degenerate CLI args -- zero budget etc. -- legitimately don't)
        assert int(res.n_spec) > 0, "superstep speculation never engaged"


if __name__ == "__main__":
    main()
