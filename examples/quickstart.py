"""Quickstart: the paper's section-4.1 recipe in ~30 lines.

Creates the WWG testbed fleet (Table 2), a 200-job task-farming
application (section 5.2), runs the Nimrod-G-like economic broker with
DBC cost-optimisation, and prints the per-resource allocation -- the
repeatable, controllable experiment the paper was built for.

  PYTHONPATH=src python examples/quickstart.py [deadline] [budget]
"""
import sys

import jax
import numpy as np

from repro.core import economy, gridlet, resource, simulation, types


def main():
    deadline = float(sys.argv[1]) if len(sys.argv) > 1 else 600.0
    budget = float(sys.argv[2]) if len(sys.argv) > 2 else 12000.0

    fleet = resource.wwg_fleet()
    farm = gridlet.task_farm(jax.random.PRNGKey(7), n_jobs=200)
    total_mi = float(farm.length_mi.sum())

    print(f"fleet: {fleet.r} resources, "
          f"{int(fleet.num_pe.sum())} PEs, "
          f"T_min={float(economy.t_min(fleet, total_mi)):.0f} "
          f"T_max={float(economy.t_max(fleet, total_mi)):.0f} "
          f"C_min={float(economy.c_min(fleet, total_mi)):.0f} "
          f"C_max={float(economy.c_max(fleet, total_mi)):.0f}")
    print(f"experiment: 200 Gridlets, deadline={deadline:.0f}, "
          f"budget={budget:.0f} G$, cost-optimisation\n")

    res = simulation.run_experiment(farm, fleet, deadline=deadline,
                                    budget=budget, opt=types.OPT_COST)

    per = np.asarray(res.per_resource_done[0], int)
    cost_mi = np.asarray(fleet.cost_per_mi())
    print("resource  PEs  G$/s   MIPS  gridlets")
    for r in range(fleet.r):
        print(f"R{r:<8d} {int(fleet.num_pe[r]):3d} "
              f"{float(fleet.cost_per_sec[r]):5.1f} "
              f"{float(fleet.mips_per_pe[r]):6.0f} {per[r]:6d}"
              + ("   <- cheapest G$/MI" if r == cost_mi.argmin() else ""))
    print(f"\ncompleted {int(res.n_done[0])}/200  "
          f"spent {float(res.spent[0]):.0f}/{budget:.0f} G$  "
          f"terminated at t={float(res.term_time[0]):.0f}/{deadline:.0f}")


if __name__ == "__main__":
    main()
