"""The integration demo: GridSim brokering THIS repo's own workloads.

Each assigned (arch x shape) dry-run cell becomes a Gridlet priced from
its roofline analysis (MODEL_FLOPS per step x a step budget); the fleet
is a heterogeneous set of TPU pods (different generations = different
FLOP/s "MIPS" ratings, different $/chip-hour = G$ rates, preemptible
pools = time-shared, reserved capacity = space-shared).  The DBC broker
then answers the capacity-planning question the paper was written for:
*which pods should each job lease under a deadline and a budget?* --
repeatably, without touching the real cluster.

  PYTHONPATH=src python examples/cluster_scheduling.py \
      [--deadline-hours 24] [--budget 50000]
"""
import argparse
import glob
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import gridlet, resource, simulation, types

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN = os.path.join(HERE, "..", "benchmarks", "artifacts", "dryrun",
                      "pod16x16")

# A heterogeneous TPU fleet: (name, pods, chips/pod "PEs", peak TFLOP/s
# per chip -> "MIPS", $/chip-hour -> G$/PE-time-unit, policy)
TPU_FLEET = [
    ("v5e-reserved", 4, 256, 197.0, 1.2, types.SPACE_SHARED),
    ("v5e-preempt", 8, 256, 197.0, 0.5, types.TIME_SHARED),
    ("v4-reserved", 2, 256, 275.0, 3.2, types.SPACE_SHARED),
    ("v5p-reserved", 2, 448, 459.0, 4.2, types.SPACE_SHARED),
    ("v5p-preempt", 2, 448, 459.0, 1.7, types.TIME_SHARED),
]
STEPS_PER_JOB = 1000.0   # price each cell as a 1000-step run


def load_jobs():
    jobs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok") or rec.get("skipped"):
            continue
        kind = rec["kind"]
        tokens = rec["global_batch"] * (rec["seq_len"]
                                        if kind != "decode" else 1)
        mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
        tflop = mult * rec["params_active"] * tokens * STEPS_PER_JOB / 1e12
        jobs.append((f"{rec['arch']}/{rec['shape']}", tflop))
    if not jobs:  # dry-run artifacts not built yet: analytic fallback
        from repro import configs
        from repro.models import count_params
        for arch in configs.names():
            cfg = configs.get(arch)
            total, active = count_params(cfg)
            for shape, spec in configs.SHAPES.items():
                if shape == "long_500k":
                    continue
                tokens = spec["global_batch"] * (
                    spec["seq_len"] if spec["kind"] != "decode" else 1)
                mult = 6.0 if spec["kind"] == "train" else 2.0
                jobs.append((f"{arch}/{shape}",
                             mult * active * tokens * STEPS_PER_JOB
                             / 1e12))
    return jobs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadline-hours", type=float, default=24.0)
    ap.add_argument("--budget", type=float, default=50_000.0)
    ap.add_argument("--opt", default="cost",
                    choices=["cost", "time", "cost_time"])
    args = ap.parse_args()

    # fleet: one resource per zone; PE = one POD (jobs gang-schedule a
    # whole pod, the dry-run's mesh unit), "MIPS" = pod TFLOP/s, so the
    # simulation time unit is the SECOND; price $/chip-hour -> G$ per
    # pod-second.  Time-shared zones model preemptible pools (jobs share
    # pods), space-shared zones model reserved capacity (dedicated pod,
    # FCFS queue).
    names, num_pe, mips, cost, policy = [], [], [], [], []
    for name, pods, chips, tf, price, pol in TPU_FLEET:
        names.append(name)
        num_pe.append(pods)
        mips.append(tf * chips)
        cost.append(price * chips / 3600.0)
        policy.append(pol)
    fleet = resource.make_fleet(num_pe, mips, cost, policy)

    jobs = load_jobs()
    # Gridlet "MI" = TFLOPs of work (rating TFLOP/s x seconds).
    lengths = jnp.asarray([t for _, t in jobs], jnp.float32)
    farm = gridlet.make_batch(lengths)
    opt = {"cost": types.OPT_COST, "time": types.OPT_TIME,
           "cost_time": types.OPT_COST_TIME}[args.opt]
    res = simulation.run_experiment(
        farm, fleet, deadline=args.deadline_hours * 3600.0,
        budget=args.budget, opt=opt)

    print(f"{len(jobs)} jobs (1000 steps each), "
          f"deadline {args.deadline_hours}h, budget ${args.budget:.0f}, "
          f"{args.opt}-optimisation\n")
    status = np.asarray(res.gridlets.status)
    res_idx = np.asarray(res.gridlets.resource)
    done = status == types.DONE
    per_pod = {}
    for j, (name, tflop) in enumerate(jobs):
        pod = names[res_idx[j]] if res_idx[j] >= 0 else "-"
        per_pod.setdefault(pod, []).append(name)
    for pod in sorted(per_pod):
        if pod == "-":
            continue
        jobs_here = per_pod[pod]
        print(f"{pod:16s} {len(jobs_here):3d} jobs  "
              f"e.g. {', '.join(jobs_here[:3])}")
    unsched = per_pod.get("-", [])
    print(f"\nscheduled {int(done.sum())}/{len(jobs)} jobs "
          f"({len(unsched)} unscheduled), spent "
          f"${float(res.spent[0]):.0f} of ${args.budget:.0f}, "
          f"makespan {float(res.term_time[0]) / 3600.0:.1f}h of "
          f"{args.deadline_hours:.1f}h")
    if args.deadline_hours > 2.0:
        print("\n(tip: rerun with --deadline-hours 1 to watch the "
              "broker lease the expensive reserved v4/v5p pods)")


if __name__ == "__main__":
    main()
