"""DBC strategy comparison on the lane-batched sweep engine (the
paper's headline Nimrod-G experiment, Table-1 style).

One `engine.run_sweep_lanes` call runs every broker strategy -- cost-,
time-, cost-time- and un-optimised dispatch, each a `Scenario(policy=)`
lane -- over the same WWG task farm and deadline/budget, then a second
lane stack adds the economy axis: commodity-market repricing, sealed-bid
auction rounds and plan-ahead (cs/0203020) dispatch.  Every lane is
asserted bitwise identical to its own `engine.run(batch=1)` reference,
so the strategy axis rides the device-parallel sweep machinery without
changing a single event.

The printed table reproduces the paper's qualitative ordering:
cost-minimisation spends the least, time-minimisation finishes
earliest, and cost-time matches time's finish inside equal-cost groups
while spending like cost.

  PYTHONPATH=src python examples/table1_strategies.py

Expected output (deterministic; asserted below, and smoke-run by the
CI docs job):

  strategy x (deadline=1200, budget=30000), 40 jobs on the WWG fleet
    cost       done 40/40  t=  963.3  spent 11260
    time       done 40/40  t=  389.4  spent 25623
    cost-time  done 40/40  t=  963.3  spent 11260
    none       done 37/40  t=  923.0  spent 29951
  ordering OK: cost spends least, time finishes first
  ...
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gridlet, resource, simulation, types

STRATEGIES = (("cost", types.OPT_COST), ("time", types.OPT_TIME),
              ("cost-time", types.OPT_COST_TIME), ("none", types.OPT_NONE))

DEADLINE, BUDGET = 1200.0, 30_000.0
N_USERS, N_JOBS, MAX_EVENTS = 1, 40, 8192


def lane_params(fleet, scenarios):
    """Stack per-scenario SimParams into one lane-batched pytree."""
    ps = [simulation._scenario_params(fleet, DEADLINE, BUDGET,
                                      types.OPT_COST, N_USERS, sc)
          for sc in scenarios]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)


def run_lanes(g, fleet, scenarios):
    """One lane-batched engine call + the per-lane bitwise check."""
    p_lanes = lane_params(fleet, scenarios)
    lanes = jax.jit(lambda pp: engine.run_sweep_lanes(
        g, fleet, pp, N_USERS, MAX_EVENTS, batch=8))(p_lanes)
    for i, sc in enumerate(scenarios):
        ref = engine.run(g, fleet,
                         jax.tree_util.tree_map(lambda x: x[i], p_lanes),
                         N_USERS, MAX_EVENTS, batch=1)
        assert int(ref.n_steps) + int(ref.n_spec) < MAX_EVENTS
        for f in ("spent", "term_time", "n_events", "overflow"):
            assert np.array_equal(np.asarray(getattr(ref, f)),
                                  np.asarray(getattr(lanes, f)[i])), \
                f"lane {i} diverges at {f}"
        for j in range(3):
            assert np.array_equal(np.asarray(ref.trace[j]),
                                  np.asarray(lanes.trace[j][i])), \
                f"lane {i} diverges at trace[{j}]"
    return lanes


def report(lanes, names, g):
    out = {}
    for i, name in enumerate(names):
        done = int((np.asarray(lanes.gridlets.status[i])
                    == types.DONE).sum())
        t = float(lanes.term_time[i][0])
        spent = float(lanes.spent[i][0])
        print(f"    {name:<10} done {done}/{g.n}  t={t:7.1f}  "
              f"spent {spent:5.0f}")
        out[name] = (done, t, spent)
    return out


def main():
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(9), n_jobs=N_JOBS,
                          n_users=N_USERS, base_mi=50_000.0)

    # -- the strategy axis: one lane per DBC policy -------------------
    print(f"  strategy x (deadline={DEADLINE:.0f}, "
          f"budget={BUDGET:.0f}), {N_JOBS} jobs on the WWG fleet")
    scs = [simulation.Scenario(policy=opt) for _, opt in STRATEGIES]
    lanes = run_lanes(g, fleet, scs)
    rows = report(lanes, [n for n, _ in STRATEGIES], g)

    # Table-1 qualitative ordering: every DBC strategy finishes the
    # farm (the unoptimised broker may exhaust its budget first --
    # that is the point of optimising), cost-min buys the cheapest
    # grid, time-min the fastest finish.
    for name in ("cost", "time", "cost-time"):
        assert rows[name][0] == N_JOBS, f"{name} left jobs undone"
    assert rows["cost"][2] < rows["time"][2], "cost-min must spend less"
    assert rows["time"][1] < rows["cost"][1], "time-min must finish first"
    assert rows["cost-time"][2] <= rows["none"][2]
    print("  ordering OK: cost spends least, time finishes first\n")

    # -- the economy axis: pricing models + plan-ahead, same engine ---
    print("  economy axis (cost-optimising broker):")
    econ_names = ["static", "commodity", "auction", "plan-ahead"]
    econ_scs = [
        simulation.Scenario(policy=types.OPT_COST),
        simulation.Scenario(policy=types.OPT_COST,
                            pricing_model="commodity",
                            market_period=60.0, market_gain=0.5),
        simulation.Scenario(policy=types.OPT_COST,
                            pricing_model="auction",
                            auction_period=60.0, seed=12),
        simulation.Scenario(policy=types.OPT_COST, plan_ahead=True),
    ]
    econ = run_lanes(g, fleet, econ_scs)
    erows = report(econ, econ_names, g)
    assert all(done == N_JOBS for done, _, _ in erows.values())
    # Sealed-bid rounds are deterministic given the seed: replaying the
    # auction lane reproduces it bitwise.
    again = run_lanes(g, fleet, [econ_scs[2]])
    assert np.array_equal(np.asarray(again.spent[0]),
                          np.asarray(econ.spent[2]))
    print("  auction replay bitwise-deterministic: OK")
    print("  every lane bit-identical to its engine.run(batch=1) "
          "reference: OK")


if __name__ == "__main__":
    main()
