"""Contention-aware network subsystem: transfer_delay edge cases, the
fair-share link_scan kernel (Pallas/XLA/oracle agreement, TPU lane
shapes, conservation), zero-contention bitwise identity with the
analytic path (incl. the golden 20-user WWG scenario), contended-path
batch identity, background traffic, and the maintenance-window sugar
over the reservation source."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (des, engine, gridlet, network, reservation,
                        resource, simulation, types)
from repro.kernels import ops, ref
from repro.kernels import event_scan as event_scan_mod


# ----------------------------------------------------------------------
# transfer_delay edge cases: finite, nonnegative, monotone in bytes.
# ----------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(nbytes=st.floats(0.0, 1e30), baud=st.sampled_from(
    [0.0, 1e-35, 1.0, 9600.0, 2.8e4, 1e30, float("inf")]))
def test_transfer_delay_finite_nonnegative(nbytes, baud):
    d = float(network.transfer_delay(nbytes, baud))
    assert np.isfinite(d) and d >= 0.0
    # zero bytes and infinite baud are exactly instantaneous
    assert float(network.transfer_delay(0.0, baud)) == network.LATENCY
    assert float(network.transfer_delay(nbytes, jnp.inf)) == \
        network.LATENCY


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), baud=st.sampled_from(
    [0.0, 1.0, 9600.0, float("inf")]))
def test_transfer_delay_monotone_in_bytes(seed, baud):
    """More bytes never arrive earlier -- including the zero-baud case,
    where the quotient overflows f32 and must clamp to the finite BIG
    horizon instead of wrapping to 'instantaneous'."""
    rng = np.random.RandomState(seed)
    sizes = np.sort(rng.uniform(0.0, 1e30, 16).astype(np.float32))
    d = np.asarray(network.transfer_delay(jnp.asarray(sizes), baud))
    assert np.all(np.isfinite(d)) and np.all(d >= 0.0)
    assert np.all(np.diff(d) >= 0.0)


def test_link_tabled_predicate():
    """Only positive payloads over finite-positive links contend."""
    tab = network.link_tabled
    assert bool(tab(100.0, 9600.0))
    assert not bool(tab(0.0, 9600.0))        # empty payload: instant
    assert not bool(tab(100.0, jnp.inf))     # infinite link: instant
    assert not bool(tab(100.0, 0.0))         # dead link: never arrives
    assert not bool(tab(-1.0, 9600.0))


# ----------------------------------------------------------------------
# link_scan: three-way agreement, conservation, TPU lane shapes.
# ----------------------------------------------------------------------
def _random_link_case(seed, l=8, t=12):
    rng = np.random.RandomState(seed)
    rem = rng.exponential(1e5, (l, t)).astype(np.float32)
    rem[rng.rand(l, t) < 0.4] = 0.0          # free slots
    if seed % 2:  # integer payloads force exact forecast ties
        rem = np.where(rem > 0,
                       (rng.randint(1, 5, (l, t)) * 1024.0)
                       .astype(np.float32), 0.0)
    baud = rng.uniform(100.0, 1e4, (l,)).astype(np.float32)
    baud[seed % l] = 0.0                     # dead link
    baud[(seed + 3) % l] = np.inf            # uncontended link
    bg = rng.choice([0.0, 1.0, 2.5], (l,)).astype(np.float32)
    tie = rng.permutation(l * t).reshape(l, t).astype(np.float32)
    return rem, baud, bg, tie


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999))
def test_link_scan_paths_agree(seed):
    """Pallas interpret, the XLA fallback (the engine's CPU hot path)
    and the numpy oracle agree on random transfer tables with dead and
    infinite links, fractional background flows and forecast ties."""
    rem, baud, bg, tie = _random_link_case(seed)
    args = (jnp.asarray(rem), jnp.asarray(baud))
    kw = dict(bg=jnp.asarray(bg), tie=jnp.asarray(tie))
    pallas_out = ops.link_scan(*args, **kw, interpret=True)
    xla_out = event_scan_mod.link_scan_xla(*args, **kw)
    ref_out = ref.link_scan_ref(rem, baud, bg=bg, tie=tie)
    for got, name in ((xla_out, "xla"), (ref_out, "oracle")):
        np.testing.assert_allclose(np.asarray(pallas_out[0]),
                                   np.asarray(got[0]), rtol=1e-4,
                                   atol=1e-4, err_msg=name)
        np.testing.assert_allclose(np.asarray(pallas_out[1]),
                                   np.asarray(got[1]), rtol=1e-4,
                                   err_msg=name)
        assert np.array_equal(np.asarray(pallas_out[3]),
                              np.asarray(got[3])), name
    assert np.array_equal(np.asarray(pallas_out[2]),
                          np.asarray(xla_out[2]))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999))
def test_link_scan_fair_share_conservation(seed):
    """Fair-share invariant: active transfers split the link equally
    and their rates sum to baud * m / (m + bg); with no background
    traffic the whole link is consumed."""
    rem, baud, bg, tie = _random_link_case(seed)
    rate, _, _, occ = event_scan_mod.link_scan_xla(
        jnp.asarray(rem), jnp.asarray(baud), bg=jnp.asarray(bg),
        tie=jnp.asarray(tie))
    rate, occ = np.asarray(rate), np.asarray(occ)
    live = (baud > 0) & np.isfinite(baud)
    m = occ.astype(np.float64)
    safe_baud = np.where(live, baud, 0.0)    # inf links carry rate 0
    expect = np.where(live & (m > 0),
                      safe_baud * m / np.maximum(m + bg, 1.0), 0.0)
    np.testing.assert_allclose(rate.sum(axis=1), expect, rtol=1e-4)
    # equal shares: every active transfer runs at the same rate
    for r in range(rem.shape[0]):
        active = rate[r][rate[r] > 0]
        if active.size:
            np.testing.assert_allclose(active, active[0], rtol=1e-5)


def test_link_scan_lowers_for_tpu_shapes():
    """The link kernel must trace/lower at fleet scale with a lane-
    padded transfer axis (L=256 links, T=600 -> padded to 640)."""
    l, t = 256, 600
    rem = jax.ShapeDtypeStruct((l, t), jnp.float32)
    v = jax.ShapeDtypeStruct((l,), jnp.float32)
    jax.eval_shape(lambda a, b, g: ops.link_scan(
        a, b, bg=g, interpret=True), rem, v, v)


def test_link_scan_lane_padding_roundtrip():
    """Outputs come back at the caller's T with the empty-row sentinel
    remapped, padding never wins the argmin."""
    rem, baud, bg, tie = _random_link_case(7, l=8, t=130)  # pads to 256
    p = ops.link_scan(jnp.asarray(rem), jnp.asarray(baud),
                      bg=jnp.asarray(bg), tie=jnp.asarray(tie),
                      interpret=True)
    x = event_scan_mod.link_scan_xla(jnp.asarray(rem), jnp.asarray(baud),
                                     bg=jnp.asarray(bg),
                                     tie=jnp.asarray(tie))
    assert p[0].shape == (8, 130)
    assert int(np.asarray(p[2]).max()) <= 130
    np.testing.assert_allclose(np.asarray(p[0]), np.asarray(x[0]),
                               rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.asarray(p[2]), np.asarray(x[2]))


# ----------------------------------------------------------------------
# Zero-contention == analytic path, bit for bit.
# ----------------------------------------------------------------------
def _grid_fields(res):
    return {f: np.asarray(getattr(res.gridlets, f))
            for f in ("status", "start", "finish", "returned",
                      "resource", "cost")}


def test_single_transfer_bitwise_matches_analytic():
    """One transfer per link at a time (power-of-two payloads so every
    advance is exact): the fair-share subsystem reproduces the analytic
    timestamps bitwise -- entry, arrival, completion and return."""
    fleet = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=16.0)
    g = gridlet.make_batch([8.0], in_bytes=64.0, out_bytes=32.0)
    analytic = engine.run_direct(g, fleet, 0, 0.0, max_events=64,
                                 batch=1)
    net = engine.run_direct(g, fleet, 0, 0.0, max_events=64, net_cap=2,
                            batch=1)
    a, b = _grid_fields(analytic), _grid_fields(net)
    for f in a:
        assert np.array_equal(a[f], b[f]), f
    # arrival 64/16 = 4, finish 4+8 = 12, return 12+32/16 = 14
    np.testing.assert_allclose(b["returned"], [14.0])
    assert int(net.overflow) == 0


def test_infinite_baud_net_mode_fully_identical():
    """Infinite links table nothing: the run with the subsystem on is
    identical to the analytic run superstep-for-superstep (trace
    included), not just in results."""
    g = gridlet.make_batch([10.0, 8.5, 9.5], in_bytes=5e4, out_bytes=2e4)
    fleet = resource.table1_resource(types.TIME_SHARED)   # baud = inf
    base = engine.run_direct(g, fleet, 0, jnp.array([0.0, 4.0, 7.0]),
                             max_events=64)
    net = engine.run_direct(g, fleet, 0, jnp.array([0.0, 4.0, 7.0]),
                            max_events=64, net_cap=3)
    for a, b in zip(base.trace, net.trace):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(base.n_steps) == int(net.n_steps)
    assert int(base.n_events) == int(net.n_events)
    a, b = _grid_fields(base), _grid_fields(net)
    for f in a:
        assert np.array_equal(a[f], b[f]), f


def test_zero_byte_wwg_golden_identical_with_net_on():
    """The acceptance bar: the golden 20-user WWG scenario (zero-byte
    payloads -- nothing can contend) is bit-for-bit identical with the
    network subsystem enabled, counters included."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=100, n_users=20)
    kw = dict(deadline=2000.0, budget=22000.0, opt=types.OPT_COST,
              n_users=20)
    base = simulation.run_experiment(g, fleet, **kw)
    net = simulation.run_experiment(g, fleet, **kw, net_cap=None)
    for f in ("n_done", "spent", "term_time", "n_events", "n_steps",
              "n_spec", "n_reseeds", "overflow"):
        assert np.array_equal(np.asarray(getattr(base, f)),
                              np.asarray(getattr(net, f))), f
    a, b = _grid_fields(base), _grid_fields(net)
    for f in a:
        assert np.array_equal(a[f], b[f]), f


# ----------------------------------------------------------------------
# Contended links: fair-share physics and batch identity.
# ----------------------------------------------------------------------
def test_fair_share_contention_trace():
    """Two simultaneous 128-byte stagings over a 16 B/unit link halve
    each other's bandwidth (arrive at 16, not 8); the two 64-byte
    returns contend the same way.  Hand-computed from the fair-share
    rule, all values powers of two."""
    fleet = resource.make_fleet([2], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=16.0)
    g = gridlet.make_batch([8.0, 8.0], in_bytes=128.0, out_bytes=64.0)
    r = engine.run_direct(g, fleet, 0, 0.0, max_events=64, net_cap=4,
                          batch=1)
    np.testing.assert_allclose(np.asarray(r.gridlets.start), 16.0)
    np.testing.assert_allclose(np.asarray(r.gridlets.finish), 24.0)
    np.testing.assert_allclose(np.asarray(r.gridlets.returned), 32.0)
    assert int(r.overflow) == 0
    tt, kind, _ = (np.asarray(x) for x in r.trace)
    assert 16.0 in tt[kind == des.K_NETWORK]     # staging drains
    assert 32.0 in tt[kind == des.K_NETWORK]     # returns drain
    # analytic run: uncontended arrivals at 8, returns 4 after finish
    ra = engine.run_direct(g, fleet, 0, 0.0, max_events=64, batch=1)
    np.testing.assert_allclose(np.asarray(ra.gridlets.start), 8.0)
    np.testing.assert_allclose(np.asarray(ra.gridlets.returned), 20.0)


def test_staggered_entries_piecewise_constant_rates():
    """A transfer entering mid-flight re-shares the link from that
    instant on (piecewise-constant integration): 128 B at t=0 plus
    128 B at t=4 over a 16 B/unit link -> arrivals at 12 and 16."""
    fleet = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=16.0)
    g = gridlet.make_batch([4.0, 4.0], in_bytes=128.0)
    r = engine.run_direct(g, fleet, 0, jnp.asarray([0.0, 4.0]),
                          max_events=64, net_cap=2, batch=1)
    np.testing.assert_allclose(np.asarray(r.gridlets.start),
                               [12.0, 16.0])


def test_background_flows_take_their_share():
    """One phantom background flow halves a lone transfer's share."""
    fleet = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=16.0)
    g = gridlet.make_batch([4.0], in_bytes=128.0)
    r = engine.run_direct(g, fleet, 0, 0.0, max_events=64, net_cap=2,
                          bg_flows=1.0, batch=1)
    np.testing.assert_allclose(np.asarray(r.gridlets.start), [16.0])
    r0 = engine.run_direct(g, fleet, 0, 0.0, max_events=64, net_cap=2,
                           batch=1)
    np.testing.assert_allclose(np.asarray(r0.gridlets.start), [8.0])


@settings(max_examples=6, deadline=None)
@given(batch=st.sampled_from([2, 3, 8]), seed=st.integers(0, 99))
def test_contended_batch_property_identical(batch, seed):
    """The contended path is bit-identical for every batch value: full
    gridlet state and event trace, over random payload mixes (some
    zero-byte, so tabled and instant transfers coexist)."""
    rng = np.random.RandomState(seed)
    fleet = resource.make_fleet([2, 2], [1.0, 1.0], [1.0, 2.0],
                                types.TIME_SHARED, baud_rate=64.0)
    n = 10
    in_b = np.where(rng.rand(n) < 0.3, 0.0,
                    rng.randint(1, 9, n) * 32.0).astype(np.float32)
    out_b = np.where(rng.rand(n) < 0.3, 0.0,
                     rng.randint(1, 5, n) * 16.0).astype(np.float32)
    g = gridlet.make_batch(jnp.full((n,), 25.0),
                           in_bytes=jnp.asarray(in_b),
                           out_bytes=jnp.asarray(out_b))
    kw = dict(deadline=1000.0, budget=50000.0, opt=types.OPT_COST,
              n_users=1, net_cap=None)
    r1 = simulation.run_experiment(g, fleet, **kw, batch=1)
    rk = simulation.run_experiment(g, fleet, **kw, batch=batch)
    for f in ("n_done", "spent", "term_time", "n_events", "overflow"):
        assert np.array_equal(np.asarray(getattr(r1, f)),
                              np.asarray(getattr(rk, f))), f
    a, b = _grid_fields(r1), _grid_fields(rk)
    for f in a:
        assert np.array_equal(a[f], b[f]), f
    assert int(r1.n_steps) == int(rk.n_steps) + int(rk.n_spec)
    assert int(r1.overflow) == 0


def test_queued_tabled_return_cuts_speculation():
    """Regression: a QUEUED gridlet with a contending return payload
    must cut the speculation horizon -- a mid-slab queue admission can
    turn it RUNNING and complete it inside the slab, creating its
    return transfer where no NETWORK apply will run.  batch=k must stay
    bit-identical to batch=1 (the third gridlet queues at t=0, admits
    at t=8, completes at t=16 and its 64-byte return drains at t=20)."""
    fleet = resource.make_fleet([2], 1.0, 1.0, types.SPACE_SHARED,
                                baud_rate=16.0)
    g = gridlet.make_batch([8.0, 24.0, 8.0],
                           out_bytes=jnp.asarray([0.0, 0.0, 64.0]))
    r1 = engine.run_direct(g, fleet, 0, 0.0, max_events=64, net_cap=2,
                           batch=1)
    rk = engine.run_direct(g, fleet, 0, 0.0, max_events=64, net_cap=2)
    np.testing.assert_allclose(np.asarray(r1.gridlets.returned),
                               [8.0, 24.0, 20.0])
    a, b = _grid_fields(r1), _grid_fields(rk)
    for f in a:
        assert np.array_equal(a[f], b[f]), f
    for x, y in zip(r1.trace, rk.trace):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_big_finite_baud_is_uncontended_not_stuck():
    """Regression: a finite baud at/above the kernel's BIG horizon must
    route like an infinite link (analytic, instantaneous) -- not into
    the transfer table, where the link row would be masked dead and the
    transfer could never drain."""
    assert not bool(network.link_tabled(100.0, 3.3e38))
    fleet = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=3.3e38)
    g = gridlet.make_batch([8.0], in_bytes=64.0, out_bytes=32.0)
    r = engine.run_direct(g, fleet, 0, 0.0, max_events=64, net_cap=2,
                          batch=1)
    assert np.all(np.asarray(r.gridlets.status) == types.DONE)
    np.testing.assert_allclose(np.asarray(r.gridlets.returned), [8.0])


def test_contended_broker_run_with_failures_batch_identical():
    """Contention + failure/recovery streams together: transfers to a
    down resource still fail-and-refund on arrival, and the batched
    path stays bit-identical."""
    fleet = resource.make_fleet([2, 2], [1.0, 1.0], [1.0, 2.0],
                                types.TIME_SHARED, baud_rate=64.0)
    g = gridlet.make_batch(jnp.full((10,), 25.0), in_bytes=128.0,
                           out_bytes=64.0)
    sc = simulation.Scenario(mtbf=80.0, mttr=8.0, seed=3)
    kw = dict(deadline=1000.0, budget=50000.0, opt=types.OPT_COST,
              n_users=1, scenario=sc, net_cap=None)
    r1 = simulation.run_experiment(g, fleet, **kw, batch=1)
    rk = simulation.run_experiment(g, fleet, **kw)
    for f in ("n_done", "spent", "term_time", "n_events", "n_failed",
              "n_resubmits"):
        assert np.array_equal(np.asarray(getattr(r1, f)),
                              np.asarray(getattr(rk, f))), f
    assert int(r1.n_steps) == int(rk.n_steps) + int(rk.n_spec)
    assert np.all(np.asarray(r1.gridlets.status) == types.DONE)


# ----------------------------------------------------------------------
# Satellites: batched golden trace identity, maintenance windows.
# ----------------------------------------------------------------------
def test_golden_wwg_trace_identical_across_batch():
    """The while-loop condition now consumes the carried _user_flags
    instead of recomputing them: the golden 20-user WWG run must stay
    trace-identical (times, kinds, actors) between batch=1 and the
    default batch."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=50, n_users=20)
    params = simulation._scenario_params(fleet, 2000.0, 22000.0,
                                         types.OPT_COST, 20, None)
    max_jobs = simulation.safe_max_jobs(g, params, fleet)
    r1 = engine.run(g, fleet, params, 20, 4000, max_jobs=max_jobs,
                    batch=1)
    rk = engine.run(g, fleet, params, 20, 4000, max_jobs=max_jobs)
    for a, b in zip(r1.trace, rk.trace):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(r1.n_steps) == int(rk.n_steps) + int(rk.n_spec)
    assert np.array_equal(np.asarray(r1.spent), np.asarray(rk.spent))


def test_maintenance_window_blocks_whole_resource():
    """reservation.maintenance holds every PE: a space-shared resource
    admits nothing during the window (arrivals queue and run at its
    close), and a time-shared resident pauses exactly for the window
    (zero effective shares)."""
    fleet = resource.make_fleet([2], 1.0, 1.0, types.SPACE_SHARED,
                                baud_rate=jnp.inf)
    g = gridlet.make_batch([10.0, 10.0])
    maint = reservation.maintenance(fleet.num_pe, [(0, 0.0, 5.0)])
    r = engine.run_direct(g, fleet, 0, 0.0, max_events=64,
                          reservations=maint)
    np.testing.assert_allclose(np.asarray(r.gridlets.finish), 15.0)
    tt, kind, _ = (np.asarray(x) for x in r.trace)
    np.testing.assert_allclose(tt[kind == des.K_RESERVATION], [5.0])
    # time-shared: the resident pauses over [4, 6) -> finish slips by 2
    fleet_ts = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                   baud_rate=jnp.inf)
    g1 = gridlet.make_batch([10.0])
    r_ts = engine.run_direct(
        g1, fleet_ts, 0, 0.0, max_events=64,
        reservations=reservation.maintenance(fleet_ts.num_pe,
                                             [(0, 4.0, 6.0)]))
    np.testing.assert_allclose(np.asarray(r_ts.gridlets.finish), 12.0)


def test_maintenance_book_method_conflicts():
    """ReservationBook.book_maintenance holds all PEs and refuses to
    stack on top of existing bookings."""
    book = reservation.ReservationBook([4, 2])
    book.book(0, 2, 10.0, 20.0)
    with pytest.raises(ValueError):
        book.book_maintenance(0, 15.0, 25.0)   # 2 PEs already held
    res = book.book_maintenance(1, 0.0, 5.0)
    assert res.pes == 2
    assert book.reserved_pes(1, 2.0) == 2


def test_fastest_drain_membership_invariant_bound():
    """fastest_drain is the sole-member (fastest possible) drain time:
    it lower-bounds the actual fair-share drain for every occupancy m
    and never decreases when members join, with transfer_delay's exact
    clamping at the edges."""
    fd = network.fastest_drain
    # m members at baud/(m+bg): actual drain m*(..) >= bound for m >= 1
    for m in (1, 2, 7):
        for bg in (0.0, 1.0, 2.5):
            actual = 1e5 * (m + bg) / 9600.0
            assert actual >= float(fd(1e5, 9600.0, bg)) - 1e-3
    assert float(fd(1e5, 9600.0, 0.0)) == pytest.approx(1e5 / 9600.0)
    assert float(fd(0.0, 9600.0, 1.0)) == 0.0       # empty payload
    assert float(fd(1e5, jnp.inf, 1.0)) == 0.0      # infinite link
    d_dead = float(fd(1e5, 0.0, 1.0))               # dead link: never
    assert np.isfinite(d_dead) and d_dead >= 1e30
    assert float(fd(1e38, 1e-30, 9.0)) == \
        float(np.float32(network.BIG))              # overflow -> BIG


def test_golden_net_trace_pinned_across_batch():
    """The contended engine_20u_100j_net BENCH row replays the
    committed golden trace bitwise -- times, kinds, actors, per-gridlet
    returns, spend, termination -- at batch=1 AND the default batch, so
    network-slab changes (the associative-scan carry-through) can never
    silently reorder events."""
    import json
    import os
    with open(os.path.join(os.path.dirname(__file__), "data",
                           "golden_net_20u.json")) as f:
        gold = json.load(f)
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=100, n_users=20,
                          in_bytes=200_000.0, out_bytes=100_000.0)
    sc = simulation.Scenario(baud_rate=28_000.0, bg_flows=1.0)
    params = simulation._scenario_params(fleet, 2000.0, 22000.0,
                                         types.OPT_COST, 20, sc)
    net_cap = simulation.safe_net_cap(g, params, fleet, 20)
    max_jobs = simulation.safe_max_jobs(g, params, fleet)
    for batch in (1, None):
        kw = {} if batch is None else dict(batch=batch)
        r = engine.run(g, fleet, params, 20, 16384, max_jobs=max_jobs,
                       net_cap=net_cap, **kw)
        tt, kind, who = (np.asarray(x) for x in r.trace)
        m = kind >= 0
        assert np.array_equal(tt[m],
                              np.asarray(gold["trace_t"], np.float32))
        assert np.array_equal(kind[m], np.asarray(gold["trace_kind"]))
        assert np.array_equal(who[m], np.asarray(gold["trace_who"]))
        assert np.array_equal(
            np.asarray(r.gridlets.returned),
            np.asarray(gold["returned"], np.float32))
        assert np.array_equal(np.asarray(r.spent),
                              np.asarray(gold["spent"], np.float32))
        assert np.array_equal(np.asarray(r.term_time),
                              np.asarray(gold["term_time"], np.float32))
        assert int(np.asarray(r.n_events)) == gold["n_events"]
        assert int(np.asarray(r.overflow)) == gold["overflow"]
        assert int((np.asarray(r.gridlets.status)
                    == types.DONE).sum()) == gold["n_done"]
