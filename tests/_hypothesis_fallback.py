"""Minimal, dependency-free stand-in for the slice of ``hypothesis``
this repo's property tests use.

The CI image installs real hypothesis (requirements-dev.txt); containers
without it fall back to this module so the property tests still RUN
(seeded pseudo-random example generation) instead of erroring at
collection.  Import through the guard used in each test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

Supported: ``@settings(max_examples=N, deadline=None)``, ``@given`` with
keyword strategies, and ``st.integers / floats / lists / sampled_from / booleans``.
Examples are drawn from a per-test RNG seeded by the test name, so runs
are deterministic; shrinking and the hypothesis database are (by design)
not reproduced.
"""
from __future__ import annotations

import random
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


class strategies:  # namespace mirroring ``hypothesis.strategies``
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def lists(elements, *, min_size=0, max_size=10):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elements.sample(rng) for _ in range(n)]
        return _Strategy(sample)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Attach the example budget to the (already ``given``-wrapped) fn."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # NOTE: deliberately no functools.wraps -- the wrapper must not
        # inherit fn's signature, or pytest would treat the strategy
        # parameters as fixtures.
        def wrapper(*args, **fixtures):
            n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                try:
                    fn(*args, **fixtures, **drawn)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): {drawn!r}"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
