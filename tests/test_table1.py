"""Paper Table 1 / Figs 9 and 12: the canonical scheduling trace.

Three Gridlets (10, 8.5, 9.5 MI) arrive at t = 0, 4, 7 on a resource with
two 1-MIPS PEs.  The paper's exact start/finish/elapsed times must come
out of the engine for both allocation policies.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gridlet, resource, types

ARRIVALS = jnp.array([0.0, 4.0, 7.0])


def _run(policy):
    g = gridlet.make_batch([10.0, 8.5, 9.5])
    fleet = resource.table1_resource(policy)
    return engine.run_direct(g, fleet, 0, ARRIVALS, max_events=64)


def test_time_shared_matches_table1():
    res = _run(types.TIME_SHARED)
    np.testing.assert_allclose(res.gridlets.start, [0.0, 4.0, 7.0])
    np.testing.assert_allclose(res.gridlets.finish, [10.0, 14.0, 18.0])
    elapsed = np.asarray(res.gridlets.finish) - np.asarray(ARRIVALS)
    np.testing.assert_allclose(elapsed, [10.0, 10.0, 11.0])


def test_space_shared_matches_table1():
    res = _run(types.SPACE_SHARED)
    np.testing.assert_allclose(res.gridlets.start, [0.0, 4.0, 10.0])
    np.testing.assert_allclose(res.gridlets.finish, [10.0, 12.5, 19.5])
    elapsed = np.asarray(res.gridlets.finish) - np.asarray(ARRIVALS)
    np.testing.assert_allclose(elapsed, [10.0, 8.5, 12.5])


@pytest.mark.parametrize("policy",
                         [types.TIME_SHARED, types.SPACE_SHARED])
def test_all_done_and_remaining_zero(policy):
    res = _run(policy)
    assert np.all(np.asarray(res.gridlets.status) == types.DONE)
    np.testing.assert_allclose(res.gridlets.remaining, 0.0, atol=1e-5)


def test_time_shared_event_trace():
    """Fig 9: completions are delivered at t = 10, 14, 18 in that order."""
    res = _run(types.TIME_SHARED)
    tt, kind, who = (np.asarray(x) for x in res.trace)
    completions = tt[kind == 1]  # EV_COMPLETION == index 0 in priority
    # trace kinds: 0=completion, 1=return, 2=arrival, 3=broker
    completions = tt[kind == 0]
    np.testing.assert_allclose(sorted(completions[:3]), [10.0, 14.0, 18.0])
    arrivals = tt[kind == 2]
    np.testing.assert_allclose(sorted(arrivals[:3]), [0.0, 4.0, 7.0])


def test_space_shared_queueing():
    """G3 must wait in the queue until G1's PE frees at t=10 (Fig 12)."""
    res = _run(types.SPACE_SHARED)
    assert float(res.gridlets.start[2]) == 10.0
    # G3 ran at full PE speed once started: 9.5 MI at 1 MIPS.
    assert float(res.gridlets.finish[2] - res.gridlets.start[2]) == \
        pytest.approx(9.5)
