"""DBC broker behaviour: paper section 5 claims as assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import economy, gridlet, resource, simulation, types

KEY = jax.random.PRNGKey(7)
CHEAPEST = 8  # R8 in Table 2: 1 G$/unit, 380 MIPS -> best G$/MI


@pytest.fixture(scope="module")
def farm():
    return gridlet.task_farm(KEY, n_jobs=60)


@pytest.fixture(scope="module")
def fleet():
    return resource.wwg_fleet()


def test_relaxed_deadline_uses_only_cheapest(farm, fleet):
    """Paper Fig 27/30: with a relaxed deadline the cost-optimising broker
    leases only the cheapest resource."""
    r = simulation.run_experiment(farm, fleet, deadline=3100.0,
                                  budget=22000.0, opt=types.OPT_COST)
    per = np.asarray(r.per_resource_done[0])
    assert per[CHEAPEST] == farm.n
    assert per.sum() == farm.n


def test_budget_never_exceeded(farm, fleet):
    for budget in (600.0, 1500.0, 4000.0):
        r = simulation.run_experiment(farm, fleet, deadline=500.0,
                                      budget=budget, opt=types.OPT_COST)
        assert float(r.spent[0]) <= budget + 1e-3


def test_done_increases_with_budget_at_tight_deadline(farm, fleet):
    """Paper Fig 21: at a tight deadline, completions grow with budget."""
    done = []
    for budget in (1500.0, 4000.0, 10000.0, 22000.0):
        r = simulation.run_experiment(farm, fleet, deadline=100.0,
                                      budget=budget, opt=types.OPT_COST)
        done.append(float(r.n_done[0]))
    assert done == sorted(done)
    assert done[-1] > done[0]


def test_done_increases_with_deadline_at_low_budget(farm, fleet):
    """Paper Fig 22: at a low budget, completions grow as deadline relaxes."""
    done = []
    for deadline in (100.0, 600.0, 1600.0, 3100.0):
        r = simulation.run_experiment(farm, fleet, deadline=deadline,
                                      budget=4000.0, opt=types.OPT_COST)
        done.append(float(r.n_done[0]))
    assert done == sorted(done)
    assert done[-1] > done[0]


def test_tight_deadline_spends_whole_budget(farm, fleet):
    """Paper Fig 24: too-tight deadline -> the complete budget is spent."""
    r = simulation.run_experiment(farm, fleet, deadline=100.0,
                                  budget=3500.0, opt=types.OPT_COST)
    assert float(r.budget_utilization[0]) > 0.9
    # ... and completions are budget-limited, not capacity-limited.
    assert 0 < float(r.n_done[0]) < farm.n


def test_time_opt_no_slower_than_cost_opt(farm, fleet):
    rc = simulation.run_experiment(farm, fleet, deadline=400.0,
                                   budget=22000.0, opt=types.OPT_COST)
    rt = simulation.run_experiment(farm, fleet, deadline=400.0,
                                   budget=22000.0, opt=types.OPT_TIME)
    assert float(rt.n_done[0]) >= float(rc.n_done[0]) - 1e-6
    if rt.n_done[0] == rc.n_done[0] == farm.n:
        assert float(rt.term_time[0]) <= float(rc.term_time[0]) + 1e-3


def test_time_opt_costs_at_least_cost_opt(farm, fleet):
    rc = simulation.run_experiment(farm, fleet, deadline=2000.0,
                                   budget=22000.0, opt=types.OPT_COST)
    rt = simulation.run_experiment(farm, fleet, deadline=2000.0,
                                   budget=22000.0, opt=types.OPT_TIME)
    assert float(rt.spent[0]) >= float(rc.spent[0]) - 1e-3


def test_cost_time_between(farm, fleet):
    """Cost-time optimisation completes >= cost-opt at equal spend order."""
    r = simulation.run_experiment(farm, fleet, deadline=400.0,
                                  budget=22000.0, opt=types.OPT_COST_TIME)
    rc = simulation.run_experiment(farm, fleet, deadline=400.0,
                                   budget=22000.0, opt=types.OPT_COST)
    assert float(r.n_done[0]) >= float(rc.n_done[0]) - 1e-6


def test_multi_user_competition_reduces_completions(fleet):
    """Paper Figs 33/36: more users competing -> fewer jobs per user."""
    per_user_done = {}
    for n_users in (1, 4, 8):
        g = gridlet.task_farm(KEY, n_jobs=40, n_users=n_users)
        r = simulation.run_experiment(g, fleet, deadline=250.0,
                                      budget=4000.0, opt=types.OPT_COST,
                                      n_users=n_users)
        per_user_done[n_users] = float(np.mean(np.asarray(r.n_done)))
    assert per_user_done[4] <= per_user_done[1] + 1e-6
    assert per_user_done[8] <= per_user_done[4] + 1e-6


def test_d_factor_one_always_completes(fleet):
    """Eq 1/2 property: D-factor >= 1 and B-factor >= 1 complete all."""
    g = gridlet.task_farm(KEY, n_jobs=30)
    r, (deadline, budget) = simulation.run_experiment_factors(
        g, fleet, d_factor=1.0, b_factor=1.0, opt=types.OPT_COST)
    assert float(r.n_done[0]) == g.n
    assert float(r.term_time[0]) <= float(deadline) + 1e-2
    assert float(r.spent[0]) <= float(budget) + 1e-2


def test_zero_budget_processes_nothing(farm, fleet):
    r = simulation.run_experiment(farm, fleet, deadline=1000.0,
                                  budget=0.0, opt=types.OPT_COST)
    assert float(r.n_done[0]) == 0.0
    assert float(r.spent[0]) == 0.0
