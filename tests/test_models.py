"""Model-math correctness: chunked attention / SSD / MoE vs naive oracles,
and prefill+decode vs full-forward consistency for every architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# Unlike the core-engine suites (which fall back to the local shim),
# this module hard-requires the dev deps: the model stack also needs a
# newer jax than minimal containers ship, so it runs in CI only.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import make


# ----------------------------------------------------------------------
# attend_chunked vs naive softmax attention
# ----------------------------------------------------------------------

def naive_attend(q, k, v, causal, window, cap, kv_valid=None, q_offset=0):
    b, sq, h, g, d = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    if cap:
        s = jnp.tanh(s / cap) * cap
    qp = q_offset + jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if kv_valid is not None:
        mask &= kp < kv_valid
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))


@settings(max_examples=12, deadline=None)
@given(
    sq=st.integers(1, 40), skv_extra=st.integers(0, 30),
    hkv=st.sampled_from([1, 2]), g=st.sampled_from([1, 3]),
    causal=st.booleans(), window=st.sampled_from([0, 5, 16]),
    cap=st.sampled_from([0.0, 30.0]),
)
def test_attend_chunked_matches_naive(sq, skv_extra, hkv, g, causal,
                                      window, cap):
    skv = sq + skv_extra
    key = jax.random.PRNGKey(sq * 131 + skv)
    kq, kk, kv_ = jax.random.split(key, 3)
    d = 8
    q = jax.random.normal(kq, (2, sq, hkv, g, d), jnp.float32)
    k = jax.random.normal(kk, (2, skv, hkv, d), jnp.float32)
    v = jax.random.normal(kv_, (2, skv, hkv, d), jnp.float32)
    q_offset = skv - sq  # decode-style alignment
    got = attn_mod.attend_chunked(q, k, v, causal=causal, window=window,
                                  cap=cap, q_offset=q_offset,
                                  q_block=16, kv_block=8)
    want = naive_attend(q, k, v, causal, window, cap, q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_attend_chunked_kv_valid_mask():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 2, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 2, 8))
    got = attn_mod.attend_chunked(q, k, v, causal=True,
                                  q_offset=jnp.asarray(11),
                                  kv_valid_len=jnp.asarray(12),
                                  q_block=8, kv_block=8)
    want = naive_attend(q, k, v, True, 0, 0.0, kv_valid=12, q_offset=11)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# SSD chunked scan vs naive recurrence
# ----------------------------------------------------------------------

def naive_ssd(x, dt, a, b_mat, c_mat):
    bs, s, h, p = x.shape
    n = b_mat.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,N], [B,N]
        g = jnp.exp(dtt * a)   # [B,H]
        state = state * g[..., None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", bt, dtt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, init,
                         (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
                          jnp.moveaxis(b_mat, 1, 0),
                          jnp.moveaxis(c_mat, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 33), h=st.sampled_from([1, 3]),
       chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_matches_recurrence(s, h, chunk):
    key = jax.random.PRNGKey(s * 7 + h)
    ks = jax.random.split(key, 4)
    bs, p, n = 2, 4, 6
    x = jax.random.normal(ks[0], (bs, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_mat = jax.random.normal(ks[3], (bs, s, n), jnp.float32)
    c_mat = jax.random.normal(jax.random.PRNGKey(99), (bs, s, n))
    got = mamba_mod.ssd_chunked(x, dt, a, b_mat, c_mat, chunk)
    want = naive_ssd(x, dt, a, b_mat, c_mat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)


# ----------------------------------------------------------------------
# MoE: with capacity >= T*k the dispatch must equal the dense mixture
# ----------------------------------------------------------------------

def test_moe_matches_dense_mixture():
    cfg = configs.SMOKES["mixtral-8x22b"].scaled(capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = mlp_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    got = mlp_mod.moe(params, cfg, x)

    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    want = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xf @ params["wi_gate"][e]) * (xf @ params["wi_up"][e])
        y = h @ params["wo"][e]
        w = ((top_i == e) * top_p).sum(-1)
        want = want + y * w[:, None]
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


def test_moe_load_balance_loss_positive():
    cfg = configs.SMOKES["granite-moe-1b-a400m"]
    params = mlp_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    lb = mlp_mod.load_balance_loss(params, cfg, x)
    assert float(lb) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, = 1 balanced


# ----------------------------------------------------------------------
# Prefill + decode == full forward, for every architecture
# ----------------------------------------------------------------------

def _batch_for(cfg, key, B, S):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        batch = {
            "tokens": toks[:, : S - nv],
            "vision_embeds": jax.random.normal(
                key, (B, nv, cfg.d_model), jnp.float32),
            "positions3": jnp.tile(jnp.arange(S)[None, None],
                                   (3, B, 1)).astype(jnp.int32),
        }
    if cfg.family == "encdec":
        batch["audio_embed"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(configs.SMOKES))
def test_prefill_decode_consistency(name):
    # capacity_factor high enough to be dropless at this tiny batch: MoE
    # capacity dropping is token-count-dependent and would differ between
    # the S and S+1 reference runs (production keeps cf ~1.25 and accepts
    # drops; exactness here isolates the cache plumbing).
    cfg = configs.SMOKES[name].scaled(compute_dtype="float32",
                                      param_dtype="float32",
                                      capacity_factor=16.0)
    api = make(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch_for(cfg, jax.random.PRNGKey(1), B, S)

    # full forward over S+1 tokens: logits at position S-1 predict token S
    next_tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0,
                                  cfg.vocab)
    full_batch = _batch_for(cfg, jax.random.PRNGKey(1), B, S + 1)
    if cfg.family == "vlm":
        full_batch["tokens"] = jnp.concatenate(
            [batch["tokens"], next_tok], 1)
    else:
        full_batch["tokens"] = jnp.concatenate(
            [batch["tokens"], next_tok], 1)

    cache = api.init_cache(B, S + 8, dtype=jnp.float32)
    pb = dict(batch)
    pb["cache"] = cache
    lg_prefill, cache = api.prefill(params, pb)

    db = {"tokens": next_tok, "cache_index": jnp.asarray(S, jnp.int32)}
    if cfg.family == "vlm":
        db["positions3"] = jnp.full((3, B, 1), S, jnp.int32)
    lg_decode, _ = api.decode(params, cache, db)

    # reference: run prefill over the S+1-token prefix with a fresh cache
    cache2 = api.init_cache(B, S + 8, dtype=jnp.float32)
    pb2 = dict(full_batch)
    if cfg.family == "vlm":
        pb2["positions3"] = jnp.tile(jnp.arange(S + 1)[None, None],
                                     (3, B, 1)).astype(jnp.int32)
    pb2["cache"] = cache2
    lg_full, _ = api.prefill(params, pb2)

    np.testing.assert_allclose(np.asarray(lg_decode[:, -1], np.float32),
                               np.asarray(lg_full[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", sorted(configs.SMOKES))
def test_train_loss_finite_and_shapes(name):
    cfg = configs.SMOKES[name]
    api = make(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, jax.random.PRNGKey(1), B, S)
    batch["targets"] = jax.random.randint(jax.random.PRNGKey(3), (B, S),
                                          0, cfg.vocab)
    loss, grads = jax.value_and_grad(api.loss)(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
