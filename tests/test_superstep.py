"""Superstep engine contract: golden Table 1 trace, pre-refactor
result equivalence, engine <-> kernel <-> oracle rate agreement, the
job-slot / calendar overflow invariants, the pluggable event sources
(failure/recovery, calendar load steps, reservations), and the k-step
speculative batching path (bit-identity with k=1, horizon cuts)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import des, engine, gridlet, resource, simulation, types
from repro.core.types import replace as treplace
from repro.kernels import ops, ref
from repro.kernels.event_scan import event_scan_xla

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__), "data",
                                     "golden_pre_refactor.json")))
ARRIVALS = jnp.array([0.0, 4.0, 7.0])


# ----------------------------------------------------------------------
# Golden event trace (paper Table 1 / Figs 9 and 12): the superstep
# engine must reproduce the exact times, kinds and FIFO order.
# ----------------------------------------------------------------------
def _trace(policy, batch=engine.DEFAULT_BATCH):
    g = gridlet.make_batch([10.0, 8.5, 9.5])
    fleet = resource.table1_resource(policy)
    res = engine.run_direct(g, fleet, 0, ARRIVALS, max_events=64,
                            batch=batch)
    tt, kind, who = (np.asarray(x) for x in res.trace)
    m = kind >= 0
    return res, list(zip(tt[m].tolist(), kind[m].tolist(),
                         who[m].tolist()))


GOLDEN_TS_TRACE = [
    (0.0, 2, 0), (4.0, 2, 1), (7.0, 2, 2),        # arrivals
    (10.0, 0, 0), (10.0, 1, 0),                   # G1 done+returned
    (14.0, 0, 1), (14.0, 1, 1),                   # G2
    (18.0, 0, 2), (18.0, 1, 2),                   # G3
]


def test_time_shared_golden_trace():
    # kinds: 0=completion, 1=return, 2=arrival, 3=broker
    res, trace = _trace(types.TIME_SHARED, batch=1)
    assert trace == GOLDEN_TS_TRACE
    # zero-delay returns fold into their completion superstep: 9 events
    # in 6 supersteps.
    assert int(res.n_events) == 9 and int(res.n_steps) == 6
    assert int(res.overflow) == 0 and int(res.n_spec) == 0


def test_time_shared_golden_trace_batched():
    """The k-step batched path replays the identical golden trace; the
    three completion supersteps (10/14/18: no arrival, broker or
    boundary can intervene) speculate into the t=7 arrival iteration."""
    res, trace = _trace(types.TIME_SHARED)          # default batch
    assert trace == GOLDEN_TS_TRACE
    assert int(res.n_events) == 9
    assert int(res.n_steps) == 3 and int(res.n_spec) == 3
    assert int(res.overflow) == 0


def test_space_shared_golden_trace():
    res, trace = _trace(types.SPACE_SHARED, batch=1)
    assert trace == [
        (0.0, 2, 0), (4.0, 2, 1), (7.0, 2, 2),
        (10.0, 0, 0), (10.0, 1, 0),                   # G1 frees the PE
        (12.5, 0, 1), (12.5, 1, 1),
        (19.5, 0, 2), (19.5, 1, 2),                   # queued G3 last
    ]
    assert int(res.n_steps) == 6 and int(res.overflow) == 0
    # batched: same trace (queue admissions are speculation-safe: they
    # ride inside the completion superstep), half the iterations
    res_b, trace_b = _trace(types.SPACE_SHARED)
    assert trace_b == trace
    assert int(res_b.n_steps) == 3 and int(res_b.n_spec) == 3


def test_simultaneous_events_apply_in_one_superstep():
    """4 equal jobs on 4 PEs: one arrival superstep admits all four, one
    completion superstep completes AND returns all four (12 events)."""
    g = gridlet.make_batch([10.0] * 4)
    fleet = resource.make_fleet([4], 1.0, 1.0, types.TIME_SHARED)
    res = engine.run_direct(g, fleet, 0, jnp.zeros(4), max_events=64,
                            batch=1)
    assert int(res.n_steps) == 2
    assert int(res.n_events) == 12
    np.testing.assert_allclose(np.asarray(res.gridlets.finish), 10.0)
    # batched: the completion superstep speculates into the arrival
    # iteration -- 12 events in ONE while-loop iteration
    res_b = engine.run_direct(g, fleet, 0, jnp.zeros(4), max_events=64)
    assert int(res_b.n_steps) == 1 and int(res_b.n_spec) == 1
    assert int(res_b.n_events) == 12


# ----------------------------------------------------------------------
# Pre-refactor equivalence: same ExperimentResult, fewer iterations.
# ----------------------------------------------------------------------
def test_matches_pre_refactor_engine_results():
    ref_run = GOLDEN["1u_200j"]
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=200, n_users=1)
    r = simulation.run_experiment(g, fleet, deadline=2000.0,
                                  budget=22000.0, opt=types.OPT_COST,
                                  n_users=1)
    np.testing.assert_allclose(np.asarray(r.n_done), ref_run["n_done"])
    np.testing.assert_allclose(np.asarray(r.spent), ref_run["spent"],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r.term_time),
                               ref_run["term_time"], rtol=1e-5)
    # batching must strictly reduce loop iterations (the 2x target on
    # the 20-user scenario is asserted by benchmarks/engine_bench.py)
    assert int(r.n_steps) < ref_run["iterations"]
    assert int(r.overflow) == 0


# ----------------------------------------------------------------------
# Engine <-> kernel <-> oracle agreement on random [R, J] states.
# ----------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), r=st.sampled_from([8, 16]),
       j=st.sampled_from([8, 24]))
def test_event_scan_paths_agree(seed, r, j):
    """Pallas interpret, the XLA fallback (the engine's CPU hot path)
    and the numpy oracle agree on random states with tie keys and mixed
    policies."""
    rng = np.random.RandomState(seed)
    remaining = rng.exponential(50.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.4] = 0.0
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    tie = rng.permutation(r * j).reshape(r, j).astype(np.float32)
    pol = rng.randint(0, 2, (r,)).astype(np.int32)
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    kw = dict(tie=jnp.asarray(tie), policy=jnp.asarray(pol))
    pallas_out = ops.event_scan(*args, **kw, interpret=True)
    xla_out = event_scan_xla(*args, **kw)
    ref_out = ref.event_scan_ref(remaining, mips, pes, tie=tie,
                                 policy=pol)
    for got in (xla_out, ref_out):
        np.testing.assert_allclose(np.asarray(pallas_out[0]),
                                   np.asarray(got[0]), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(pallas_out[1]),
                                   np.asarray(got[1]), rtol=1e-4)
        assert np.array_equal(np.asarray(pallas_out[3]),
                              np.asarray(got[3]))
    assert np.array_equal(np.asarray(pallas_out[2]),
                          np.asarray(xla_out[2]))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), n_jobs=st.integers(1, 24),
       num_pe=st.integers(1, 6))
def test_kernel_agrees_with_engine_rates(seed, n_jobs, num_pe):
    """The kernel evaluated on the resource-major table must reproduce
    engine._rates (the flat XLA reference the superstep loop replaced),
    including FIFO tie-breaks on equal remaining work."""
    rng = np.random.RandomState(seed)
    rem = rng.randint(1, 6, (n_jobs,)).astype(np.float32)  # forces ties
    g = gridlet.make_batch(jnp.full((n_jobs,), 100.0))
    g = treplace(g, status=jnp.full((n_jobs,), types.RUNNING, jnp.int32),
                 resource=jnp.zeros((n_jobs,), jnp.int32),
                 remaining=jnp.asarray(rem))
    fleet = resource.make_fleet([num_pe], 3.0, 1.0, types.TIME_SHARED)
    st_ = engine.init_state(g, fleet, 1)
    st_ = treplace(st_, g=g)
    flat = np.asarray(engine._rates(st_, fleet, 1))

    table = jnp.pad(jnp.asarray(rem).reshape(1, n_jobs),
                    ((0, 7), (0, 0)))
    tie = jnp.pad(
        jnp.arange(n_jobs, dtype=jnp.float32).reshape(1, n_jobs),
        ((0, 7), (0, 0)))
    rate, tmin, amin, occ = ops.event_scan(
        table, jnp.full((8,), 3.0), jnp.full((8,), num_pe, jnp.int32),
        tie=tie, policy=jnp.zeros((8,), jnp.int32), interpret=True)
    np.testing.assert_allclose(np.asarray(rate)[0], flat, rtol=1e-5)
    assert int(occ[0]) == n_jobs
    t = rem / np.maximum(flat, 1e-30)
    assert float(tmin[0]) == pytest.approx(float(t.min()))
    # argmin: earliest completion, FIFO among ties
    want = min(range(n_jobs), key=lambda i: (np.float32(t[i]), i))
    assert int(amin[0]) == want


# ----------------------------------------------------------------------
# Slot-table invariants.
# ----------------------------------------------------------------------
def test_no_slot_overflow_across_policies():
    for policy in (types.TIME_SHARED, types.SPACE_SHARED):
        g = gridlet.make_batch(jnp.arange(1.0, 13.0))
        fleet = resource.make_fleet([2], 1.0, 1.0, policy)
        res = engine.run_direct(g, fleet, 0, jnp.zeros(12),
                                max_events=256)
        assert int(res.overflow) == 0
        assert np.all(np.asarray(res.gridlets.status) == types.DONE)


def test_broker_experiment_overflow_zero():
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(11), n_jobs=40, n_users=2)
    r = simulation.run_experiment(g, fleet, deadline=800.0, budget=9000.0,
                                  opt=types.OPT_COST, n_users=2)
    assert int(r.overflow) == 0
    assert float(np.asarray(r.n_done).sum()) > 0


# ----------------------------------------------------------------------
# Pluggable event sources.
# ----------------------------------------------------------------------
def test_zero_rate_sources_reproduce_golden():
    """With all three new sources registered but their rates zero/empty,
    the 20-user WWG scenario is bit-for-bit identical to a run without
    any scenario (which itself must match the pre-refactor golden)."""
    ref_run = GOLDEN["20u_100j"]
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=100, n_users=20)
    kw = dict(deadline=2000.0, budget=22000.0, opt=types.OPT_COST,
              n_users=20)
    base = simulation.run_experiment(g, fleet, **kw)
    zero = simulation.run_experiment(
        g, fleet, **kw,
        scenario=simulation.Scenario(mtbf=0.0, mttr=0.0,
                                     reservations=[], seed=123))
    for f in ("n_done", "spent", "term_time", "n_steps", "n_spec",
              "n_events"):
        assert np.array_equal(np.asarray(getattr(base, f)),
                              np.asarray(getattr(zero, f))), f
    assert int(zero.n_failed) == 0 and int(zero.n_resubmits) == 0
    np.testing.assert_allclose(np.asarray(zero.n_done), ref_run["n_done"])
    np.testing.assert_allclose(np.asarray(zero.spent), ref_run["spent"],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(zero.term_time),
                               ref_run["term_time"], rtol=1e-5)


def test_failure_resubmits_without_double_billing():
    """Failures mid-execution move gridlets to FAILED with a refund; the
    broker resubmits them and the total spend is exactly the sum of the
    committed costs of the jobs that eventually completed."""
    fleet = resource.make_fleet([2, 2], [1.0, 1.0], [1.0, 2.0],
                                types.TIME_SHARED)
    g = gridlet.make_batch(jnp.full((12,), 30.0))
    sc = simulation.Scenario(mtbf=60.0, mttr=10.0, seed=0)
    r = simulation.run_experiment(g, fleet, deadline=2000.0,
                                  budget=100000.0, opt=types.OPT_COST,
                                  n_users=1, scenario=sc)
    status = np.asarray(r.gridlets.status)
    assert int(r.n_failed) > 0                  # seed 0 produces failures
    # every FAILED gridlet was eventually resubmitted and completed
    assert np.all(status == types.DONE)
    assert int(r.n_resubmits) >= int(r.n_failed) > 0
    # no double billing: spend == committed cost of completed gridlets
    cost_done = float(np.asarray(r.gridlets.cost)[status ==
                                                  types.DONE].sum())
    assert float(r.spent[0]) == pytest.approx(cost_done, rel=1e-6)
    assert float(np.asarray(r.downtime).sum()) > 0.0
    assert int(r.overflow) == 0


def test_calendar_step_alone_advances_time():
    """A weekend boundary is a first-class event: the engine lands a
    superstep on it with no other event due, and the piecewise-constant
    load integrates exactly (200 MI at rate 1 until t=120, rate 0.5 over
    the 48 h weekend, rate 1 after t=168 -> finish at 224)."""
    fleet = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                weekend_load=0.5, baud_rate=jnp.inf)
    g = gridlet.make_batch([200.0])
    r = engine.run_direct(g, fleet, 0, 0.0, max_events=64)
    assert float(r.gridlets.finish[0]) == 224.0
    tt, kind, _ = (np.asarray(x) for x in r.trace)
    m = kind >= 0
    steps = tt[kind == des.K_CALENDAR]
    np.testing.assert_allclose(steps[:2], [120.0, 168.0])
    # the two boundary supersteps carry ONLY the calendar event
    assert list(zip(tt[m].tolist(), kind[m].tolist())) == [
        (0.0, des.K_ARRIVAL), (120.0, des.K_CALENDAR),
        (168.0, des.K_CALENDAR), (224.0, des.K_COMPLETION),
        (224.0, des.K_RETURN)]


def test_reservation_blocks_reserved_pes():
    """A [0, 12) window holding 2 of 4 space-shared PEs admits only two
    of four simultaneous arrivals; the other two run when the window
    closes (a RESERVATION event re-admits them at t=12)."""
    fleet = resource.make_fleet([4], 1.0, 1.0, types.SPACE_SHARED,
                                baud_rate=jnp.inf)
    g = gridlet.make_batch([20.0] * 4)
    r = engine.run_direct(g, fleet, 0, 0.0, max_events=64,
                          reservations=[(0, 2, 0.0, 12.0)])
    np.testing.assert_allclose(sorted(np.asarray(r.gridlets.finish)),
                               [20.0, 20.0, 32.0, 32.0])
    tt, kind, _ = (np.asarray(x) for x in r.trace)
    assert 12.0 in tt[kind == des.K_RESERVATION]
    # without the reservation all four PEs admit immediately
    r0 = engine.run_direct(g, fleet, 0, 0.0, max_events=64)
    np.testing.assert_allclose(np.asarray(r0.gridlets.finish), 20.0)
    assert int(r.overflow) == 0


def test_reservation_shrinks_time_shared_shares():
    """Blocked PEs leave the time-shared share pool: 2 equal jobs on a
    2-PE resource with 1 PE reserved run at half speed each."""
    fleet = resource.make_fleet([2], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=jnp.inf)
    g = gridlet.make_batch([10.0, 10.0])
    r = engine.run_direct(g, fleet, 0, 0.0, max_events=64,
                          reservations=[(0, 1, 0.0, 100.0)])
    np.testing.assert_allclose(np.asarray(r.gridlets.finish), 20.0)


# ----------------------------------------------------------------------
# k-step speculative batching (engine.step_batched).
# ----------------------------------------------------------------------
def _assert_same_run(r1, rk, check_failures=False):
    fields = ["n_done", "spent", "term_time", "n_events", "overflow"]
    if check_failures:
        fields += ["n_failed", "n_resubmits"]
    for f in fields:
        assert np.array_equal(np.asarray(getattr(r1, f)),
                              np.asarray(getattr(rk, f))), f
    np.testing.assert_allclose(np.asarray(r1.downtime),
                               np.asarray(rk.downtime))
    for f in ("status", "finish", "returned", "cost", "resource"):
        assert np.array_equal(np.asarray(getattr(r1.gridlets, f)),
                              np.asarray(getattr(rk.gridlets, f))), f


def test_batched_engine_bit_identical_on_golden_and_failure():
    """The acceptance contract of the k-step path: on the golden
    20-user WWG scenario AND on the seeded failure scenario, batch=k is
    bit-for-bit identical to batch=1 while running >= 1.5x fewer
    while-loop iterations; the supersteps merely repartition
    (n_steps_k1 == n_steps_k + n_spec_k)."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=100, n_users=20)
    kw = dict(deadline=2000.0, budget=22000.0, opt=types.OPT_COST,
              n_users=20)
    for sc in (None, simulation.Scenario(mtbf=500.0, mttr=25.0, seed=1)):
        r1 = simulation.run_experiment(g, fleet, **kw, scenario=sc,
                                       batch=1)
        rk = simulation.run_experiment(g, fleet, **kw, scenario=sc)
        _assert_same_run(r1, rk, check_failures=sc is not None)
        assert int(r1.n_spec) == 0
        assert int(r1.n_steps) == int(rk.n_steps) + int(rk.n_spec)
        assert int(r1.n_steps) >= 1.5 * int(rk.n_steps), \
            (int(r1.n_steps), int(rk.n_steps))


@settings(max_examples=4, deadline=None)
@given(batch=st.sampled_from([2, 3, 5, 8]), seed=st.integers(0, 99))
def test_batched_engine_property_identical(batch, seed):
    """Property form: for random failure seeds and odd batch depths the
    full event trace (times, kinds, actors) is identical to k=1."""
    fleet = resource.make_fleet([2, 2], [1.0, 1.0], [1.0, 2.0],
                                types.TIME_SHARED)
    g = gridlet.make_batch(jnp.full((10,), 25.0))
    sc = simulation.Scenario(mtbf=80.0, mttr=8.0, seed=seed)
    kw = dict(deadline=1000.0, budget=50000.0, opt=types.OPT_COST,
              n_users=1, scenario=sc)
    r1 = simulation.run_experiment(g, fleet, **kw, batch=1)
    rk = simulation.run_experiment(g, fleet, **kw, batch=batch)
    _assert_same_run(r1, rk, check_failures=True)
    assert int(r1.n_steps) == int(rk.n_steps) + int(rk.n_spec)


def test_reservation_boundary_cuts_speculation():
    """Horizon-boundary contract: a reservation window opening mid-slab
    is an interference point.  3 jobs on a 1-PE time-shared resource
    finish at 30/55/65 around a [40, 45) full-capacity hold; without the
    window the whole run folds into one iteration, with it the engine
    must commit both boundaries (and the completions they displace) in
    separate iterations -- while staying bit-identical to k=1."""
    fleet = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=jnp.inf)
    g = gridlet.make_batch([10.0, 20.0, 30.0])
    resv = [(0, 1, 40.0, 45.0)]
    free = engine.run_direct(g, fleet, 0, 0.0, max_events=64)
    assert int(free.n_steps) == 1          # arrivals + 3 speculated waves
    r1 = engine.run_direct(g, fleet, 0, 0.0, max_events=64,
                           reservations=resv, batch=1)
    rk = engine.run_direct(g, fleet, 0, 0.0, max_events=64,
                           reservations=resv)
    np.testing.assert_allclose(np.asarray(rk.gridlets.finish),
                               [30.0, 55.0, 65.0])
    for a, b in zip(r1.trace, rk.trace):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(r1.n_steps) == int(rk.n_steps) + int(rk.n_spec)
    # the two boundary commits forced >= 3 iterations (vs 1 unreserved)
    assert int(rk.n_steps) >= 3
    tt, kind, _ = (np.asarray(x) for x in rk.trace)
    np.testing.assert_allclose(tt[kind == des.K_RESERVATION],
                               [40.0, 45.0])


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), n_commits=st.sampled_from([0, 3, 9]))
def test_event_frontier_matches_stacked_source_mins(seed, n_commits):
    """The fused frontier pass over the sources' candidate arrays is
    exactly the stacked per-source ``next_time``/``horizon`` scalar
    reductions it replaced -- on real engine states from scenarios with
    live failure streams and reservation windows, at several points of
    the run."""
    from repro.kernels import ops as kernel_ops
    fleet = resource.make_fleet([2, 3], [1.0, 1.0], [1.0, 2.0],
                                types.TIME_SHARED,
                                weekend_load=jnp.asarray([0.0, 0.5]))
    g = gridlet.make_batch(jnp.full((8,), 40.0) +
                           jnp.arange(8, dtype=jnp.float32))
    params = engine.default_params(
        500.0, 50000.0, types.OPT_COST, 1, fleet.r, mtbf=90.0, mttr=9.0,
        reservations=[(0, 1, 30.0, 60.0)],
        fail_key=jax.random.PRNGKey(seed))
    state = engine.init_state(g, fleet, 1, params=params)
    commit = jax.jit(lambda s: engine._step_commit(
        s, fleet, params, 1, engine._empty_slab(s))[0])
    for _ in range(n_commits):
        state = commit(state)

    ctx = {}
    sources = engine._make_sources(fleet, params, 1, ctx)
    r_pad = state.row_gridlet.shape[0]
    ctx["scan"] = engine._scan_events(state, fleet, params, fleet.r,
                                      r_pad)
    cands = [s.candidates(state) for s in sources]
    sizes = tuple(c.shape[0] for c in cands)
    t_star, fired, counts, _, mins = kernel_ops.event_frontier(
        jnp.concatenate(cands), sizes)
    # the stacked scalar fan-in the frontier replaced
    times = np.asarray(jnp.stack([s.next_time(state) for s in sources]))
    assert np.array_equal(np.asarray(mins), times)
    t_ref = times.min()
    assert np.asarray(t_star) == np.float32(t_ref) or \
        (np.isinf(t_ref) and np.isinf(np.asarray(t_star)))
    want_fired = np.isfinite(times) & (times <= t_ref)
    assert np.array_equal(np.asarray(fired), want_fired)
    # oracle agreement on the identical candidate vector
    oracle = ref.event_frontier_ref(
        np.asarray(jnp.concatenate(cands)), sizes)
    for a, b in zip((t_star, fired, counts), oracle):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the horizon frontier == the stacked per-source horizon mins
    t_safe = engine._speculation_horizon(state, fleet, params, 1)
    horizons = np.asarray(
        jnp.stack([s.horizon(state, types.INF) for s in sources]))
    assert np.asarray(t_safe) == horizons.min() or \
        (np.isinf(horizons.min()) and np.isinf(np.asarray(t_safe)))


def test_slab_carry_keeps_sorts_rare():
    """The slab-fed scan must actually engage: on the 20-user WWG
    scenario the overwhelming majority of supersteps run sort-free
    (the carry only reseeds when the table restructures), and the
    reseed count is identical for batch=1 and batch=k (sorts happen
    exactly where the physics demands, not where the batching does)."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=50, n_users=10)
    kw = dict(deadline=2000.0, budget=22000.0, opt=types.OPT_COST,
              n_users=10)
    rk = simulation.run_experiment(g, fleet, **kw)
    r1 = simulation.run_experiment(g, fleet, **kw, batch=1)
    assert int(rk.n_reseeds) == int(r1.n_reseeds)
    assert int(rk.n_scans) >= int(rk.n_steps) + int(rk.n_spec)
    assert int(rk.n_reseeds) < 0.35 * int(rk.n_scans), \
        (int(rk.n_reseeds), int(rk.n_scans))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_event_scan_mask_paths_agree(seed):
    """The pe_blocked / row_ok masking agrees across Pallas interpret,
    the XLA fallback and the numpy oracle."""
    rng = np.random.RandomState(seed)
    r, j = 8, 12
    remaining = rng.exponential(50.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.3] = 0.0
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    tie = rng.permutation(r * j).reshape(r, j).astype(np.float32)
    pol = rng.randint(0, 2, (r,)).astype(np.int32)
    blocked = rng.randint(0, 9, (r,)).astype(np.float32)
    ok = (rng.rand(r) < 0.7).astype(np.float32)
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    kw = dict(tie=jnp.asarray(tie), policy=jnp.asarray(pol),
              pe_blocked=jnp.asarray(blocked), row_ok=jnp.asarray(ok))
    pallas_out = ops.event_scan(*args, **kw, interpret=True)
    xla_out = event_scan_xla(*args, **kw)
    ref_out = ref.event_scan_ref(remaining, mips, pes, tie=tie,
                                 policy=pol, pe_blocked=blocked,
                                 row_ok=ok)
    for got in (xla_out, ref_out):
        np.testing.assert_allclose(np.asarray(pallas_out[0]),
                                   np.asarray(got[0]), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(pallas_out[1]),
                                   np.asarray(got[1]), rtol=1e-4)
        assert np.array_equal(np.asarray(pallas_out[3]),
                              np.asarray(got[3]))
    assert np.array_equal(np.asarray(pallas_out[2]),
                          np.asarray(xla_out[2]))
