"""Sweep engine contract: the select-free path (engine.run_sweep /
simulation.sweep(select_free=True)) is bit-for-bit identical to the
reference batch=1 path over random deadline x budget grids crossed with
{OPT_COST, OPT_TIME} x failure seeds x net on/off; the sharded scenario
axis (simulation.sweep_sharded) matches the unsharded sweep exactly,
including under a forced multi-device host; and the slab kernels'
``live`` masked no-op gate is a bitwise no-op on all three backends.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import engine, gridlet, resource, simulation, types
from repro.kernels import ops, ref
from repro.kernels import event_scan as event_scan_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The "how" counters may pack the same events into supersteps
# differently between the reference and sweep loops (a mid-slab carry
# invalidation declines a micro-step the reference path would commit);
# every "what" field must match bitwise.
HOW_COUNTERS = {"n_steps", "n_spec", "n_scans", "n_reseeds"}


def assert_results_identical(a, b, tag=""):
    for name in a._fields:
        if name in HOW_COUNTERS:
            continue
        la = jax.tree_util.tree_leaves(getattr(a, name))
        lb = jax.tree_util.tree_leaves(getattr(b, name))
        assert len(la) == len(lb), name
        for i, (x, y) in enumerate(zip(la, lb)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                f"{tag}{name}[leaf {i}] differs"


def _case(seed, with_failures, with_net):
    rng = np.random.RandomState(seed)
    n_users = int(rng.randint(2, 4))
    n_jobs = int(rng.randint(4, 9))
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(seed), n_jobs=n_jobs,
                          n_users=n_users)
    deadlines = np.sort(rng.uniform(300.0, 2500.0, size=2)).tolist()
    budgets = np.sort(rng.uniform(3000.0, 25000.0, size=2)).tolist()
    scenario = simulation.Scenario(
        mtbf=float(rng.uniform(200.0, 600.0)) if with_failures else None,
        mttr=float(rng.uniform(20.0, 120.0)) if with_failures else None,
        seed=seed,
        baud_rate=1e6 if with_net else None)
    net_cap = None if with_net else 0   # None = auto-size
    return g, fleet, deadlines, budgets, scenario, n_users, net_cap


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 999),
       opt=st.sampled_from([types.OPT_COST, types.OPT_TIME]),
       with_failures=st.booleans(),
       with_net=st.booleans())
def test_sweep_select_free_bit_identical(seed, opt, with_failures,
                                         with_net):
    """simulation.sweep's select-free engine == the reference batch=1
    path, bitwise, over random grids x opt x failures x net."""
    g, fleet, dls, buds, scenario, n_users, net_cap = _case(
        seed, with_failures, with_net)
    ref_res = simulation.sweep(g, fleet, dls, buds, opt, n_users,
                               scenario=scenario, batch=1,
                               net_cap=net_cap, select_free=False)
    swp_res = simulation.sweep(g, fleet, dls, buds, opt, n_users,
                               scenario=scenario, net_cap=net_cap,
                               select_free=True)
    assert_results_identical(ref_res, swp_res)


def test_run_sweep_matches_run_inner_unbatched():
    """engine.run_sweep == engine.run_inner outside any vmap, and its
    batch=1 degenerate case == the batch=8 case (the micro-steps only
    repack work, never change it)."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=8, n_users=3)
    params = simulation._scenario_params(
        fleet, 1500.0, 15000.0, types.OPT_COST, 3, simulation.Scenario())
    me = simulation._max_events(g.n, 3, 3100.0, 1.0)
    a = engine.run_inner(g, fleet, params, 3, me, batch=1)
    b = engine.run_sweep(g, fleet, params, 3, me, batch=8)
    c = engine.run_sweep(g, fleet, params, 3, me, batch=1)
    for name in a._fields:
        if name in HOW_COUNTERS:
            continue
        for x, y, z in zip(jax.tree_util.tree_leaves(getattr(a, name)),
                           jax.tree_util.tree_leaves(getattr(b, name)),
                           jax.tree_util.tree_leaves(getattr(c, name))):
            assert np.array_equal(np.asarray(x), np.asarray(y)), name
            assert np.array_equal(np.asarray(x), np.asarray(z)), name


def test_sweep_sharded_matches_sweep_single_device():
    """sweep_sharded on the host's single device == sweep, bitwise
    (same lane layout, no shard_map in the way)."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(5), n_jobs=6, n_users=2)
    dls, buds = [700.0, 1400.0], [6000.0, 14000.0]
    a = simulation.sweep(g, fleet, dls, buds, types.OPT_COST, 2)
    b = simulation.sweep_sharded(g, fleet, dls, buds, types.OPT_COST, 2)
    assert_results_identical(a, b)


def test_sweep_sharded_matches_under_forced_devices():
    """shard_map smoke test: with 8 forced host devices, the sharded
    sweep (padded S = 6 -> 8 lanes) is bitwise identical to the plain
    vmap sweep.  Runs in a subprocess so the main pytest process keeps
    its single CPU device."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import gridlet, resource, simulation, types
        assert len(jax.devices()) == 8
        fleet = resource.wwg_fleet()
        g = gridlet.task_farm(jax.random.PRNGKey(5), n_jobs=6, n_users=2)
        dls = [500.0, 1000.0, 2000.0]
        buds = [6000.0, 14000.0]
        a = simulation.sweep(g, fleet, dls, buds, types.OPT_COST, 2)
        b = simulation.sweep_sharded(g, fleet, dls, buds,
                                     types.OPT_COST, 2)
        skip = {"n_steps", "n_spec", "n_scans", "n_reseeds"}
        for name in a._fields:
            if name in skip:
                continue
            for x, y in zip(jax.tree_util.tree_leaves(getattr(a, name)),
                            jax.tree_util.tree_leaves(getattr(b, name))):
                assert np.array_equal(np.asarray(x), np.asarray(y)), name
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 999), k=st.sampled_from([1, 4]))
def test_slab_live_gate_three_way(seed, k):
    """The slab kernels' scalar ``live`` gate: live=True is a bitwise
    pass-through, live=False an all-sentinel no-op -- on the XLA
    fallback, Pallas interpret and the numpy oracle alike."""
    rng = np.random.RandomState(seed)
    r, j = 8, 12
    rem = np.where(rng.rand(r, j) > 0.3, rng.rand(r, j) * 100.0, 0.0)
    rem = rem.astype(np.float32)
    mips = rng.uniform(1.0, 4.0, r).astype(np.float32)
    pes = rng.randint(1, 5, r).astype(np.int32)
    args = (jnp.asarray(rem), jnp.asarray(mips), jnp.asarray(pes))

    base = ops.event_scan_slab(*args, k)
    for live in (True, False):
        xla = ops.event_scan_slab(*args, k, live=jnp.asarray(live))
        pal = event_scan_mod.event_scan_slab(*args, k,
                                             live=jnp.asarray(live),
                                             interpret=True)
        orc = ref.event_scan_slab_ref(rem, mips, pes, k, live=live)
        if live:   # pass-through: bitwise equal to the ungated call
            assert np.array_equal(np.asarray(xla[0]), np.asarray(base[0]))
            assert np.array_equal(np.asarray(xla[1]), np.asarray(base[1]))
        else:      # no-op: every wave the (BIG, J) sentinel, everywhere
            assert np.all(np.asarray(xla[0]) >= 3.0e38)
            assert np.all(np.asarray(xla[1]) == j)
            for got in (pal, orc):
                assert np.array_equal(np.asarray(xla[0]),
                                      np.asarray(got[0]))
                assert np.array_equal(np.asarray(xla[1]),
                                      np.asarray(got[1]))
        np.testing.assert_allclose(np.asarray(xla[0]), np.asarray(pal[0]),
                                   rtol=2e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(xla[0]), np.asarray(orc[0]),
                                   rtol=2e-3, atol=1e-3)
        assert np.array_equal(np.asarray(xla[1]), np.asarray(pal[1]))
        assert np.array_equal(np.asarray(xla[1]), np.asarray(orc[1]))


def test_masked_apply_contract():
    """des.FnSource.masked_apply: fire=True == apply bitwise, fire=False
    == identity bitwise, even at a garbage event time -- the contract
    the sweep engine's unconditional supersteps rest on."""
    from repro.core import des
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(1), n_jobs=5, n_users=2)
    params = simulation._scenario_params(
        fleet, 900.0, 9000.0, types.OPT_COST, 2,
        simulation.Scenario(mtbf=300.0, mttr=50.0, seed=7))
    state = engine.init_state(g, fleet, 2, params=params)

    def bump(s, now):   # touches floats, ints and the rng key
        key, _ = jax.random.split(s.rng_key)
        return types.replace(s, t=jnp.maximum(s.t, now),
                             n_events=s.n_events + 1, rng_key=key)

    src = des.FnSource(kind=des.K_FAILURE, name="bump",
                       candidates_fn=lambda s: jnp.full((1,), types.INF),
                       apply_fn=bump)
    t = jnp.asarray(25.0, jnp.float32)
    garbage = jnp.asarray(-1.0e30, jnp.float32)
    on = src.masked_apply(state, t, jnp.asarray(True))
    want = src.apply(state, t)
    off = src.masked_apply(state, garbage, jnp.asarray(False))
    for x, y in zip(jax.tree_util.tree_leaves(on),
                    jax.tree_util.tree_leaves(want)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(off),
                    jax.tree_util.tree_leaves(state)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("model,kind_name", [("commodity", "market"),
                                             ("auction", "auction")])
def test_pricing_sources_masked_apply_noop(model, kind_name):
    """The MARKET and AUCTION sources honour the masked-apply contract
    on the REAL engine sources: fire=True == apply bitwise; fire=False
    == bitwise identity even at a garbage event time (every write is
    gated on the round being due, and the auction's PRNG split is
    selected back).  This is what lets the sweep paths run pricing
    rounds unconditionally."""
    from repro.core import des
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(2), n_jobs=6, n_users=2)
    params = simulation._scenario_params(
        fleet, 900.0, 9000.0, types.OPT_COST, 2,
        simulation.Scenario(pricing_model=model, market_period=40.0,
                            auction_period=40.0, seed=5))
    state = engine.init_state(g, fleet, 2, params=params)
    sources = engine._make_sources(fleet, params, 2,
                                   {"select_free": True})
    pos = {s.kind: i for i, s in enumerate(sources)}
    kind = des.K_MARKET if model == "commodity" else des.K_AUCTION
    src = sources[pos[kind]]
    assert src.name == kind_name

    t_due = jnp.asarray(40.0, jnp.float32)      # the round IS due
    garbage = jnp.asarray(-1.0e30, jnp.float32)
    on = src.masked_apply(state, t_due, jnp.asarray(True))
    want = src.apply(state, t_due)
    off = src.masked_apply(state, garbage, jnp.asarray(False))
    for x, y in zip(jax.tree_util.tree_leaves(on),
                    jax.tree_util.tree_leaves(want)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(off),
                    jax.tree_util.tree_leaves(state)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # The fired round really moved the posted price and rescheduled.
    assert not np.array_equal(np.asarray(on.price), np.asarray(state.price))
    nxt = on.next_market if model == "commodity" else on.next_auction
    assert float(nxt) == 80.0


def test_run_sweep_lanes_matches_per_lane_reference():
    """engine.run_sweep_lanes (the lane-batched loop with any-lane
    cond skips) == running each lane's params through engine.run_inner
    one at a time -- heterogeneous lanes, so some iterations take the
    skip branches while others need the taken ones."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(11), n_jobs=7, n_users=3)
    tmpl = simulation._scenario_params(
        fleet, 0.0, 0.0, types.OPT_COST, 3, simulation.Scenario())
    me = simulation._max_events(g.n, 3, 4100.0, 1.0)
    dls = jnp.asarray([250.0, 900.0, 2000.0], jnp.float32)
    buds = jnp.asarray([2500.0, 9000.0, 20000.0], jnp.float32)
    p_lanes = jax.vmap(
        lambda d, b: simulation._scenario_point(tmpl, d, b, 3))(dls, buds)
    lanes = jax.jit(
        lambda p: engine.run_sweep_lanes(g, fleet, p, 3, me))(p_lanes)
    for i in range(dls.shape[0]):
        one = engine.run_inner(
            g, fleet, simulation._scenario_point(tmpl, dls[i], buds[i], 3),
            3, me, batch=1)
        lane = jax.tree_util.tree_map(lambda x: x[i], lanes)
        assert_results_identical(one, lane, tag=f"lane{i} ")
