"""Unit tests: des, economy, stats, rand, reservation, gis, calendar,
segments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (calendar, des, economy, gis, gridlet, rand,
                        reservation, resource, segments, stats, types)


# ---------------------------------------------------------- des --------
def test_event_queue_orders_by_time_then_fifo():
    q = des.make_queue(8)
    q = des.schedule(q, 5.0, 0, 1, 10)
    q = des.schedule(q, 2.0, 0, 1, 11)
    q = des.schedule(q, 5.0, 0, 1, 12)   # same time as first -> FIFO
    order = []
    for _ in range(3):
        q, (t, src, dst, tag, data, valid) = des.pop_next(q)
        assert bool(valid)
        order.append((float(t), int(tag)))
    assert order == [(2.0, 11), (5.0, 10), (5.0, 12)]
    assert int(q.overflow) == 0
    q, (*_, valid) = des.pop_next(q)
    assert not bool(valid)


def test_event_queue_full_drops_and_counts():
    """A full calendar must not overwrite a live event (it previously
    clobbered slot 0); the dropped schedule is counted in overflow."""
    q = des.make_queue(2)
    q = des.schedule(q, 1.0, 0, 0, 10)
    q = des.schedule(q, 2.0, 0, 0, 11)
    q = des.schedule(q, 0.5, 0, 0, 12)   # full: dropped, not slot 0
    assert int(q.overflow) == 1
    q, (t, *_, tag, _d, valid) = des.pop_next(q)
    assert bool(valid) and float(t) == 1.0
    # freeing a slot makes schedule work again, overflow is sticky
    q = des.schedule(q, 3.0, 0, 0, 13)
    assert int(des.size(q)) == 2 and int(q.overflow) == 1


def test_event_queue_cancel():
    q = des.make_queue(4)
    q = des.schedule(q, 1.0, 7, 1, 10)
    q = des.schedule(q, 2.0, 8, 1, 11)
    q = des.cancel(q, lambda q: q.src == 7)  # stale-event discard rule
    q, (t, *_, valid) = des.pop_next(q)
    assert bool(valid) and float(t) == 2.0


@settings(max_examples=20, deadline=None)
@given(times=st.lists(st.floats(0.0, 100.0), min_size=1, max_size=16))
def test_event_queue_pop_sorted(times):
    q = des.make_queue(len(times))
    for i, t in enumerate(times):
        q = des.schedule(q, t, 0, 0, i)
    popped = []
    for _ in times:
        q, (t, *_, valid) = des.pop_next(q)
        popped.append(float(t))
    assert popped == sorted(np.float32(times).tolist())
    assert int(q.overflow) == 0


# ------------------------------------------------------ economy --------
def test_eq1_eq2_bounds():
    fleet = resource.wwg_fleet()
    total_mi = 200 * 10_000.0
    tmin = float(economy.t_min(fleet, total_mi))
    tmax = float(economy.t_max(fleet, total_mi))
    cmin = float(economy.c_min(fleet, total_mi))
    cmax = float(economy.c_max(fleet, total_mi))
    assert 0 < tmin < tmax
    assert 0 < cmin < cmax
    # D/B factor endpoints
    assert float(economy.deadline_from_factor(fleet, total_mi, 0.0)) == \
        pytest.approx(tmin)
    assert float(economy.deadline_from_factor(fleet, total_mi, 1.0)) == \
        pytest.approx(tmax)
    assert float(economy.budget_from_factor(fleet, total_mi, 0.0)) == \
        pytest.approx(cmin)
    # negative factors produce infeasible constraints (< minimum)
    assert float(economy.deadline_from_factor(fleet, total_mi, -0.5)) < tmin


# -------------------------------------------------------- stats --------
def test_accumulator_moments():
    acc = stats.accumulator()
    xs = [1.0, 2.0, 3.0, 4.0]
    for x in xs:
        acc = stats.add(acc, x)
    assert float(stats.mean(acc)) == pytest.approx(2.5)
    assert float(stats.std(acc)) == pytest.approx(np.std(xs))
    assert float(acc.vmin) == 1.0 and float(acc.vmax) == 4.0


def test_accumulator_bulk_masked():
    acc = stats.accumulator()
    acc = stats.add_many(acc, jnp.array([1.0, 100.0, 3.0]),
                         mask=jnp.array([1.0, 0.0, 1.0]))
    assert float(stats.mean(acc)) == pytest.approx(2.0)
    assert float(acc.vmax) == 3.0


# --------------------------------------------------------- rand --------
@settings(max_examples=20, deadline=None)
@given(d=st.floats(1.0, 1e4), fl=st.floats(0.0, 1.0),
       fm=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_gridsim_random_range(d, fl, fm, seed):
    v = float(rand.real(jax.random.PRNGKey(seed), d, fl, fm))
    assert (1 - fl) * d - 1e-3 <= v <= (1 + fm) * d + 1e-3


def test_gridsim_random_deterministic():
    k = jax.random.PRNGKey(0)
    assert float(rand.real(k, 10.0, 0.1, 0.1)) == \
        float(rand.real(k, 10.0, 0.1, 0.1))


# -------------------------------------------------- reservation --------
def test_reservation_booking_and_conflicts():
    book = reservation.ReservationBook([2, 4])
    r1 = book.book(0, 1, 0.0, 10.0)
    book.book(0, 1, 0.0, 10.0)
    with pytest.raises(ValueError):
        book.book(0, 1, 5.0, 15.0)       # both PEs held on [5,10)
    book.book(0, 2, 10.0, 20.0)          # back-to-back is fine
    assert book.reserved_pes(0, 5.0) == 2
    assert book.reserved_pes(0, 15.0) == 2
    book.cancel(r1)
    assert book.reserved_pes(0, 5.0) == 1
    assert book.load_factor(1, 0.0) == 0.0


def test_reservation_validation():
    book = reservation.ReservationBook([2])
    with pytest.raises(ValueError):
        book.book(0, 0, 0.0, 1.0)
    with pytest.raises(ValueError):
        book.book(0, 1, 5.0, 5.0)
    with pytest.raises(ValueError):
        book.book(1, 1, 0.0, 1.0)


# ---------------------------------------------------------- gis --------
def test_gis_register_deregister():
    fleet = resource.wwg_fleet()
    g = gis.init(fleet)
    assert bool(gis.resource_list(g).all())
    g = gis.deregister(g, 3)
    rate, cost = gis.dynamics(g, fleet, 0.0)
    assert float(rate[3]) == 0.0
    assert float(rate[0]) > 0.0
    g = gis.register(g, 3)
    rate, _ = gis.dynamics(g, fleet, 0.0)
    assert float(rate[3]) > 0.0


# ----------------------------------------------------- calendar --------
def test_calendar_weekend_load():
    fleet = resource.make_fleet([1, 1], 100.0, 1.0, types.TIME_SHARED,
                                time_zone=[0.0, 0.0],
                                base_load=0.1, weekend_load=0.4)
    # t=0 is Monday 00:00 UTC; Saturday starts at hour 120.
    weekday = np.asarray(calendar.load(fleet, 10.0))
    weekend = np.asarray(calendar.load(fleet, 121.0))
    np.testing.assert_allclose(weekday, 0.1, atol=1e-6)
    np.testing.assert_allclose(weekend, 0.5, atol=1e-6)
    assert float(calendar.effective_mips(fleet, 10.0)[0]) == \
        pytest.approx(90.0)


def test_calendar_time_zone_shift():
    fleet = resource.make_fleet([1, 1], 100.0, 1.0, types.TIME_SHARED,
                                time_zone=[0.0, 24.0 * 5],
                                base_load=0.0, weekend_load=0.5)
    load = np.asarray(calendar.load(fleet, 1.0))
    assert load[0] == 0.0 and load[1] == 0.5  # zone-shifted into Saturday


# ----------------------------------------------------- segments --------
@settings(max_examples=25, deadline=None)
@given(
    groups=st.lists(st.integers(0, 3), min_size=1, max_size=24),
    seed=st.integers(0, 1000),
)
def test_group_rank_matches_numpy(groups, seed):
    rng = np.random.RandomState(seed)
    n = len(groups)
    keys = rng.rand(n).astype(np.float32)
    member = rng.rand(n) > 0.3
    gk = jnp.asarray(groups, jnp.int32)
    rank, counts = segments.group_rank(gk, jnp.asarray(member),
                                       jnp.asarray(keys), 4)
    rank, counts = np.asarray(rank), np.asarray(counts)
    for grp in range(4):
        idxs = [i for i in range(n) if member[i] and groups[i] == grp]
        assert counts[grp] == len(idxs)
        expect = sorted(idxs, key=lambda i: (keys[i], i))
        for want_rank, i in enumerate(expect):
            assert rank[i] == want_rank


@settings(max_examples=25, deadline=None)
@given(
    groups=st.lists(st.integers(0, 2), min_size=1, max_size=16),
    seed=st.integers(0, 1000),
)
def test_group_prefix_sum_matches_numpy(groups, seed):
    rng = np.random.RandomState(seed)
    n = len(groups)
    vals = rng.rand(n).astype(np.float32) * 10
    order = rng.rand(n).astype(np.float32)
    member = rng.rand(n) > 0.3
    out = np.asarray(segments.group_prefix_sum(
        jnp.asarray(groups, jnp.int32), jnp.asarray(member),
        jnp.asarray(order), jnp.asarray(vals), 3))
    for grp in range(3):
        idxs = [i for i in range(n) if member[i] and groups[i] == grp]
        idxs.sort(key=lambda i: (order[i], i))
        run = 0.0
        for i in idxs:
            assert out[i] == pytest.approx(run, abs=1e-4)
            run += vals[i]
