"""The telemetry subsystem: ring semantics, exporter schema, the
utilisation post-processor and the speculation-safety off-gate.

The bitwise on/off identity across engine paths is pinned by
tests/test_scenario_fuzz.py (the fuzz corpus runs every path with the
ring recording); this module pins everything else: the golden JSONL
row schema (a field added to ``telemetry.record`` without updating
``SCHEMA``/docs fails here, not in a consumer), Chrome trace_event
structure, drop-past-capacity ring behaviour, and that ``telemetry=
None`` yields ``result.telemetry is None`` on every run path.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import engine, gridlet, resource, simulation, telemetry, types


@pytest.fixture(scope="module")
def traced_run():
    fleet = resource.make_fleet(
        num_pe=[2, 4], mips_per_pe=[100.0, 200.0],
        cost_per_sec=[2.0, 4.0], policy=types.TIME_SHARED)
    farm = gridlet.task_farm(jax.random.PRNGKey(0), n_jobs=12)
    res = simulation.run_experiment(farm, fleet, deadline=10_000.0,
                                    budget=1e7, telemetry=256,
                                    max_events=512)
    return fleet, farm, res


def test_result_carries_ring(traced_run):
    fleet, farm, res = traced_run
    tel = res.telemetry
    assert tel is not None
    assert telemetry.n_recorded(tel) > 0
    assert not telemetry.truncated(tel)
    # One row per applied superstep; the ring's event column must sum
    # to the engine's own event counter.
    rows = telemetry.rows(tel)
    assert sum(r["events"] for r in rows) == int(np.asarray(res.n_events))
    # Commit instants are non-decreasing (chronological ring).
    t = [r["t"] for r in rows]
    assert all(a <= b for a, b in zip(t, t[1:]))


def test_jsonl_golden_schema(traced_run, tmp_path):
    """The exporter writes exactly the documented SCHEMA keys with the
    documented python kinds -- the golden trace-schema contract."""
    _, _, res = traced_run
    path = tmp_path / "trace.jsonl"
    n = telemetry.to_jsonl(res.telemetry, path)
    lines = path.read_text().splitlines()
    assert n == len(lines) > 0
    kinds = {"int": int, "float": float, "list[str]": list,
             "list[float]": list, "list[int]": list}
    for line in lines:
        row = json.loads(line)
        assert set(row) == set(telemetry.SCHEMA), \
            "JSONL keys drifted from telemetry.SCHEMA"
        for key, (kind, _) in telemetry.SCHEMA.items():
            assert isinstance(row[key], kinds[kind]), (key, kind)
        for name in row["kinds"]:
            assert name in telemetry.KIND_NAMES.values()


def test_chrome_trace_structure(traced_run, tmp_path):
    _, _, res = traced_run
    path = tmp_path / "trace.json"
    n = telemetry.to_chrome_trace(res.telemetry, path)
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == n > 0
    assert {e["ph"] for e in events} == {"C", "i"}
    for e in events:
        assert {"name", "ph", "ts", "pid"} <= set(e)
    # Counter tracks exist for each documented series.
    names = {e["name"] for e in events if e["ph"] == "C"}
    assert {"utilisation", "queue_depth", "price", "economy",
            "network"} <= names


def test_utilisation_series(traced_run):
    fleet, farm, res = traced_run
    t, util = telemetry.utilisation(res.telemetry)
    assert t.shape[0] == util.shape[0] == telemetry.n_recorded(res.telemetry)
    assert util.shape[1] == fleet.r
    assert (util >= 0.0).all() and (util <= 1.0).all()
    # Left-Riemann integral recovers executed MI exactly on this
    # load-free fleet (same audit examples/utilisation_trace.py runs).
    npe = np.asarray(fleet.num_pe, np.float64)
    mips = np.asarray(fleet.mips_per_pe, np.float64)
    integral = ((util[:-1].astype(np.float64) * npe * mips).sum(1)
                * np.diff(t)).sum()
    done = np.asarray(res.gridlets.status) == types.DONE
    mi_done = np.asarray(res.gridlets.length_mi, np.float64)[done].sum()
    np.testing.assert_allclose(integral, mi_done, rtol=1e-3)


def test_ring_drops_past_capacity(traced_run):
    """A tiny ring drops rows instead of wrapping, keeps counting, and
    changes nothing about the simulation results."""
    fleet, farm, _ = traced_run
    params = simulation._scenario_params(fleet, 10_000.0, 1e7,
                                         types.OPT_COST, 1, None)
    big = engine.run(farm, fleet, params, 1, 512, telemetry=256)
    tiny = engine.run(farm, fleet, params, 1, 512, telemetry=4)
    assert telemetry.truncated(tiny.telemetry)
    assert (telemetry.n_recorded(tiny.telemetry)
            == telemetry.n_recorded(big.telemetry))
    assert len(telemetry.rows(tiny.telemetry)) == 4
    # The first 4 rows are identical -- later writes dropped, never
    # wrapped over them.
    for a, b in zip(telemetry.rows(tiny.telemetry),
                    telemetry.rows(big.telemetry)):
        assert a == b
    for f in ("spent", "term_time", "n_events"):
        assert np.array_equal(np.asarray(getattr(big, f)),
                              np.asarray(getattr(tiny, f)))


def test_off_gate_is_none(traced_run):
    fleet, farm, _ = traced_run
    params = simulation._scenario_params(fleet, 10_000.0, 1e7,
                                         types.OPT_COST, 1, None)
    assert engine.run(farm, fleet, params, 1, 512).telemetry is None
    assert engine.run_inner(farm, fleet, params, 1, 512).telemetry is None
    assert engine.run_sweep(farm, fleet, params, 1, 512).telemetry is None
    res = simulation.run_experiment(farm, fleet, deadline=10_000.0,
                                    budget=1e7, max_events=512)
    assert res.telemetry is None


def test_init_rejects_nonpositive_capacity():
    with pytest.raises(ValueError):
        telemetry.init(0, 2)
    with pytest.raises(ValueError):
        telemetry.init(-8, 2)


def test_depth_column_marks_slab_position(traced_run):
    """Speculative micro-steps record their position inside the slab;
    committing supersteps record depth 0."""
    fleet, farm, _ = traced_run
    params = simulation._scenario_params(fleet, 10_000.0, 1e7,
                                         types.OPT_COST, 1, None)
    r1 = engine.run(farm, fleet, params, 1, 512, batch=1, telemetry=256)
    rk = engine.run(farm, fleet, params, 1, 512, batch=8, telemetry=256)
    assert all(r["depth"] == 0 for r in telemetry.rows(r1.telemetry))
    depths = [r["depth"] for r in telemetry.rows(rk.telemetry)]
    assert max(depths) > 0, "batch=8 never speculated on this farm"
    assert max(depths) <= 7  # at most batch - 1 micro-steps per slab
    # Depth resets at each commit and increments within a slab.
    for prev, cur in zip(depths, depths[1:]):
        assert cur == 0 or cur == prev + 1
