"""Regenerate tests/data/golden_auction.json — the pinned event trace
for a small sealed-bid auction scenario (dynamic-pricing suite).

Run from the repo root against a known-good engine revision:

    PYTHONPATH=src python tests/data/gen_golden_auction.py

The golden is the batch=1 reference run (the canonical event order);
tests assert both batch=1 and the default batch reproduce it bitwise.
The scenario is sized so several K_AUCTION rounds land inside the
64-slot trace ring, interleaved with completions and broker polls.
"""
import json
import os

import jax
import numpy as np

from repro.core import des, engine, gridlet, resource, simulation, types

OUT = os.path.join(os.path.dirname(__file__), "golden_auction.json")


def build_case():
    fleet = resource.make_fleet([2, 4], [300.0, 500.0], [2.0, 5.0],
                                [types.TIME_SHARED, types.SPACE_SHARED])
    g = gridlet.task_farm(jax.random.PRNGKey(6), n_jobs=10, n_users=2)
    sc = simulation.Scenario(pricing_model="auction", auction_period=15.0,
                             seed=8)
    params = simulation._scenario_params(fleet, 400.0, 20_000.0,
                                         types.OPT_COST, 2, sc)
    max_jobs = simulation.safe_max_jobs(g, params, fleet)
    return g, fleet, params, max_jobs


def main():
    g, fleet, params, max_jobs = build_case()
    r = engine.run(g, fleet, params, 2, 4096, max_jobs=max_jobs, batch=1)
    tt, kind, who = (np.asarray(x) for x in r.trace)
    m = kind >= 0
    n_auction = int((kind[m] == des.K_AUCTION).sum())
    assert n_auction >= 3, f"only {n_auction} auction rounds in trace"
    golden = {
        "_scenario": "golden_auction (2 res, task_farm seed 6, 10 jobs "
                     "x 2 users, auction_period=15, auction seed 8, "
                     "OPT_COST, batch=1)",
        "n_done": int((np.asarray(r.gridlets.status)
                       == types.DONE).sum()),
        "returned": np.asarray(r.gridlets.returned).tolist(),
        "spent": np.asarray(r.spent).tolist(),
        "term_time": np.asarray(r.term_time).tolist(),
        "n_events": int(np.asarray(r.n_events)),
        "overflow": int(np.asarray(r.overflow)),
        "trace_t": tt[m].tolist(),
        "trace_kind": kind[m].astype(int).tolist(),
        "trace_who": who[m].astype(int).tolist(),
    }
    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1)
    print(f"wrote {OUT}: {int(m.sum())} trace events "
          f"({n_auction} auction rounds), n_events={golden['n_events']}")


if __name__ == "__main__":
    main()
