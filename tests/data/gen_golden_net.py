"""Regenerate tests/data/golden_net_20u.json — the pinned event trace
for the contended ``engine_20u_100j_net`` BENCH row.

Run from the repo root against a known-good engine revision:

    PYTHONPATH=src python tests/data/gen_golden_net.py

The golden is the batch=1 reference run (the canonical event order);
tests assert both batch=1 and the default batch reproduce it bitwise.
"""
import json
import os

import jax
import numpy as np

from repro.core import engine, gridlet, resource, simulation, types

OUT = os.path.join(os.path.dirname(__file__), "golden_net_20u.json")


def main():
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=100, n_users=20,
                          in_bytes=200_000.0, out_bytes=100_000.0)
    sc = simulation.Scenario(baud_rate=28_000.0, bg_flows=1.0)
    params = simulation._scenario_params(fleet, 2000.0, 22000.0,
                                         types.OPT_COST, 20, sc)
    net_cap = simulation.safe_net_cap(g, params, fleet, 20)
    max_jobs = simulation.safe_max_jobs(g, params, fleet)
    r = engine.run(g, fleet, params, 20, 16384, max_jobs=max_jobs,
                   batch=1, net_cap=net_cap)
    tt, kind, who = (np.asarray(x) for x in r.trace)
    m = kind >= 0
    golden = {
        "_scenario": "engine_20u_100j_net (wwg_fleet, task_farm seed 3, "
                     "baud=28000, bg=1, in=200k out=100k, batch=1)",
        "n_done": int((np.asarray(r.gridlets.status)
                       == types.DONE).sum()),
        "returned": np.asarray(r.gridlets.returned).tolist(),
        "spent": np.asarray(r.spent).tolist(),
        "term_time": np.asarray(r.term_time).tolist(),
        "n_events": int(np.asarray(r.n_events)),
        "overflow": int(np.asarray(r.overflow)),
        "trace_t": tt[m].tolist(),
        "trace_kind": kind[m].astype(int).tolist(),
        "trace_who": who[m].astype(int).tolist(),
    }
    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1)
    print(f"wrote {OUT}: {int(m.sum())} trace events, "
          f"n_events={golden['n_events']}")


if __name__ == "__main__":
    main()
