"""Correlated failure domains: shared-trunk topology, trace-driven
fault injection and the fault-tolerant retry/backoff broker.

Pins the PR's three layers and their contracts:

* topology math (``network.trunk_topology`` / ``trunk_incidence`` /
  ``trunk_rate_cap``) and the capped fair-share ``link_scan`` across
  all three kernel paths (Pallas interpret / XLA / numpy oracle);
* engine semantics -- a trunk-target trace row fails every resource
  behind the trunk in ONE superstep (one K_TRACE event), downtime
  accrues per member, and the failure counters replay bit-for-bit
  across every batch depth and engine path (``run`` / ``run_inner`` /
  ``run_sweep_lanes``);
* broker fault tolerance -- retry budgets abandon chronically failing
  gridlets, exponential backoff delays re-dispatch, the cooldown
  blacklist shuns freshly recovered resources;
* the frozen default: a Scenario with every new knob at its default is
  bitwise identical to no scenario at all, and the per-lane
  ``truncated`` / ``overflow`` diagnostics surface through ``sweep`` /
  ``sweep_sharded``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import des, engine, gridlet, network, resource, simulation
from repro.core.types import DONE, FAILED, OPT_COST, TIME_SHARED
from repro.kernels import ops
from repro.kernels import event_scan as event_scan_mod
from repro.kernels import ref


def _fleet3():
    return resource.make_fleet([4, 4, 4], 100.0, [1.0, 2.0, 3.0],
                               TIME_SHARED)


def _jobs(n=12, mi=500.0, in_bytes=None):
    return gridlet.make_batch(jnp.full((n,), mi), in_bytes=in_bytes,
                              user=jnp.zeros((n,), jnp.int32))


# ----------------------------------------------------------------------
# Topology math
# ----------------------------------------------------------------------
def test_trunk_topology_gathers_per_resource():
    t_of, baud_r, bg_r = network.trunk_topology(
        [0, 1, 0, -1], 4, trunk_baud=[100.0, 200.0], trunk_bg=[1.0, 0.0])
    assert np.array_equal(np.asarray(t_of), [0, 1, 0, -1])
    np.testing.assert_allclose(np.asarray(baud_r),
                               [100.0, 200.0, 100.0, network.BIG])
    np.testing.assert_allclose(np.asarray(bg_r), [1.0, 0.0, 1.0, 0.0])


def test_trunk_topology_validates():
    with pytest.raises(ValueError):
        network.trunk_topology([0, 0], 3)          # wrong length
    with pytest.raises(ValueError):
        network.trunk_topology([0, -2], 2)         # id below -1


def test_trunk_incidence_and_rate_cap():
    t_of = jnp.asarray([0, 0, 1, -1], jnp.int32)
    inc = np.asarray(network.trunk_incidence(t_of, 4))
    assert np.array_equal(inc, [[1, 1, 0, 0], [1, 1, 0, 0],
                                [0, 0, 1, 0], [0, 0, 0, 0]])
    # occupancy 3+2 on trunk 0, 4 on trunk 1; bg 1 on trunk 0
    cap = np.asarray(network.trunk_rate_cap(
        jnp.asarray([3, 2, 4, 7]), t_of,
        jnp.asarray([120.0, 120.0, 80.0, network.BIG]),
        jnp.asarray([1.0, 1.0, 0.0, 0.0])))
    np.testing.assert_allclose(cap[:3], [120.0 / 6, 120.0 / 6, 80.0 / 4])
    assert cap[3] == network.BIG                   # private never binds


def test_link_scan_cap_paths_agree():
    rng = np.random.RandomState(5)
    rem = rng.exponential(1e5, (8, 12)).astype(np.float32)
    rem[rng.rand(8, 12) < 0.4] = 0.0
    baud = rng.uniform(100.0, 1e4, (8,)).astype(np.float32)
    bg = rng.choice([0.0, 1.0], (8,)).astype(np.float32)
    cap = rng.uniform(50.0, 500.0, (8,)).astype(np.float32)
    cap[0] = network.BIG                           # never-binding row
    args = (jnp.asarray(rem), jnp.asarray(baud))
    kw = dict(bg=jnp.asarray(bg), cap=jnp.asarray(cap))
    pallas_out = ops.link_scan(*args, **kw, interpret=True)
    xla_out = event_scan_mod.link_scan_xla(*args, **kw)
    ref_out = ref.link_scan_ref(rem, baud, bg=bg, cap=cap)
    for got, name in ((xla_out, "xla"), (ref_out, "oracle")):
        np.testing.assert_allclose(np.asarray(pallas_out[0]),
                                   np.asarray(got[0]), rtol=1e-4,
                                   atol=1e-4, err_msg=name)
        np.testing.assert_allclose(np.asarray(pallas_out[1]),
                                   np.asarray(got[1]), rtol=1e-4,
                                   err_msg=name)
    # the cap binds: no transfer exceeds it, and rows where the private
    # share already sat below the cap are untouched
    rate = np.asarray(xla_out[0])
    assert (rate <= cap[:, None] * (1 + 1e-5)).all()
    un_out = event_scan_mod.link_scan_xla(*args, bg=jnp.asarray(bg))
    un_rate = np.asarray(un_out[0])
    loose = un_rate <= cap[:, None] * (1 - 1e-5)
    np.testing.assert_allclose(rate[loose], un_rate[loose], rtol=1e-6)


# ----------------------------------------------------------------------
# Engine: correlated failure + trace semantics
# ----------------------------------------------------------------------
def test_trunk_cut_fails_domain_in_one_superstep():
    """One trunk-target down row fells every resource behind the trunk
    in a single K_TRACE event; victims refund, resubmit elsewhere and
    still finish."""
    sc = simulation.Scenario(trunk_of=[0, 0, -1],
                             fault_trace=[(1.0, 3 + 0, 0)])  # R + id
    r = simulation.run_experiment(_jobs(), _fleet3(), 100.0, 1e9,
                                  OPT_COST, scenario=sc)
    assert int(r.n_failed) > 0
    assert int(r.n_resubmits) == int(r.n_failed)
    assert float(r.n_done.sum()) == 12.0
    dt = np.asarray(r.downtime)
    assert dt[0] == dt[1] and dt[0] > 0.0 and dt[2] == 0.0


def test_trace_event_count_is_one_per_instant():
    """The whole failure domain goes down under ONE trace event -- the
    event log records a single K_TRACE firing per schedule row, not one
    per member resource."""
    sc = simulation.Scenario(trunk_of=[0, 0, -1],
                             fault_trace=[(1.0, 3, 0), (5.0, 3, 1)])
    g, fleet = _jobs(), _fleet3()
    params = simulation._scenario_params(fleet, 100.0, 1e9, OPT_COST, 1,
                                         sc)
    res = engine.run(g, fleet, params, 1, 512,
                     max_jobs=simulation.safe_max_jobs(g, params, fleet),
                     batch=1)
    kinds = np.asarray(res.trace[1])
    assert (kinds == des.K_TRACE).sum() == 2
    dt = np.asarray(res.downtime)
    np.testing.assert_allclose(dt, [4.0, 4.0, 0.0], atol=1e-4)


def test_trace_counters_identical_across_paths():
    """n_failed / n_resubmits / downtime replay bit-for-bit across
    batch depths {1, 2, 8} and across run / run_inner /
    run_sweep_lanes under a trunk-cut trace scenario."""
    sc = simulation.Scenario(trunk_of=[0, 0, -1],
                             fault_trace=[(1.0, 3, 0), (5.0, 3, 1),
                                          (9.0, 2, 0), (11.0, 2, 1)],
                             retry_limit=3, backoff_base=0.5,
                             blacklist_cooldown=2.0)
    g, fleet = _jobs(), _fleet3()
    params = simulation._scenario_params(fleet, 100.0, 1e9, OPT_COST, 1,
                                         sc)
    kw = dict(max_jobs=simulation.safe_max_jobs(g, params, fleet))
    ref_res = engine.run(g, fleet, params, 1, 512, batch=1, **kw)
    want = {f: np.asarray(getattr(ref_res, f))
            for f in ("n_failed", "n_resubmits", "downtime", "spent",
                      "term_time")}
    assert int(ref_res.n_failed) > 0

    runs = {}
    for b in (2, 8):
        runs[f"run.b{b}"] = engine.run(g, fleet, params, 1, 512,
                                       batch=b, **kw)
    runs["run_inner"] = jax.jit(
        lambda gg, pp: engine.run_inner(gg, fleet, pp, 1, 512, **kw))(
        g, params)
    lanes = jax.jit(
        lambda gg, pp: engine.run_sweep_lanes(gg, fleet, pp, 1, 512,
                                              batch=8, **kw))(
        g, jax.tree_util.tree_map(lambda a: jnp.stack([a, a]), params))
    for lane in range(2):
        runs[f"lanes.l{lane}"] = jax.tree_util.tree_map(
            lambda a: a[lane], lanes)
    for name, r in runs.items():
        for f, w in want.items():
            assert np.array_equal(w, np.asarray(getattr(r, f))), \
                f"{name} diverges at {f}"


def test_trunk_bandwidth_caps_transfer_rates():
    """Net mode: two resources behind a half-speed trunk finish their
    stagings later than over private links, identically at every batch
    depth."""
    g = _jobs(n=4, mi=100.0, in_bytes=jnp.full((4,), 1000.0))
    fleet = resource.make_fleet([4, 4], 100.0, 1.0, TIME_SHARED)
    r_priv = simulation.run_experiment(
        g, fleet, 1000.0, 1e9, OPT_COST, net_cap=None,
        scenario=simulation.Scenario(baud_rate=100.0))
    sc = simulation.Scenario(baud_rate=100.0, trunk_of=[0, 0],
                             trunk_baud=50.0)
    r_tr = simulation.run_experiment(g, fleet, 1000.0, 1e9, OPT_COST,
                                     net_cap=None, scenario=sc)
    assert float(r_tr.term_time.max()) > float(r_priv.term_time.max())
    for b in (1, 2):
        rb = simulation.run_experiment(g, fleet, 1000.0, 1e9, OPT_COST,
                                       net_cap=None, scenario=sc,
                                       batch=b)
        assert float(rb.term_time.max()) == float(r_tr.term_time.max())
        assert float(rb.spent.sum()) == float(r_tr.spent.sum())


# ----------------------------------------------------------------------
# Broker fault tolerance
# ----------------------------------------------------------------------
def test_retry_limit_abandons_chronic_failures():
    """With retry_limit=0 a single failure abandons the gridlet: no
    resubmission, terminal FAILED status, broker still terminates."""
    fleet = resource.make_fleet([4], 100.0, 1.0, TIME_SHARED)
    sc = simulation.Scenario(fault_trace=[(1.0, 0, 0), (2.0, 0, 1)],
                             retry_limit=0)
    r = simulation.run_experiment(_jobs(), fleet, 500.0, 1e9, OPT_COST,
                                  scenario=sc, max_events=4096)
    status = np.asarray(r.gridlets.status)
    assert int(r.n_failed) > 0
    assert (status == FAILED).sum() == int(r.n_failed)
    assert int(r.n_resubmits) == 0
    assert not bool(r.truncated)
    # untouched gridlets still finish
    assert float(r.n_done.sum()) == 12.0 - int(r.n_failed)


def test_backoff_delays_redispatch():
    """Exponential backoff holds failed gridlets out of the dispatch
    pool: a first retry waits exactly backoff_base after the failure
    (retry_at == t_fail + base * 2**0) and nothing re-starts before
    it; without backoff re-dispatch follows recovery immediately."""
    fleet = resource.make_fleet([4], 100.0, 1.0, TIME_SHARED)
    trace = [(1.0, 0, 0), (1.5, 0, 1)]
    base = simulation.run_experiment(
        _jobs(), fleet, 1000.0, 1e9, OPT_COST, max_events=4096,
        scenario=simulation.Scenario(fault_trace=trace))
    backed = simulation.run_experiment(
        _jobs(), fleet, 1000.0, 1e9, OPT_COST, max_events=4096,
        scenario=simulation.Scenario(fault_trace=trace,
                                     backoff_base=100.0))
    assert float(base.n_done.sum()) == 12.0
    assert float(backed.n_done.sum()) == 12.0
    failed = np.asarray(backed.gridlets.n_retries) > 0
    assert failed.sum() == int(backed.n_failed) > 0
    np.testing.assert_allclose(
        np.asarray(backed.gridlets.retry_at)[failed], 1.0 + 100.0)
    # no failed gridlet completes before its retry stamp -- the wait
    # dwarfs the whole no-backoff makespan, so the comparison is
    # unambiguous under time-shared contention effects
    assert np.asarray(base.gridlets.finish).max() < 101.0
    assert np.asarray(backed.gridlets.finish)[failed].min() >= 101.0


def test_blacklist_cooldown_shuns_recovered_resource():
    """A freshly recovered resource is shunned for blacklist_cooldown
    time units: with a single resource the whole farm stalls that long
    before re-dispatch."""
    fleet = resource.make_fleet([4], 100.0, 1.0, TIME_SHARED)
    trace = [(1.0, 0, 0), (2.0, 0, 1)]
    plain = simulation.run_experiment(
        _jobs(), fleet, 1000.0, 1e9, OPT_COST, max_events=4096,
        scenario=simulation.Scenario(fault_trace=trace))
    shunned = simulation.run_experiment(
        _jobs(), fleet, 1000.0, 1e9, OPT_COST, max_events=4096,
        scenario=simulation.Scenario(fault_trace=trace,
                                     blacklist_cooldown=100.0))
    assert float(plain.n_done.sum()) == 12.0
    assert float(shunned.n_done.sum()) == 12.0
    # recovery lands at t=2; the cooldown keeps the only resource off
    # the registry until t=102, which dwarfs the plain makespan -- so
    # every post-failure completion must land after it
    assert np.asarray(plain.gridlets.finish).max() < 102.0
    failed = np.asarray(shunned.gridlets.n_retries) > 0
    assert failed.sum() > 0
    assert np.asarray(shunned.gridlets.finish)[failed].min() >= 102.0


# ----------------------------------------------------------------------
# The frozen default + per-lane diagnostics
# ----------------------------------------------------------------------
def test_default_knobs_bitwise_frozen():
    """A Scenario carrying every new knob at its default value is
    bit-for-bit identical to running with no scenario at all."""
    g, fleet = _jobs(), _fleet3()
    r0 = simulation.run_experiment(g, fleet, 100.0, 1e9, OPT_COST)
    r1 = simulation.run_experiment(
        g, fleet, 100.0, 1e9, OPT_COST,
        scenario=simulation.Scenario(trunk_of=None, fault_trace=None,
                                     retry_limit=None, backoff_base=None,
                                     blacklist_cooldown=None))
    for f in ("spent", "term_time", "n_events", "n_failed", "downtime"):
        assert np.array_equal(np.asarray(getattr(r0, f)),
                              np.asarray(getattr(r1, f))), f
    assert np.array_equal(np.asarray(r0.gridlets.finish),
                          np.asarray(r1.gridlets.finish))


def test_sweep_surfaces_truncated_and_overflow_per_lane():
    """sweep / sweep_sharded expose the truncated and overflow
    diagnostics with full [D, B] lane shape -- and a starved
    max_events trips truncated on every lane, loudly."""
    g, fleet = _jobs(n=6), _fleet3()
    sc = simulation.Scenario(trunk_of=[0, 0, -1],
                             fault_trace=[(1.0, 3, 0), (5.0, 3, 1)])
    ok = simulation.sweep(g, fleet, [50.0, 100.0], [1e9, 1e8], OPT_COST,
                          scenario=sc)
    assert ok.truncated.shape == (2, 2) and ok.overflow.shape == (2, 2)
    assert not np.asarray(ok.truncated).any()
    assert not np.asarray(ok.overflow).any()
    starved = simulation.sweep(g, fleet, [50.0, 100.0], [1e9], OPT_COST,
                               scenario=sc, max_events=6)
    assert starved.truncated.shape == (2, 1)
    assert np.asarray(starved.truncated).all()
    sharded = simulation.sweep_sharded(g, fleet, [50.0, 100.0],
                                       [1e9, 1e8], OPT_COST, scenario=sc)
    assert np.array_equal(np.asarray(sharded.truncated),
                          np.asarray(ok.truncated))
    assert np.array_equal(np.asarray(sharded.overflow),
                          np.asarray(ok.overflow))
    assert np.array_equal(np.asarray(sharded.n_failed),
                          np.asarray(ok.n_failed))
