"""Multi-device distribution tests.

Each test runs in a subprocess that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing
jax, so the main pytest process keeps its single CPU device (smoke tests
and benches must see 1 device).
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Pre-existing seed failures (tracked in CHANGES.md, PR 6): the
# models/dist/train modules use jax.shard_map and
# jax.sharding.get_abstract_mesh, both added after the installed jax
# release.  Every test here drives those modules in a subprocess, so
# they all fail on the missing attributes until jax is upgraded.
pytestmark = pytest.mark.xfail(
    not (hasattr(jax, "shard_map")
         and hasattr(jax.sharding, "get_abstract_mesh")),
    reason="installed jax predates jax.shard_map / "
           "jax.sharding.get_abstract_mesh (pre-existing seed failure)")


def run_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharding_rules_resolve_all_archs():
    out = run_devices("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.models import make
        from repro.dist import sharding as sh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for name in configs.names():
            cfg = configs.get(name)
            api = make(cfg)
            shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            specs = sh.param_specs(shapes, mesh)
            # every spec must be a valid PartitionSpec over mesh axes
            leaves = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))
            assert leaves, name
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import make
        from repro.dist import sharding as sh
        from repro.train import loop, optimizer as opt_mod, data as data_mod

        cfg = configs.SMOKES["qwen2-7b"].scaled(vocab=512)
        api = make(cfg)
        ocfg = opt_mod.AdamWConfig(warmup_steps=1, total_steps=10)
        step = loop.make_train_step(api, ocfg)
        it = data_mod.for_model(cfg, batch=8, seq=16, seed=0)
        batch = next(it)

        # single device reference
        state0 = loop.init_state(api, jax.random.PRNGKey(0), ocfg)
        s1, m1 = jax.jit(step)(state0, batch)

        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        state0 = loop.init_state(api, jax.random.PRNGKey(0), ocfg)
        pspec = sh.param_specs(state0["params"], mesh)
        sspec = {"params": pspec,
                 "opt": {"m": pspec, "v": pspec,
                         "step": jax.sharding.PartitionSpec()}}
        bspec = sh.batch_specs(jax.eval_shape(lambda: batch), mesh)
        st_sh = sh.to_shardings(sspec, mesh)
        b_sh = sh.to_shardings(bspec, mesh)
        state0 = jax.tree_util.tree_map(jax.device_put, state0, st_sh)
        batch_s = jax.tree_util.tree_map(jax.device_put, batch, b_sh)
        with mesh:
            s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))(state0, batch_s)
        # bf16 compute: reduction order differs across shardings
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-3)
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            s1["params"], jax.device_get(s2["params"]))
        assert max(jax.tree_util.tree_leaves(d)) < 5e-3  # ~ lr scale
        print("OK")
    """)
    assert "OK" in out


def test_elastic_remesh_checkpoint_restart(tmp_path):
    ckpt_dir = str(tmp_path)
    out = run_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import make
        from repro.dist import sharding as sh, fault
        from repro.train import (loop, optimizer as opt_mod,
                                 data as data_mod, checkpoint as ckpt)

        cfg = configs.SMOKES["qwen2-7b"].scaled(vocab=512)
        api = make(cfg)
        ocfg = opt_mod.AdamWConfig(warmup_steps=1, total_steps=10)
        step_fn = loop.make_train_step(api, ocfg)
        it = data_mod.for_model(cfg, batch=8, seq=16, seed=0)

        mesh = fault.elastic_mesh(jax.devices(), model_parallel=2)
        assert dict(mesh.shape) == {{"data": 4, "model": 2}}
        state = loop.init_state(api, jax.random.PRNGKey(0), ocfg)
        state = fault.reshard(state, mesh)
        with mesh:
            state, _ = jax.jit(step_fn)(state, next(it))
        ckpt.save({ckpt_dir!r}, 1, state)

        # lose 3 devices -> largest mesh keeping model=2 is 2x2
        mesh2 = fault.elastic_mesh(jax.devices()[:5], model_parallel=2)
        assert dict(mesh2.shape) == {{"data": 2, "model": 2}}
        like = loop.init_state(api, jax.random.PRNGKey(0), ocfg)
        state2 = ckpt.restore({ckpt_dir!r}, 1, like)
        state2 = fault.reshard(state2, mesh2)
        with mesh2:
            state2, m = jax.jit(step_fn)(state2, next(it))
        assert np.isfinite(m["loss"])
        assert int(state2["opt"]["step"]) == 2
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import pipeline

        mesh = jax.make_mesh((4,), ("pp",))
        n_stages, n_micro, width = 4, 6, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, width, width)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        mbs = jax.random.normal(jax.random.PRNGKey(1),
                                (n_micro, 8, width))
        got = pipeline.pipeline_apply(stage_fn, ws, mbs, mesh, "pp")

        want = mbs
        for s in range(n_stages):
            want = jax.vmap(lambda x: stage_fn(ws[s], x))(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

        # and it is differentiable (the backward pipeline)
        def loss(ws):
            return pipeline.pipeline_apply(
                stage_fn, ws, mbs, mesh, "pp").sum()
        g = jax.grad(loss)(ws)
        assert np.isfinite(np.asarray(g)).all() and np.abs(g).sum() > 0
        print("OK")
    """)
    assert "OK" in out


def test_compressed_pod_allreduce():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train import compression as comp

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        g = {"w": jnp.ones((8, 16)) * 0.5, "b": jnp.arange(8.0) * 1e-3}
        out = comp.pod_allreduce_int8(g, mesh)
        # all-reduce of identical replicas == identity (up to int8 quant)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(g["w"]), rtol=2e-2)
        np.testing.assert_allclose(np.asarray(out["b"]),
                                   np.asarray(g["b"]), atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_error_feedback_compression_converges():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train import compression as comp
        # error feedback: sum of sent messages -> sum of true gradients
        key = jax.random.PRNGKey(0)
        gs = [jax.random.normal(jax.random.PRNGKey(i), (64,))
              for i in range(30)]
        ef = {"g": jnp.zeros((64,))}
        sent_total = jnp.zeros((64,))
        for g in gs:
            sent, ef_new = comp.compress({"g": g}, ef, method="topk",
                                         k_frac=0.1)
            ef = ef_new
            sent_total = sent_total + sent["g"]
        true_total = sum(gs)
        resid = jnp.linalg.norm(sent_total - true_total)
        assert float(resid) == float(jnp.linalg.norm(ef["g"]))
        assert float(resid) < float(jnp.linalg.norm(true_total))
        print("OK")
    """, n=1)
    assert "OK" in out
