"""Property tests for the economic invariants of the broker suite.

The paper's economy only makes sense if four properties hold on every
execution path, under every strategy and pricing model:

  * a user's ``spent`` never exceeds its ``budget`` -- including the
    failure refund/resubmit cycle, where committed cost is returned and
    re-committed at (possibly repriced) dispatch,
  * an inactive broker (deadline passed, or the cheapest possible
    purchase no longer fits the remaining budget) dispatches nothing,
  * auction rounds are deterministic given the scenario seed (bitwise
    replay) and actually draw different prices under different seeds,
  * repriced costs stay positive, finite and inside the
    ``[floor, cap] * base`` clamp for any demand history.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import des, economy, engine, gridlet, resource, \
    simulation, types

MAX_EVENTS = 4096


def _run(sc, opt=types.OPT_COST, deadline=500.0, budget=20_000.0,
         n_jobs=8, n_users=2, seed=0):
    fleet = resource.make_fleet([2, 4], [300.0, 500.0], [2.0, 5.0],
                                [types.TIME_SHARED, types.SPACE_SHARED])
    g = gridlet.task_farm(jax.random.PRNGKey(seed), n_jobs=n_jobs,
                          n_users=n_users)
    params = simulation._scenario_params(fleet, deadline, budget, opt,
                                         n_users, sc)
    res = engine.run(g, fleet, params, n_users, MAX_EVENTS, batch=1)
    assert int(res.n_steps) + int(res.n_spec) < MAX_EVENTS
    return res, params


SCENARIOS = [
    ("static", None),
    ("commodity", simulation.Scenario(pricing_model="commodity",
                                      market_period=25.0,
                                      market_gain=0.5)),
    ("auction", simulation.Scenario(pricing_model="auction",
                                    auction_period=25.0, seed=3)),
    ("plan+failures", simulation.Scenario(plan_ahead=True, mtbf=150.0,
                                          mttr=20.0, seed=11)),
    ("auction+failures", simulation.Scenario(pricing_model="auction",
                                             auction_period=30.0,
                                             mtbf=120.0, mttr=15.0,
                                             seed=7)),
]


@pytest.mark.parametrize("tag,sc", SCENARIOS)
@pytest.mark.parametrize("opt", [types.OPT_COST, types.OPT_TIME,
                                 types.OPT_COST_TIME, types.OPT_NONE])
def test_spent_never_exceeds_budget(tag, sc, opt):
    """Dispatch commits exact cost against the remaining budget, and a
    failure refund can only lower ``spent`` -- so it never crosses the
    budget, on tight budgets and through refund/resubmit cycles."""
    for budget in (300.0, 2_000.0, 20_000.0):
        res, params = _run(sc, opt=opt, budget=budget)
        spent = np.asarray(res.spent)
        assert np.all(np.isfinite(spent)) and np.all(spent >= 0.0)
        assert np.all(spent <= np.asarray(params.budget)), \
            f"{tag}/opt={opt}/budget={budget}: overspent {spent}"


@pytest.mark.parametrize("tag,sc", SCENARIOS)
def test_inactive_broker_dispatches_nothing(tag, sc):
    """deadline <= 0 (never active) and budget == 0 (nothing
    affordable): every gridlet stays CREATED and nothing is billed."""
    for deadline, budget in ((0.0, 20_000.0), (500.0, 0.0)):
        res, _ = _run(sc, deadline=deadline, budget=budget)
        assert np.all(np.asarray(res.gridlets.status) == types.CREATED)
        assert np.all(np.asarray(res.spent) == 0.0)


def test_auction_rounds_deterministic_given_seed():
    """Same scenario seed -> bitwise-identical replay (including every
    auction draw); a different auction_seed moves the posted prices and
    hence the spend under cost optimisation."""
    sc = simulation.Scenario(pricing_model="auction", auction_period=20.0,
                            seed=4)
    a, _ = _run(sc, opt=types.OPT_COST)
    b, _ = _run(sc, opt=types.OPT_COST)
    kinds = np.asarray(a.trace[1])
    assert (kinds == des.K_AUCTION).sum() >= 1, "no auction round fired"
    for f in ("spent", "term_time", "n_events"):
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
    for i in range(3):
        assert np.array_equal(np.asarray(a.trace[i]),
                              np.asarray(b.trace[i]))
    c, _ = _run(sc._replace(auction_seed=99), opt=types.OPT_COST)
    assert not np.array_equal(np.asarray(a.gridlets.cost),
                              np.asarray(c.gridlets.cost)), \
        "different auction seed left every dispatch cost untouched"


def test_repriced_costs_stay_positive_finite_and_clamped():
    """Iterating the commodity adjustment over random demand histories
    keeps the posted price inside [floor, cap] * base -- positive and
    finite by construction; the auction draw lands in the same box."""
    rng = np.random.RandomState(0)
    base = jnp.asarray([0.004, 0.01, 2.5], jnp.float32)   # G$/MI
    floor, cap, gain = 0.5, 2.0, 0.25
    lo, hi = np.asarray(base * floor), np.asarray(base * cap)
    price = base
    for _ in range(200):
        demand = jnp.asarray(rng.uniform(0.0, 8.0, 3), jnp.float32)
        price = economy.commodity_reprice(price, base, demand, gain,
                                          floor, cap)
        p = np.asarray(price)
        assert np.all(np.isfinite(p)) and np.all(p > 0.0)
        assert np.all(p >= lo) and np.all(p <= hi)
    for s in range(20):
        p = np.asarray(economy.auction_round(jax.random.PRNGKey(s), base,
                                             floor, cap))
        assert np.all(np.isfinite(p)) and np.all(p > 0.0)
        assert np.all(p >= lo) and np.all(p <= hi)


def test_golden_auction_trace_pinned_across_batch():
    """The committed golden_auction.json scenario replays bitwise --
    times, kinds, actors, spend, termination -- at batch=1 AND the
    default batch, pinning the auction source's event ordering, PRNG
    stream and price-driven dispatch decisions (regenerate with
    tests/data/gen_golden_auction.py)."""
    import json
    import os
    with open(os.path.join(os.path.dirname(__file__), "data",
                           "golden_auction.json")) as f:
        gold = json.load(f)
    fleet = resource.make_fleet([2, 4], [300.0, 500.0], [2.0, 5.0],
                                [types.TIME_SHARED, types.SPACE_SHARED])
    g = gridlet.task_farm(jax.random.PRNGKey(6), n_jobs=10, n_users=2)
    sc = simulation.Scenario(pricing_model="auction", auction_period=15.0,
                             seed=8)
    params = simulation._scenario_params(fleet, 400.0, 20_000.0,
                                         types.OPT_COST, 2, sc)
    max_jobs = simulation.safe_max_jobs(g, params, fleet)
    assert np.asarray(gold["trace_kind"]).tolist().count(
        des.K_AUCTION) >= 3
    for batch in (1, None):
        kw = {} if batch is None else dict(batch=batch)
        r = engine.run(g, fleet, params, 2, 4096, max_jobs=max_jobs,
                       **kw)
        tt, kind, who = (np.asarray(x) for x in r.trace)
        m = kind >= 0
        assert np.array_equal(tt[m],
                              np.asarray(gold["trace_t"], np.float32))
        assert np.array_equal(kind[m], np.asarray(gold["trace_kind"]))
        assert np.array_equal(who[m], np.asarray(gold["trace_who"]))
        assert np.array_equal(np.asarray(r.gridlets.returned),
                              np.asarray(gold["returned"], np.float32))
        assert np.array_equal(np.asarray(r.spent),
                              np.asarray(gold["spent"], np.float32))
        assert np.array_equal(np.asarray(r.term_time),
                              np.asarray(gold["term_time"], np.float32))
        assert int(np.asarray(r.n_events)) == gold["n_events"]
        assert int(np.asarray(r.overflow)) == gold["overflow"]
        assert int((np.asarray(r.gridlets.status)
                    == types.DONE).sum()) == gold["n_done"]


def test_engine_prices_stay_clamped_under_pricing():
    """End-to-end: drive the real engine sources over many rounds and
    check the carried posted price never leaves the clamp box."""
    fleet = resource.make_fleet([2, 4], [300.0, 500.0], [2.0, 5.0],
                                [types.TIME_SHARED, types.SPACE_SHARED])
    g = gridlet.task_farm(jax.random.PRNGKey(1), n_jobs=6, n_users=2)
    for model in ("commodity", "auction"):
        params = simulation._scenario_params(
            fleet, 500.0, 20_000.0, types.OPT_COST, 2,
            simulation.Scenario(pricing_model=model, market_period=10.0,
                                auction_period=10.0, seed=2))
        state = engine.init_state(g, fleet, 2, params=params)
        sources = engine._make_sources(fleet, params, 2,
                                       {"select_free": True})
        pos = {s.kind: i for i, s in enumerate(sources)}
        kind = des.K_MARKET if model == "commodity" else des.K_AUCTION
        src = sources[pos[kind]]
        base = np.asarray(fleet.cost_per_mi(), np.float32)
        lo = base * float(params.price_floor)
        hi = base * float(params.price_cap)
        now = 10.0
        for _ in range(50):
            state = src.apply(state, jnp.asarray(now, jnp.float32))
            p = np.asarray(state.price)
            assert np.all(np.isfinite(p)) and np.all(p > 0.0)
            assert np.all(p >= lo) and np.all(p <= hi)
            now += 10.0
