"""Engine invariants: Fig 8 share algebra, queueing, network, conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import engine, gridlet, network, resource, types
from repro.core.types import replace


# ----------------------------------------------------------------------
# Fig 8 PE-share allocation, probed through the private _rates helper.
# ----------------------------------------------------------------------
def _rates_for(n_jobs, num_pe, mips=1.0):
    g = gridlet.make_batch(jnp.full((n_jobs,), 100.0))
    g = replace(g, status=jnp.full((n_jobs,), types.RUNNING, jnp.int32),
                resource=jnp.zeros((n_jobs,), jnp.int32),
                remaining=jnp.arange(1, n_jobs + 1, dtype=jnp.float32))
    fleet = resource.make_fleet([num_pe], mips, 1.0, types.TIME_SHARED)
    st_ = engine.init_state(g, fleet, 1)
    st_ = replace(st_, g=g)
    return np.asarray(engine._rates(st_, fleet, 1))


@settings(max_examples=30, deadline=None)
@given(n_jobs=st.integers(1, 17), num_pe=st.integers(1, 8))
def test_fig8_share_conservation(n_jobs, num_pe):
    """Total allocated rate == min(jobs, PEs) * MIPS; every job > 0."""
    rates = _rates_for(n_jobs, num_pe)
    assert np.all(rates > 0)
    np.testing.assert_allclose(rates.sum(), min(n_jobs, num_pe),
                               rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(n_jobs=st.integers(2, 17), num_pe=st.integers(1, 8))
def test_fig8_max_min_share(n_jobs, num_pe):
    """Only two share levels exist: eff/k and eff/(k+1), k=floor(g/P);
    smallest-remaining jobs receive the larger share."""
    rates = _rates_for(n_jobs, num_pe)
    if n_jobs <= num_pe:
        np.testing.assert_allclose(rates, 1.0)
        return
    k = n_jobs // num_pe
    uniq = np.unique(np.round(rates, 6))
    expected = np.array([1.0 / k, 1.0 / (k + 1)], np.float32)
    assert all(np.isclose(u, expected, atol=1e-5).any() for u in uniq)
    # remaining was arange(1..n): rates must be non-increasing in remaining
    assert np.all(np.diff(rates) <= 1e-9)


def test_space_shared_sjf_order():
    """SJF admits the shortest queued job first."""
    g = gridlet.make_batch([10.0, 9.0, 2.0])  # all arrive together
    fleet = resource.make_fleet([1], 1.0, 1.0, types.SPACE_SHARED,
                                queue_policy=types.SJF)
    res = engine.run_direct(g, fleet, 0, jnp.zeros(3), max_events=64)
    # G1 runs 0-10 (first arrival wins the free PE), then G3 (2 MI), G2.
    np.testing.assert_allclose(res.gridlets.finish, [10.0, 21.0, 12.0])


def test_space_shared_fcfs_order():
    g = gridlet.make_batch([10.0, 9.0, 2.0])
    fleet = resource.make_fleet([1], 1.0, 1.0, types.SPACE_SHARED,
                                queue_policy=types.FCFS)
    res = engine.run_direct(g, fleet, 0, jnp.array([0.0, 1.0, 2.0]),
                            max_events=64)
    np.testing.assert_allclose(res.gridlets.finish, [10.0, 19.0, 21.0])


def test_network_delay_shifts_schedule():
    """Input transfer delays arrival; output transfer delays return."""
    g = gridlet.make_batch([10.0], in_bytes=[100.0], out_bytes=[50.0])
    fleet = resource.make_fleet([1], 1.0, 1.0, types.TIME_SHARED,
                                baud_rate=10.0)
    res = engine.run_direct(g, fleet, 0, jnp.zeros(1), max_events=32)
    assert float(res.gridlets.start[0]) == pytest.approx(10.0)   # 100/10
    assert float(res.gridlets.finish[0]) == pytest.approx(20.0)
    assert float(res.gridlets.returned[0]) == pytest.approx(25.0)  # +50/10


@settings(max_examples=15, deadline=None)
@given(
    lengths=st.lists(st.floats(1.0, 50.0), min_size=1, max_size=9),
    num_pe=st.integers(1, 3),
    policy=st.sampled_from([types.TIME_SHARED, types.SPACE_SHARED]),
)
def test_conservation_and_makespan(lengths, num_pe, policy):
    """All jobs finish; makespan is bounded below by work/capacity and
    above by serial execution (property over random job sets)."""
    g = gridlet.make_batch(jnp.asarray(lengths, jnp.float32))
    fleet = resource.make_fleet([num_pe], 1.0, 1.0, policy)
    res = engine.run_direct(g, fleet, 0, jnp.zeros(len(lengths)),
                            max_events=16 * len(lengths) + 32)
    assert np.all(np.asarray(res.gridlets.status) == types.DONE)
    makespan = float(np.max(res.gridlets.finish))
    total = float(sum(lengths))
    assert makespan >= total / num_pe - 1e-3
    assert makespan <= total + 1e-3
    # every finish >= its own length / full speed
    assert np.all(np.asarray(res.gridlets.finish) >=
                  np.asarray(lengths) - 1e-3)


def test_effective_mips_under_load():
    fleet = resource.make_fleet([2], 100.0, 1.0, types.TIME_SHARED,
                                base_load=0.5)
    g = gridlet.make_batch([100.0])
    res = engine.run_direct(g, fleet, 0, jnp.zeros(1), max_events=32)
    # 100 MI at 100*(1-0.5) = 50 MIPS -> 2 time units.
    assert float(res.gridlets.finish[0]) == pytest.approx(2.0)
