"""The HLO analyzer behind the roofline (launch/hlo.py): trip-count
weighting, collective ring-model bytes, dot-FLOP extraction."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch import hlo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def compile_text(code: str, devices: int = 4) -> str:
    """Compile a jitted fn in a subprocess (fresh device count), return
    its HLO text."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_weighted_flops_multiply_scan_trip_counts():
    text = compile_text("""
        import jax, jax.numpy as jnp
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
        print(c.as_text())
    """)
    w = hlo.weighted_cost(text)
    # 7 iterations x 2*8*16*16 flops; cost_analysis would report 1x.
    assert w["dot_flops"] == pytest.approx(7 * 2 * 8 * 16 * 16)
    assert w["hbm_bytes"] > 0


def test_collective_bytes_all_reduce_ring_model():
    text = compile_text("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = jax.make_mesh((4,), ("d",))
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32,
                                 sharding=NamedSharding(mesh, P("d")))
        w = jax.ShapeDtypeStruct((128, 32), jnp.float32,
                                 sharding=NamedSharding(mesh, P("d")))
        # contraction over the sharded dim of w vs batch-sharded x
        # forces a cross-device reduction of the [64, 32] result.
        def f(x, w):
            return jax.lax.with_sharding_constraint(x @ w, P())
        with mesh:
            c = jax.jit(f).lower(x, w).compile()
        print(c.as_text())
    """)
    coll = hlo.collective_bytes(text, 4)
    assert sum(coll.values()) > 0
    # XLA gathers both sharded operands: ring all-gather moves
    # full_bytes * (g-1)/g per chip for each.
    expect = (64 * 128 * 4 + 128 * 32 * 4) * 3 / 4
    assert coll.get("all-gather", 0.0) == pytest.approx(expect), coll


def test_shape_bytes_and_group_parsing():
    assert hlo._shape_bytes("bf16[4,8]{1,0}") == 64
    assert hlo._shape_bytes("(f32[2,2]{1,0}, s8[16]{0})") == 32
    assert hlo._shape_bytes("pred[]") == 1
    line_explicit = "x = f32[2] all-reduce(%a), replica_groups={{0,1},{2,3}}"
    assert hlo._group_size(line_explicit, 8) == 2
    line_iota = "x = f32[2] all-reduce(%a), replica_groups=[4,2]<=[8]"
    assert hlo._group_size(line_iota, 8) == 2
    assert hlo._group_size("x = f32[2] all-reduce(%a)", 8) == 8


def test_trip_count_extraction():
    cond = ["%c = s32[] constant(23)",
            "ROOT %lt = pred[] compare(%i, %c), direction=LT"]
    assert hlo._trip_count(cond) == 23
    assert hlo._trip_count([]) == 1


def test_top_collectives_reports_weighted_sites():
    text = compile_text("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = jax.make_mesh((4,), ("d",))
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, "d")))
        def f(x):
            def body(c, _):
                s = jax.lax.with_sharding_constraint(
                    jnp.sum(c, keepdims=True, axis=1), P())
                return c * 0.9 + s * 0.01, None
            y, _ = jax.lax.scan(body, x, None, length=5)
            return y
        with mesh:
            c = jax.jit(f).lower(x).compile()
        print(c.as_text())
    """)
    rows = hlo.top_collectives(text, 4, k=5)
    if rows:  # a reduction inside a x5 loop must be weighted by 5
        assert any(r[3] >= 5 for r in rows), rows
