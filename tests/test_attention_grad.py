"""Flash-attention custom VJP vs autodiff of the naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as am


def naive(q, k, v, causal, window, cap):
    b, sq, h, g, d = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * d ** -0.5
    if cap:
        s = jnp.tanh(s / cap) * cap
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


@pytest.mark.xfail(
    not hasattr(jax.sharding, "get_abstract_mesh"),
    reason="pre-existing seed failure (tracked in CHANGES.md, PR 6): "
           "models/common.py uses jax.sharding.get_abstract_mesh, "
           "added after the installed jax release",
    raises=AttributeError)
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 8, 0.0), (True, 0, 30.0),
    (False, 0, 0.0), (True, 8, 30.0),
])
def test_flash_vjp_matches_naive_autodiff(causal, window, cap):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 40, 2, 3, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 40, 2, 8))
    ct = jax.random.normal(jax.random.PRNGKey(3), (2, 40, 2, 3, 8))

    def f1(q, k, v):
        return (am.attend_chunked(q, k, v, causal=causal, window=window,
                                  cap=cap, q_block=16, kv_block=8)
                * ct).sum()

    def f2(q, k, v):
        return (naive(q, k, v, causal, window, cap) * ct).sum()

    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_ssd_grad_matches_recurrence_autodiff():
    from repro.models import mamba2 as mm
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    bs, s, h, p, n = 2, 24, 3, 4, 6
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_mat = jax.random.normal(ks[3], (bs, s, n))
    c_mat = jax.random.normal(ks[4], (bs, s, n))

    def naive_ssd(x, dt, a, b_mat, c_mat):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            g = jnp.exp(dtt * a)
            state = state * g[..., None, None] + jnp.einsum(
                "bn,bh,bhp->bhpn", bt, dtt, xt)
            return state, jnp.einsum("bn,bhpn->bhp", ct, state)
        init = jnp.zeros((bs, h, p, n))
        _, ys = jax.lax.scan(step, init,
                             tuple(jnp.moveaxis(t, 1, 0)
                                   for t in (x, dt, b_mat, c_mat)))
        return jnp.moveaxis(ys, 0, 1)

    ct = jax.random.normal(jax.random.PRNGKey(9), (bs, s, h, p))
    f1 = lambda *args: (mm.ssd_chunked(*args, chunk=8) * ct).sum()
    f2 = lambda *args: (naive_ssd(*args) * ct).sum()
    g1 = jax.grad(f1, (0, 1, 2, 3, 4))(x, dt, a, b_mat, c_mat)
    g2 = jax.grad(f2, (0, 1, 2, 3, 4))(x, dt, a, b_mat, c_mat)
    for a_, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)
