"""Training loop, checkpointing, data pipeline and serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import make
from repro.serve.engine import Request, Server
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import data as data_mod
from repro.train import loop, optimizer as opt_mod

CFG = configs.SMOKES["qwen2-7b"].scaled(d_model=64, d_ff=256, vocab=512,
                                        n_layers=2)

# Pre-existing seed failures (tracked in CHANGES.md, PR 6): any test
# that runs a model forward pass hits models/common.py's
# jax.sharding.get_abstract_mesh, added after the installed jax
# release.  The checkpoint/data/optimizer/compression tests below
# don't touch the model and stay live.
needs_model_forward = pytest.mark.xfail(
    not hasattr(jax.sharding, "get_abstract_mesh"),
    reason="installed jax predates jax.sharding.get_abstract_mesh "
           "(pre-existing seed failure)")


@needs_model_forward
def test_fit_decreases_loss_and_checkpoints(tmp_path):
    api = make(CFG)
    it = data_mod.for_model(CFG, batch=4, seq=32, seed=0)
    ocfg = opt_mod.AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=30)
    out = loop.fit(api, it, ocfg, steps=25, ckpt_dir=str(tmp_path),
                   ckpt_every=10, log_every=0)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]
    assert ckpt.latest_step(str(tmp_path)) == 25


@needs_model_forward
def test_fit_restart_resumes(tmp_path):
    api = make(CFG)
    ocfg = opt_mod.AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=30)
    it = data_mod.for_model(CFG, batch=4, seq=32, seed=0)
    loop.fit(api, it, ocfg, steps=10, ckpt_dir=str(tmp_path),
             ckpt_every=5, log_every=0)
    # a "crashed and restarted" run continues from step 10, not 0
    it2 = data_mod.for_model(CFG, batch=4, seq=32, seed=0)
    out = loop.fit(api, it2, ocfg, steps=12, ckpt_dir=str(tmp_path),
                   ckpt_every=5, log_every=0)
    assert int(out["state"]["opt"]["step"]) == 12
    assert len(out["history"]) == 2  # only steps 11-12 re-ran


def test_checkpoint_atomic_and_gc(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, tree, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    back = ckpt.restore(str(tmp_path), 4, tree)
    np.testing.assert_allclose(back["a"], tree["a"])
    # stale tmp dirs from "crashes" are cleaned on the next save
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp.dead"))
    ckpt.save(str(tmp_path), 5, tree, keep=2)
    assert not any(".tmp." in n for n in os.listdir(str(tmp_path)))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.ones((5,))})


def test_data_pipeline_deterministic_and_rank_disjoint():
    d0 = data_mod.SyntheticLM(512, 8, 16, seed=1, rank=0, world=2)
    d0b = data_mod.SyntheticLM(512, 8, 16, seed=1, rank=0, world=2)
    d1 = data_mod.SyntheticLM(512, 8, 16, seed=1, rank=1, world=2)
    b0, b0b, b1 = d0.batch_at(5), d0b.batch_at(5), d1.batch_at(5)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(d0.batch_at(0)["tokens"])[:, 1:],
        np.asarray(d0.batch_at(0)["targets"])[:, :-1])


def test_optimizer_schedule_and_clipping():
    ocfg = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                               clip_norm=1.0, weight_decay=0.0)
    assert float(opt_mod.schedule(ocfg, jnp.asarray(5))) == \
        pytest.approx(0.5, rel=1e-3)
    params = {"w": jnp.zeros((4,))}
    opt = opt_mod.init(params)
    big = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = opt_mod.update(ocfg, big, opt, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_compression_ratios():
    assert comp.compression_ratio("int8") == pytest.approx(0.25)
    assert comp.compression_ratio("topk", k_frac=0.01) < 0.03
    assert comp.compression_ratio("none") == 1.0


@needs_model_forward
def test_server_continuous_batching():
    cfg = CFG
    api = make(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, slots=2, max_len=48)
    for rid in range(5):
        srv.submit(Request(rid=rid, prompt=[3, 5, 7 + rid],
                           max_new_tokens=4))
    done = srv.run_until_done(max_steps=100)
    assert len(done) == 5
    assert all(len(r.generated) >= 4 for r in done)
    # with only 2 slots, requests were necessarily queued then admitted
    assert not srv.active and not srv.queue


@needs_model_forward
def test_server_greedy_matches_manual_decode():
    cfg = CFG
    api = make(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = [3, 5, 7]
    srv = Server(api, params, slots=1, max_len=32)
    srv.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    done = srv.run_until_done(max_steps=50)[0]

    # manual greedy reference
    cache = api.init_cache(1, 32, dtype=jnp.float32)
    lg, cache = api.prefill(params, {
        "tokens": jnp.asarray([prompt]), "cache": cache})
    toks = [int(jnp.argmax(lg[0, -1]))]
    pos = len(prompt)
    for _ in range(2):
        lg, cache = api.decode(params, cache, {
            "tokens": jnp.asarray([[toks[-1]]]),
            "cache_index": jnp.asarray(pos)})
        toks.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    assert done.generated == toks
