"""Shared test config: make the tests directory importable so the
``_hypothesis_fallback`` shim resolves regardless of pytest rootdir."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
