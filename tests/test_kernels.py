"""Pallas kernels vs ref.py oracles (interpret mode on CPU).

Per the brief: sweep shapes/dtypes per kernel; property tests via
hypothesis on the system invariants (softmax normalisation, state decay).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.core import engine, gridlet, resource, types
from repro.core.types import replace as treplace


# ------------------------------------------------------------------
# flash attention
# ------------------------------------------------------------------
FLASH_SHAPES = [
    # (b, hq, hkv, sq, d, causal, window, cap)
    (1, 2, 2, 64, 16, True, 0, 0.0),
    (2, 4, 2, 128, 32, True, 0, 0.0),
    (2, 4, 1, 128, 32, True, 32, 0.0),      # GQA + window
    (1, 8, 8, 256, 64, True, 0, 50.0),      # softcap
    (1, 2, 2, 64, 16, False, 0, 0.0),       # bidirectional
    (2, 6, 2, 96, 16, True, 16, 30.0),      # everything at once
]


@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window,cap", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, window,
                                     cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + s), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              cap=cap, block_q=32, block_kv=32,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_lowers_for_tpu_shapes():
    """The kernel must at least trace/lower with production block sizes."""
    q = jax.ShapeDtypeStruct((1, 8, 2048, 128), jnp.bfloat16)
    k = jax.ShapeDtypeStruct((1, 2, 2048, 128), jnp.bfloat16)
    v = jax.ShapeDtypeStruct((1, 2, 2048, 128), jnp.bfloat16)
    jax.eval_shape(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, interpret=True), q, k, v)


# ------------------------------------------------------------------
# SSD scan
# ------------------------------------------------------------------
SSD_SHAPES = [
    # (b, s, h, p, n, chunk, block_h)
    (1, 32, 4, 8, 16, 8, 4),
    (2, 64, 8, 16, 32, 16, 4),
    (1, 128, 8, 32, 64, 32, 8),
    (2, 48, 2, 8, 8, 16, 2),
]


@pytest.mark.parametrize("b,s,h,p,n,chunk,bh", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(b, s, h, p, n, chunk, bh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(
        jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    cm = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    got = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, block_h=bh,
                       interpret=True)
    want = ref.ssd_ref(x, dt, a, bm, cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 64]), h=st.sampled_from([2, 4]),
       seed=st.integers(0, 99))
def test_ssd_scan_property_decay_bounds(s, h, seed):
    """With x == 0 the output is 0 (pure decay); states never blow up."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b, p, n = 1, 8, 8
    x = jnp.zeros((b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[0], (b, s, n))
    y = ops.ssd_scan(x, dt, a, bm, cm, chunk=8, block_h=2,
                     interpret=True)
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


# ------------------------------------------------------------------
# event scan (paper Fig 8)
# ------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    r=st.sampled_from([8, 16]),
    j=st.sampled_from([8, 32]),
    seed=st.integers(0, 999),
)
def test_event_scan_matches_ref(r, j, seed):
    rng = np.random.RandomState(seed)
    remaining = rng.exponential(50.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.4] = 0.0   # empty slots
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    rate, tmin, amin, occ = ops.event_scan(
        jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes),
        interpret=True)
    rate_ref, tmin_ref, amin_ref, occ_ref = ref.event_scan_ref(
        remaining, mips, pes)
    np.testing.assert_allclose(np.asarray(rate), np.asarray(rate_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(tmin), np.asarray(tmin_ref),
                               rtol=1e-4)
    assert np.array_equal(np.asarray(occ), np.asarray(occ_ref))
    # argmin cols must agree wherever the row forecast is unambiguous
    # at f32 resolution (the oracle ranks in f64).
    np.testing.assert_allclose(np.asarray(amin), np.asarray(amin_ref))


def test_event_scan_matches_engine_rates():
    """The kernel, its oracle and the engine's XLA path must agree."""
    n_jobs, num_pe = 7, 2
    g = gridlet.make_batch(jnp.full((n_jobs,), 100.0))
    g = treplace(g, status=jnp.full((n_jobs,), types.RUNNING, jnp.int32),
                 resource=jnp.zeros((n_jobs,), jnp.int32),
                 remaining=jnp.arange(1.0, n_jobs + 1.0))
    fleet = resource.make_fleet([num_pe], 3.0, 1.0, types.TIME_SHARED)
    st_ = engine.init_state(g, fleet, 1)
    st_ = treplace(st_, g=g)
    engine_rates = np.asarray(engine._rates(st_, fleet, 1))

    remaining = jnp.arange(1.0, n_jobs + 1.0).reshape(1, n_jobs)
    remaining = jnp.pad(remaining, ((0, 7), (0, 0)))  # block_r alignment
    rate, tmin, _, _ = ops.event_scan(remaining, jnp.full((8,), 3.0),
                                      jnp.full((8,), num_pe, jnp.int32),
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(rate)[0], engine_rates,
                               rtol=1e-5)
    assert float(tmin[0]) == pytest.approx(
        float((jnp.arange(1.0, n_jobs + 1.0) / engine_rates).min()))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999))
def test_event_scan_capacity_conservation(seed):
    """Fig 8 invariant: allocated rate sums to min(jobs, PEs) * mips."""
    rng = np.random.RandomState(seed)
    r, j = 8, 16
    remaining = rng.exponential(10.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.5] = 0.0
    mips = rng.uniform(1.0, 10.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 5, (r,)).astype(np.int32)
    rate, _, _, _ = ops.event_scan(jnp.asarray(remaining),
                                   jnp.asarray(mips), jnp.asarray(pes),
                                   interpret=True)
    jobs = (remaining > 0).sum(axis=1)
    expect = np.minimum(jobs, pes) * mips
    np.testing.assert_allclose(np.asarray(rate).sum(axis=1), expect,
                               rtol=1e-4)


# ------------------------------------------------------------------
# event scan slab (k-wave completion forecast, one fused call)
# ------------------------------------------------------------------
def _random_slab_case(seed, r=8, j=12):
    rng = np.random.RandomState(seed)
    remaining = rng.exponential(50.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.3] = 0.0
    if seed % 2:  # integer remainings force ties within and across rows
        remaining = np.where(
            remaining > 0, rng.randint(1, 5, (r, j)).astype(np.float32),
            0.0)
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    kw = dict(tie=rng.permutation(r * j).reshape(r, j).astype(np.float32),
              policy=rng.randint(0, 2, (r,)).astype(np.int32),
              pe_blocked=rng.randint(0, 4, (r,)).astype(np.float32),
              row_ok=(rng.rand(r) < 0.8).astype(np.float32))
    return remaining, mips, pes, kw


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 999), k=st.sampled_from([1, 4, 6]))
def test_event_scan_slab_paths_agree(seed, k):
    """Pallas interpret, the XLA fallback and the iterated-single-scan
    oracle agree on the k-wave forecast, masks and tie keys included."""
    remaining, mips, pes, kw = _random_slab_case(seed)
    jkw = {a: jnp.asarray(v) for a, v in kw.items()}
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    pallas_out = ops.event_scan_slab(*args, k, **jkw, interpret=True)
    xla_out = ops.event_scan_slab(*args, k, **jkw)
    ref_out = ref.event_scan_slab_ref(remaining, mips, pes, k, **kw)
    for got, name in ((xla_out, "xla"), (ref_out, "oracle")):
        np.testing.assert_allclose(
            np.asarray(pallas_out[0]), np.asarray(got[0]), rtol=2e-3,
            atol=1e-3, err_msg=f"t_wave vs {name}")
        assert np.array_equal(np.asarray(pallas_out[1]),
                              np.asarray(got[1])), f"col_wave vs {name}"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 999))
def test_event_scan_slab_wave0_is_event_scan(seed):
    """Wave 0 of the slab is exactly the single scan's forecast -- the
    slab is a strict generalisation of event_scan."""
    remaining, mips, pes, kw = _random_slab_case(seed)
    jkw = {a: jnp.asarray(v) for a, v in kw.items()}
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    t_w, col_w = ops.event_scan_slab(*args, 3, **jkw)
    _, tmin, amin, _ = ops.event_scan(*args, **jkw)
    np.testing.assert_allclose(np.asarray(t_w[:, 0]), np.asarray(tmin),
                               rtol=1e-5)
    assert np.array_equal(np.asarray(col_w[:, 0]), np.asarray(amin))
    # waves are non-decreasing in time per row (BIG pads stay last)
    tw = np.asarray(t_w)
    assert np.all(np.diff(tw, axis=1) >= -1e-3)


def test_event_scan_slab_lowers_for_tpu_shapes():
    """The slab kernel must trace/lower at fleet scale (R=256, J=128,
    k=8) -- the TPU-target workload of the batched superstep engine."""
    r, j = 256, 128
    rem = jax.ShapeDtypeStruct((r, j), jnp.float32)
    v = jax.ShapeDtypeStruct((r,), jnp.float32)
    jax.eval_shape(lambda a, m, p: ops.event_scan_slab(
        a, m, p, 8, interpret=True), rem, v, v)
