"""Pallas kernels vs ref.py oracles (interpret mode on CPU).

Per the brief: sweep shapes/dtypes per kernel; property tests via
hypothesis on the system invariants (softmax normalisation, state decay).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.core import engine, gridlet, resource, types
from repro.core.types import replace as treplace


# ------------------------------------------------------------------
# flash attention
# ------------------------------------------------------------------
FLASH_SHAPES = [
    # (b, hq, hkv, sq, d, causal, window, cap)
    (1, 2, 2, 64, 16, True, 0, 0.0),
    (2, 4, 2, 128, 32, True, 0, 0.0),
    (2, 4, 1, 128, 32, True, 32, 0.0),      # GQA + window
    (1, 8, 8, 256, 64, True, 0, 50.0),      # softcap
    (1, 2, 2, 64, 16, False, 0, 0.0),       # bidirectional
    (2, 6, 2, 96, 16, True, 16, 30.0),      # everything at once
]


@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window,cap", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, window,
                                     cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + s), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              cap=cap, block_q=32, block_kv=32,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_lowers_for_tpu_shapes():
    """The kernel must at least trace/lower with production block sizes."""
    q = jax.ShapeDtypeStruct((1, 8, 2048, 128), jnp.bfloat16)
    k = jax.ShapeDtypeStruct((1, 2, 2048, 128), jnp.bfloat16)
    v = jax.ShapeDtypeStruct((1, 2, 2048, 128), jnp.bfloat16)
    jax.eval_shape(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, interpret=True), q, k, v)


# ------------------------------------------------------------------
# SSD scan
# ------------------------------------------------------------------
SSD_SHAPES = [
    # (b, s, h, p, n, chunk, block_h)
    (1, 32, 4, 8, 16, 8, 4),
    (2, 64, 8, 16, 32, 16, 4),
    (1, 128, 8, 32, 64, 32, 8),
    (2, 48, 2, 8, 8, 16, 2),
]


@pytest.mark.parametrize("b,s,h,p,n,chunk,bh", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(b, s, h, p, n, chunk, bh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(
        jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    cm = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    got = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, block_h=bh,
                       interpret=True)
    want = ref.ssd_ref(x, dt, a, bm, cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 64]), h=st.sampled_from([2, 4]),
       seed=st.integers(0, 99))
def test_ssd_scan_property_decay_bounds(s, h, seed):
    """With x == 0 the output is 0 (pure decay); states never blow up."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b, p, n = 1, 8, 8
    x = jnp.zeros((b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[0], (b, s, n))
    y = ops.ssd_scan(x, dt, a, bm, cm, chunk=8, block_h=2,
                     interpret=True)
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


# ------------------------------------------------------------------
# event scan (paper Fig 8)
# ------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    r=st.sampled_from([8, 16]),
    j=st.sampled_from([8, 32]),
    seed=st.integers(0, 999),
)
def test_event_scan_matches_ref(r, j, seed):
    rng = np.random.RandomState(seed)
    remaining = rng.exponential(50.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.4] = 0.0   # empty slots
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    rate, tmin, amin, occ = ops.event_scan(
        jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes),
        interpret=True)
    rate_ref, tmin_ref, amin_ref, occ_ref = ref.event_scan_ref(
        remaining, mips, pes)
    np.testing.assert_allclose(np.asarray(rate), np.asarray(rate_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(tmin), np.asarray(tmin_ref),
                               rtol=1e-4)
    assert np.array_equal(np.asarray(occ), np.asarray(occ_ref))
    # argmin cols must agree wherever the row forecast is unambiguous
    # at f32 resolution (the oracle ranks in f64).
    np.testing.assert_allclose(np.asarray(amin), np.asarray(amin_ref))


def test_event_scan_matches_engine_rates():
    """The kernel, its oracle and the engine's XLA path must agree."""
    n_jobs, num_pe = 7, 2
    g = gridlet.make_batch(jnp.full((n_jobs,), 100.0))
    g = treplace(g, status=jnp.full((n_jobs,), types.RUNNING, jnp.int32),
                 resource=jnp.zeros((n_jobs,), jnp.int32),
                 remaining=jnp.arange(1.0, n_jobs + 1.0))
    fleet = resource.make_fleet([num_pe], 3.0, 1.0, types.TIME_SHARED)
    st_ = engine.init_state(g, fleet, 1)
    st_ = treplace(st_, g=g)
    engine_rates = np.asarray(engine._rates(st_, fleet, 1))

    remaining = jnp.arange(1.0, n_jobs + 1.0).reshape(1, n_jobs)
    remaining = jnp.pad(remaining, ((0, 7), (0, 0)))  # block_r alignment
    rate, tmin, _, _ = ops.event_scan(remaining, jnp.full((8,), 3.0),
                                      jnp.full((8,), num_pe, jnp.int32),
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(rate)[0], engine_rates,
                               rtol=1e-5)
    assert float(tmin[0]) == pytest.approx(
        float((jnp.arange(1.0, n_jobs + 1.0) / engine_rates).min()))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999))
def test_event_scan_capacity_conservation(seed):
    """Fig 8 invariant: allocated rate sums to min(jobs, PEs) * mips."""
    rng = np.random.RandomState(seed)
    r, j = 8, 16
    remaining = rng.exponential(10.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.5] = 0.0
    mips = rng.uniform(1.0, 10.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 5, (r,)).astype(np.int32)
    rate, _, _, _ = ops.event_scan(jnp.asarray(remaining),
                                   jnp.asarray(mips), jnp.asarray(pes),
                                   interpret=True)
    jobs = (remaining > 0).sum(axis=1)
    expect = np.minimum(jobs, pes) * mips
    np.testing.assert_allclose(np.asarray(rate).sum(axis=1), expect,
                               rtol=1e-4)


# ------------------------------------------------------------------
# event scan slab (k-wave completion forecast, one fused call)
# ------------------------------------------------------------------
def _random_slab_case(seed, r=8, j=12):
    rng = np.random.RandomState(seed)
    remaining = rng.exponential(50.0, (r, j)).astype(np.float32)
    remaining[rng.rand(r, j) < 0.3] = 0.0
    if seed % 2:  # integer remainings force ties within and across rows
        remaining = np.where(
            remaining > 0, rng.randint(1, 5, (r, j)).astype(np.float32),
            0.0)
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    kw = dict(tie=rng.permutation(r * j).reshape(r, j).astype(np.float32),
              policy=rng.randint(0, 2, (r,)).astype(np.int32),
              pe_blocked=rng.randint(0, 4, (r,)).astype(np.float32),
              row_ok=(rng.rand(r) < 0.8).astype(np.float32))
    return remaining, mips, pes, kw


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 999), k=st.sampled_from([1, 4, 6]))
def test_event_scan_slab_paths_agree(seed, k):
    """Pallas interpret, the XLA fallback and the iterated-single-scan
    oracle agree on the k-wave forecast, masks and tie keys included."""
    remaining, mips, pes, kw = _random_slab_case(seed)
    jkw = {a: jnp.asarray(v) for a, v in kw.items()}
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    pallas_out = ops.event_scan_slab(*args, k, **jkw, interpret=True)
    xla_out = ops.event_scan_slab(*args, k, **jkw)
    ref_out = ref.event_scan_slab_ref(remaining, mips, pes, k, **kw)
    for got, name in ((xla_out, "xla"), (ref_out, "oracle")):
        np.testing.assert_allclose(
            np.asarray(pallas_out[0]), np.asarray(got[0]), rtol=2e-3,
            atol=1e-3, err_msg=f"t_wave vs {name}")
        assert np.array_equal(np.asarray(pallas_out[1]),
                              np.asarray(got[1])), f"col_wave vs {name}"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 999))
def test_event_scan_slab_wave0_is_event_scan(seed):
    """Wave 0 of the slab is exactly the single scan's forecast -- the
    slab is a strict generalisation of event_scan."""
    remaining, mips, pes, kw = _random_slab_case(seed)
    jkw = {a: jnp.asarray(v) for a, v in kw.items()}
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    t_w, col_w = ops.event_scan_slab(*args, 3, **jkw)
    _, tmin, amin, _ = ops.event_scan(*args, **jkw)
    np.testing.assert_allclose(np.asarray(t_w[:, 0]), np.asarray(tmin),
                               rtol=1e-5)
    assert np.array_equal(np.asarray(col_w[:, 0]), np.asarray(amin))
    # waves are non-decreasing in time per row (BIG pads stay last)
    tw = np.asarray(t_w)
    assert np.all(np.diff(tw, axis=1) >= -1e-3)


def test_event_scan_slab_lowers_for_tpu_shapes():
    """The slab kernel must trace/lower at fleet scale (R=256, J=128,
    k=8) -- the TPU-target workload of the batched superstep engine."""
    r, j = 256, 128
    rem = jax.ShapeDtypeStruct((r, j), jnp.float32)
    v = jax.ShapeDtypeStruct((r,), jnp.float32)
    jax.eval_shape(lambda a, m, p: ops.event_scan_slab(
        a, m, p, 8, interpret=True), rem, v, v)


# ------------------------------------------------------------------
# rank output, lane tiling and the bitonic large-J path
# ------------------------------------------------------------------
from repro.kernels import event_scan as event_scan_mod


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), j=st.sampled_from([8, 64, 512, 1024]))
def test_bitonic_rank_matches_lexsort(seed, j):
    """The in-kernel O(J log^2 J) bitonic rank agrees with the stable
    lexsort rank on every valid slot (invalid-slot ranks are
    uncontractual), at power-of-two widths up to past the crossover."""
    rng = np.random.RandomState(seed)
    rem = rng.exponential(50.0, (8, j)).astype(np.float32)
    rem[rng.rand(8, j) < 0.4] = 0.0
    if seed % 2:  # integer remainings force ties broken by the tie key
        rem = np.where(rem > 0,
                       rng.randint(1, 4, (8, j)).astype(np.float32), 0.0)
    tie = rng.permutation(8 * j).reshape(8, j).astype(np.float32)
    valid = (rem > 0) & (rem < event_scan_mod.BIG)
    rb, _, _ = jax.jit(event_scan_mod._bitonic_rank)(
        jnp.asarray(rem), jnp.asarray(tie), jnp.asarray(valid))
    rl, _, _ = event_scan_mod._lexsort_rank(
        jnp.asarray(rem), jnp.asarray(tie), jnp.asarray(valid))
    assert np.array_equal(np.asarray(rb)[valid], np.asarray(rl)[valid])


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 999), j=st.sampled_from([12, 130, 600]))
def test_event_scan_rank_output_and_lane_padding(seed, j):
    """``with_rank=True`` agrees across Pallas interpret (lane-padded;
    J=600 pads to 1024 and exercises the bitonic in-kernel path), the
    XLA fallback and the oracle -- on valid slots, with identical
    rate/forecast/argmin/occupancy outputs at the caller's original J.
    """
    rng = np.random.RandomState(seed)
    r = 8
    rem = rng.exponential(50.0, (r, j)).astype(np.float32)
    rem[rng.rand(r, j) < 0.4] = 0.0
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    tie = rng.permutation(r * j).reshape(r, j).astype(np.float32)
    pol = rng.randint(0, 2, (r,)).astype(np.int32)
    args = (jnp.asarray(rem), jnp.asarray(mips), jnp.asarray(pes))
    kw = dict(tie=jnp.asarray(tie), policy=jnp.asarray(pol))
    p = ops.event_scan(*args, **kw, interpret=True, with_rank=True)
    x = event_scan_mod.event_scan_xla(*args, **kw, with_rank=True)
    o = ref.event_scan_ref(rem, mips, pes, tie=tie, policy=pol,
                           with_rank=True)
    valid = rem > 0
    for got, name in ((x, "xla"), (o, "oracle")):
        np.testing.assert_allclose(np.asarray(p[0]), np.asarray(got[0]),
                                   rtol=1e-4, atol=1e-4, err_msg=name)
        np.testing.assert_allclose(np.asarray(p[1]), np.asarray(got[1]),
                                   rtol=1e-4, err_msg=name)
        assert np.array_equal(np.asarray(p[3]), np.asarray(got[3])), name
        assert np.array_equal(np.asarray(p[4])[valid],
                              np.asarray(got[4])[valid]), f"rank {name}"
    assert np.array_equal(np.asarray(p[2]), np.asarray(x[2]))
    assert p[0].shape == (r, j) and p[4].shape == (r, j)
    assert int(np.asarray(p[2]).max()) <= j   # sentinel remapped to J


def test_event_scan_rank_injection_is_bitwise_identical():
    """Injecting the fresh rank back into the XLA path (the engine's
    slab-fed sort-free micro-step scan) reproduces every output
    bitwise."""
    rng = np.random.RandomState(7)
    r, j = 8, 40
    rem = rng.exponential(50.0, (r, j)).astype(np.float32)
    rem[rng.rand(r, j) < 0.3] = 0.0
    mips = rng.uniform(1.0, 500.0, (r,)).astype(np.float32)
    pes = rng.randint(1, 9, (r,)).astype(np.int32)
    kw = dict(tie=jnp.asarray(
        rng.permutation(r * j).reshape(r, j).astype(np.float32)))
    base = event_scan_mod.event_scan_xla(
        jnp.asarray(rem), jnp.asarray(mips), jnp.asarray(pes), **kw,
        with_rank=True)
    again = event_scan_mod.event_scan_xla(
        jnp.asarray(rem), jnp.asarray(mips), jnp.asarray(pes), **kw,
        with_rank=True, rank=base[4])
    for a, b in zip(base, again):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------
# event frontier (fused 8-source fan-in)
# ------------------------------------------------------------------
def _random_frontier_case(rng, n_src=None, seg_hi=7):
    sizes = tuple(int(v) for v in rng.randint(
        0, seg_hi, size=n_src or rng.randint(1, 9)))
    c = sum(sizes)
    cand = np.where(rng.rand(c) < 0.35, np.inf,
                    rng.uniform(0.0, 100.0, c)).astype(np.float32)
    if c and rng.rand() < 0.5:      # force exact duplicates of the min
        cand[rng.randint(c)] = np.nanmin(
            np.where(np.isfinite(cand), cand, np.nan)) \
            if np.isfinite(cand).any() else np.inf
    cuts = (rng.rand(c) < 0.5).astype(np.float32)
    return cand, sizes, cuts


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 9999))
def test_event_frontier_paths_agree(seed):
    """Pallas interpret, the XLA fallback and the oracle agree exactly
    (t*, fired, counts, t_safe, per-source mins) on random segment
    layouts including empty segments and all-inf sources."""
    rng = np.random.RandomState(seed)
    cand, sizes, cuts = _random_frontier_case(rng)
    fp = event_scan_mod.event_frontier(jnp.asarray(cand), sizes,
                                       cuts=jnp.asarray(cuts),
                                       interpret=True)
    fx = event_scan_mod.event_frontier_xla(jnp.asarray(cand), sizes,
                                           cuts=jnp.asarray(cuts))
    fr = ref.event_frontier_ref(cand, sizes, cuts=cuts)
    for a, b, c in zip(fp, fx, fr):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(a), np.asarray(c))


def test_event_frontier_tpu_lane_shapes():
    """The engine's real layout -- per-row completion forecasts,
    per-resource failure/recovery streams, [N]-sized RETURN/ARRIVAL
    segments, a scalar broker -- padded across TPU lane boundaries."""
    rng = np.random.RandomState(0)
    sizes = (16, 11, 11, 6, 2000, 2000, 11, 1)
    c = sum(sizes)
    cand = np.where(rng.rand(c) < 0.6, np.inf,
                    rng.uniform(0.0, 500.0, c)).astype(np.float32)
    cuts = np.concatenate([
        np.zeros(16, np.float32),           # COMPLETION: spec-safe
        np.ones(11, np.float32), np.ones(11, np.float32),
        np.ones(6, np.float32),
        np.zeros(2000, np.float32),         # RETURN: spec-safe
        np.ones(2000, np.float32), np.ones(11, np.float32),
        np.ones(1, np.float32)])
    fp = event_scan_mod.event_frontier(jnp.asarray(cand), sizes,
                                       cuts=jnp.asarray(cuts),
                                       interpret=True)
    fr = ref.event_frontier_ref(cand, sizes, cuts=cuts)
    for a, b in zip(fp, fr):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # t_safe only sees horizon-cutting candidates
    t_star, fired, counts, t_safe, mins = fr
    assert float(t_safe) >= float(t_star)


# ------------------------------------------------------------------
# associative-scan slab: operator property, 3-way agreement, lowering
# ------------------------------------------------------------------
def _random_wave_matrix(rng, k, dtype):
    """A random wave-compose operand: identity except one row, like the
    matrices _wave_matrices emits (last row stays [0..0 1])."""
    m = np.eye(k + 1, dtype=dtype)
    p = rng.randint(0, k)
    m[p, :] = 0.0
    m[p, :p] = rng.uniform(-3.0, 0.0, p)
    m[p, k] = rng.uniform(0.0, 50.0)
    return m


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), k=st.sampled_from([2, 4, 8]))
def test_wave_compose_operator_is_associative(seed, k):
    """The wave-compose operator (matrix product of homogeneous wave
    updates) is exactly associative in f64 and associative to matmul
    rounding in f32 -- the property jax.lax.associative_scan and the
    in-kernel product tree rely on to regroup the k waves freely."""
    rng = np.random.RandomState(seed)
    a64, b64, c64 = (_random_wave_matrix(rng, k, np.float64)
                     for _ in range(3))
    # exact-precision leg: the operator's definition (compose(a, b) =
    # b @ a) mirrored in float64 numpy -- jnp would demote to f32
    left = c64 @ (b64 @ a64)
    right = (c64 @ b64) @ a64
    np.testing.assert_allclose(left, right, rtol=1e-12, atol=1e-12)
    comp = event_scan_mod._compose_waves
    a, b, c = (x.astype(np.float32) for x in (a64, b64, c64))
    np.testing.assert_allclose(
        np.asarray(comp(comp(jnp.asarray(a), jnp.asarray(b)),
                        jnp.asarray(c))),
        np.asarray(comp(jnp.asarray(a),
                        comp(jnp.asarray(b), jnp.asarray(c)))),
        rtol=2e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 999), j=st.sampled_from([100, 512, 1024]),
       k=st.sampled_from([1, 4, 8]))
def test_event_scan_slab_assoc_three_way_agreement(seed, j, k):
    """Associative slab tri-implementation at engine widths: Pallas
    interpret (balanced product tree), the XLA associative_scan path,
    the sequential recurrence and the float64 forward-substitution
    oracle all agree -- J = 512/1024 route the rank through the bitonic
    network, J = 100 through the pairwise path."""
    remaining, mips, pes, kw = _random_slab_case(seed, j=j)
    jkw = {a: jnp.asarray(v) for a, v in kw.items()}
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    pallas_out = ops.event_scan_slab(*args, k, **jkw, interpret=True,
                                     assoc=True)
    xla_out = ops.event_scan_slab(*args, k, **jkw, assoc=True)
    seq_out = ops.event_scan_slab(*args, k, **jkw, assoc=False)
    ref_out = ref.event_scan_slab_assoc_ref(remaining, mips, pes, k,
                                            **kw)
    for got, name in ((xla_out, "xla-assoc"), (seq_out, "sequential"),
                      (ref_out, "oracle")):
        np.testing.assert_allclose(
            np.asarray(pallas_out[0]), np.asarray(got[0]), rtol=2e-3,
            atol=1e-3, err_msg=f"t_wave vs {name}")
        assert np.array_equal(np.asarray(pallas_out[1]),
                              np.asarray(got[1])), f"col_wave vs {name}"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 999))
def test_event_scan_slab_assoc_wave0_bitwise(seed):
    """Wave 0 must be BITWISE identical between the associative and
    sequential paths (identity prefix rows compose exactly), which is
    what lets the engine treat the two as interchangeable for the
    single-wave forecasts its micro-steps consume."""
    remaining, mips, pes, kw = _random_slab_case(seed)
    jkw = {a: jnp.asarray(v) for a, v in kw.items()}
    args = (jnp.asarray(remaining), jnp.asarray(mips), jnp.asarray(pes))
    t_a, col_a = ops.event_scan_slab(*args, 6, **jkw, assoc=True)
    t_s, col_s = ops.event_scan_slab(*args, 6, **jkw, assoc=False)
    assert np.array_equal(np.asarray(t_a[:, 0]), np.asarray(t_s[:, 0]))
    assert np.array_equal(np.asarray(col_a), np.asarray(col_s))
    # later waves agree to compose rounding; padding stays exact BIG/J
    np.testing.assert_allclose(np.asarray(t_a), np.asarray(t_s),
                               rtol=2e-3, atol=1e-3)


def test_event_scan_slab_assoc_lowers_for_tpu_shapes():
    """Both slab formulations trace/lower at fleet scale (R=256, J=128,
    k=8) and at the wide bitonic widths J = 512/1024."""
    for j in (128, 512, 1024):
        rem = jax.ShapeDtypeStruct((256, j), jnp.float32)
        v = jax.ShapeDtypeStruct((256,), jnp.float32)
        for assoc in (True, False):
            jax.eval_shape(
                lambda a, m, p, assoc=assoc: ops.event_scan_slab(
                    a, m, p, 8, interpret=True, assoc=assoc),
                rem, v, v)
