"""Scenario-fuzzing differential harness for the superstep engine.

Every execution path of the engine -- the jitted batched reference
(``engine.run``), the select-free sweep loop (``engine.run_sweep``) and
the lane-batched sweep (``engine.run_sweep_lanes``) -- must produce
bit-for-bit identical *results* (gridlet lifecycles, spend, traces,
event counts) for every batch/slab depth, across randomly drawn
scenarios: fleet shapes x scheduling policies x deadlines x budgets x
failure streams x network subsystem on/off.  The associative-scan slab
carry-through (FAILURE / RECOVERY / NETWORK events firing inside
speculative micro-supersteps) is exactly the machinery this pins down:
any unsafe horizon or mis-ordered in-slab apply shows up as a trace or
spend divergence on some drawn scenario.

``CORPUS`` is the committed deterministic seed set (tier-1 gated, runs
without hypothesis installed); the ``@given`` fuzzer widens the search
when hypothesis is available and shrinks to a minimal seed on failure
-- add that seed to ``CORPUS`` when it finds one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without dev deps: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import engine, gridlet, resource, simulation, types

# Deterministic seeded corpus: chosen to cover both resource policies,
# all four broker optimisations, failures on/off, the network subsystem
# on/off, the dynamic-pricing models (each of K_MARKET and K_AUCTION
# fires in at least one seed -- asserted below), plan-ahead dispatch
# and the failure-domain axis (_build_case draws all of those from the
# seed).  716 and 735 draw shared-trunk topologies with trunk-target
# injection rows that actually fell a populated failure domain (716
# additionally with retry_limit=1, 735 with the network subsystem on).
CORPUS = (0, 3, 7, 42, 101, 555, 601, 607, 716, 735)

MAX_EVENTS = 4096


def _build_case(seed):
    """One fuzzed scenario, fully determined by ``seed``."""
    rng = np.random.RandomState(seed)
    n_res = int(rng.randint(2, 5))
    fleet = resource.make_fleet(
        num_pe=rng.randint(1, 4, n_res).tolist(),
        mips_per_pe=np.round(rng.uniform(1.0, 8.0, n_res), 2).tolist(),
        cost_per_sec=np.round(rng.uniform(1.0, 5.0, n_res), 2).tolist(),
        policy=rng.choice([types.TIME_SHARED, types.SPACE_SHARED],
                          n_res).tolist(),
        baud_rate=28_000.0)
    n_users = int(rng.randint(1, 3))
    n_jobs = int(rng.randint(4, 9))
    net_on = bool(seed % 2)
    g = gridlet.task_farm(
        jax.random.PRNGKey(seed), n_jobs=n_jobs, n_users=n_users,
        base_mi=1000.0,
        in_bytes=float(rng.choice([0.0, 50_000.0])) if net_on else 0.0,
        out_bytes=float(rng.choice([0.0, 25_000.0])) if net_on else 0.0)
    sc_kw = {}
    if net_on:
        sc_kw.update(baud_rate=float(rng.choice([9_600.0, 28_000.0])),
                     bg_flows=float(rng.choice([0.0, 1.0])))
    if rng.randint(0, 2):  # failure stream on/off
        sc_kw.update(mtbf=float(rng.choice([150.0, 600.0])),
                     mttr=float(rng.choice([5.0, 40.0])),
                     seed=int(rng.randint(0, 100)))
    deadline = float(rng.choice([200.0, 500.0, 2000.0]))
    budget = float(rng.choice([5_000.0, 50_000.0]))
    # The policy axis: all four broker optimisations, the three pricing
    # models (static weighted double so most scenarios keep advertised
    # prices) and plan-ahead dispatch.  Drawn AFTER every legacy knob so
    # the pre-policy-axis scenario shapes replay unchanged per seed.
    opt = int(rng.choice([types.OPT_COST, types.OPT_TIME,
                          types.OPT_COST_TIME, types.OPT_NONE]))
    pricing = int(rng.choice([0, 0, 1, 2]))
    if pricing == 1:
        sc_kw.update(pricing_model="commodity",
                     market_period=float(rng.choice([20.0, 75.0])),
                     market_gain=float(rng.choice([0.1, 0.5])))
    elif pricing == 2:
        sc_kw.update(pricing_model="auction",
                     auction_period=float(rng.choice([25.0, 90.0])),
                     auction_seed=int(rng.randint(0, 100)))
    if rng.randint(0, 2):
        sc_kw.update(plan_ahead=True)
    # The failure-domain axis: shared-trunk topology, trace-driven
    # fault injection and the fault-tolerant broker knobs.  Drawn AFTER
    # every earlier knob so the pre-trunk scenario shapes replay
    # unchanged per seed.  An injection schedule replaces the
    # stochastic MTBF stream (mixing both fault sources on one
    # resource is unsupported -- see engine.default_params).
    if rng.randint(0, 2):
        sc_kw.update(trunk_of=rng.randint(-1, 2, n_res).tolist(),
                     trunk_baud=float(rng.choice([14_000.0, 56_000.0])),
                     trunk_bg=float(rng.choice([0.0, 1.0])))
        if rng.randint(0, 2):
            sc_kw.pop("mtbf", None)
            sc_kw.pop("mttr", None)
            rows, t = [], 0.0
            for _ in range(int(rng.randint(1, 4))):
                t += float(np.round(rng.uniform(5.0, 60.0), 1))
                tgt = int(rng.randint(0, n_res + 2))  # resource | trunk
                rows.append((t, tgt, 0))
                rows.append((t + float(np.round(rng.uniform(5.0, 30.0),
                                                1)), tgt, 1))
            sc_kw.update(fault_trace=rows)
        if rng.randint(0, 2):
            sc_kw.update(retry_limit=int(rng.randint(1, 4)),
                         backoff_base=float(rng.choice([0.0, 5.0])),
                         blacklist_cooldown=float(rng.choice([0.0,
                                                              10.0])))
    sc = simulation.Scenario(**sc_kw) if sc_kw else None
    params = simulation._scenario_params(fleet, deadline, budget, opt,
                                         n_users, sc)
    max_jobs = simulation.safe_max_jobs(g, params, fleet)
    net_cap = simulation.safe_net_cap(g, params, fleet, n_users) \
        if net_on else 0
    return g, fleet, params, n_users, max_jobs, net_cap


_RESULT_FIELDS = ("spent", "term_time", "n_events", "overflow",
                  "n_failed", "n_resubmits", "downtime")
_GRIDLET_FIELDS = ("status", "resource", "remaining", "start", "finish",
                   "returned", "cost")


def _fingerprint(r):
    """Everything that must be bitwise identical across paths (the
    "how" counters n_steps/n_spec/n_scans/n_reseeds are excluded: they
    may pack the same events into supersteps differently)."""
    out = {f: np.asarray(getattr(r, f)) for f in _RESULT_FIELDS}
    for f in _GRIDLET_FIELDS:
        out["gridlet." + f] = np.asarray(getattr(r.gridlets, f))
    for name, a in zip(("t", "kind", "who"), r.trace):
        out["trace." + name] = np.asarray(a)
    return out


def _assert_paths_identical(seed):
    g, fleet, params, n_users, max_jobs, net_cap = _build_case(seed)
    kw = dict(max_jobs=max_jobs, net_cap=net_cap)
    ref = engine.run(g, fleet, params, n_users, MAX_EVENTS, batch=1,
                     **kw)
    assert int(ref.n_steps) + int(ref.n_spec) < MAX_EVENTS, \
        f"seed {seed}: truncated -- raise MAX_EVENTS"
    fp0 = _fingerprint(ref)

    runs = {}
    # run_inner: the unjitted reference body under an explicit jit
    runs["run_inner.b1"] = jax.jit(
        lambda gg, pp: engine.run_inner(gg, fleet, pp, n_users,
                                        MAX_EVENTS, **kw))(g, params)
    for batch in (2, 8):  # the slab-depth axis
        runs[f"run.b{batch}"] = engine.run(g, fleet, params, n_users,
                                           MAX_EVENTS, batch=batch, **kw)
    runs["run_sweep.b8"] = jax.jit(
        lambda gg, pp: engine.run_sweep(gg, fleet, pp, n_users,
                                        MAX_EVENTS, batch=8, **kw))(
        g, params)
    lanes = jax.jit(
        lambda gg, pp: engine.run_sweep_lanes(gg, fleet, pp, n_users,
                                              MAX_EVENTS, batch=8,
                                              **kw))(
        g, jax.tree_util.tree_map(lambda a: jnp.stack([a, a]), params))
    for lane in range(2):
        runs[f"run_sweep_lanes.l{lane}"] = jax.tree_util.tree_map(
            lambda a: a[lane], lanes)

    # telemetry on/off: the metrics ring is a separate loop carry that
    # must never feed back into the simulation -- every engine path
    # replays the full fingerprint (results, gridlets, trace) bitwise
    # with the ring recording alongside.
    runs["run.b1.tel"] = engine.run(g, fleet, params, n_users,
                                    MAX_EVENTS, batch=1, telemetry=256,
                                    **kw)
    runs["run.b8.tel"] = engine.run(g, fleet, params, n_users,
                                    MAX_EVENTS, batch=8, telemetry=256,
                                    **kw)
    runs["run_sweep.b8.tel"] = jax.jit(
        lambda gg, pp: engine.run_sweep(gg, fleet, pp, n_users,
                                        MAX_EVENTS, batch=8,
                                        telemetry=256, **kw))(g, params)
    lanes_tel = jax.jit(
        lambda gg, pp: engine.run_sweep_lanes(gg, fleet, pp, n_users,
                                              MAX_EVENTS, batch=8,
                                              telemetry=256, **kw))(
        g, jax.tree_util.tree_map(lambda a: jnp.stack([a, a]), params))
    for lane in range(2):
        runs[f"run_sweep_lanes.l{lane}.tel"] = jax.tree_util.tree_map(
            lambda a: a[lane], lanes_tel)

    for name, r in runs.items():
        fp = _fingerprint(r)
        for key, want in fp0.items():
            assert np.array_equal(want, fp[key]), \
                f"seed {seed}: {name} diverges from batch=1 at {key}"
        if name.endswith(".tel"):
            assert r.telemetry is not None and int(r.telemetry.n) > 0, \
                f"seed {seed}: {name} recorded no telemetry rows"
        else:
            assert r.telemetry is None


@pytest.mark.parametrize("seed", CORPUS)
def test_fuzz_corpus_paths_identical(seed):
    """The committed corpus: every engine path replays every scenario
    bitwise at every batch depth."""
    _assert_paths_identical(seed)


def test_fuzz_corpus_covers_pricing_kinds():
    """The committed corpus exercises each dynamic-pricing event kind
    at least once (a corpus re-roll that silently loses coverage of
    K_MARKET or K_AUCTION fails here, not in review)."""
    from repro.core import des
    seen = set()
    for seed in CORPUS:
        g, fleet, params, n_users, max_jobs, net_cap = _build_case(seed)
        r = engine.run(g, fleet, params, n_users, MAX_EVENTS, batch=1,
                       max_jobs=max_jobs, net_cap=net_cap)
        seen |= set(np.asarray(r.trace[1]).tolist())
    assert des.K_MARKET in seen, "no corpus seed fires a market round"
    assert des.K_AUCTION in seen, "no corpus seed fires an auction round"


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 99_999))
def test_fuzz_random_scenarios_paths_identical(seed):
    """Hypothesis-widened search over the same scenario space; shrinks
    to a minimal failing seed -- commit it to CORPUS if found."""
    _assert_paths_identical(seed)
