"""Vectorised DES engine throughput (the core's own perf table).

The 2002 toolkit ran one JVM thread per entity; the array engine's cost
is events/second at fleet scale.  Three WWG scenarios (1 / 20 / 200
users), a failure scenario, a correlated trunk-cut scenario (shared
failure domain + trace-driven injection + the retry/backoff broker)
and a large-J deep-queue scenario are timed
and written to ``benchmarks/artifacts/BENCH_engine.json`` with
steady-state events/sec, compile time, while-loop iterations and
wall-clock, so future PRs have a perf trajectory (the full schema and
the PR-over-PR table live in docs/PERFORMANCE.md).

Timing discipline: the first call is timed separately (``compile_s`` --
jit tracing + XLA compile + the first run) from the steady-state run
that follows (``wall_s``/``events_per_sec``).  Folding compilation into
the throughput number hides the real per-iteration constant, which is
what the engine work optimises.

Each scenario runs twice more: once with the k-step speculative
superstep batching that is the engine default
(``engine.DEFAULT_BATCH``) -- the timed run -- and once with
``batch=1`` to record the iteration-count baseline and assert the two
runs are bit-for-bit identical (``batched_identical``).  The 20-user
cell is additionally compared against the recorded pre-superstep engine
(tests/data/golden_pre_refactor.json): results must stay identical
while while-loop iterations keep shrinking (``iteration_ratio``).
A third untimed pass per scenario runs with the telemetry metrics ring
recording and gates ``telemetry_identical`` -- the ring is a separate
loop carry that must never feed back into the simulation.  Every cell
also carries roofline columns (``arith_intensity`` /
``pct_of_roofline`` / ``roofline_bound``): the analytic FLOP/byte
model of the associative slab solve at the cell's job-table shape
(benchmarks/roofline.bench_row) grounded against the measured wall.

Three microbench sections ride along under the ``_`` prefix (skipped
by the per-scenario renderer columns, rendered as their own tables):

* ``_rank_crossover`` -- XLA-compiled wall-clock of the three exact
  in-kernel ranking algorithms (pairwise O(J^2), bitonic O(J log^2 J),
  lexsort O(J log J)) across J, measuring the
  ``event_scan.RANK_BITONIC_MIN_J`` crossover claim;
* ``_sweep_bench`` -- the sweep engine section: steady-state wall of
  ``simulation.sweep`` through the reference batch=1 path vs the
  lane-batched select-free sweep engine (``select_free=True``, the
  default), timed as interleaved median-of-3 with ``compile_s`` split
  out per row (first call) so the ratio measures execution, not
  tracing or load transients; bitwise ``sweep_identical`` checks on
  both the coarse-poll headline grid and the paper-default-poll grid;
  and a host-device-count scaling row timing
  ``simulation.sweep_sharded`` in subprocesses at
  ``--xla_force_host_platform_device_count`` 1 vs 2 on a
  heterogeneous-run-length grid (short-deadline lanes grouped on one
  device stop costing while-loop iterations on the other);
* ``_strategy_sweep`` -- the economic-broker section: the four DBC
  strategies plus the commodity/auction pricing models and plan-ahead
  dispatch as lanes of one ``engine.run_sweep_lanes`` call, with
  CI-gated ``strategy_identical`` (every lane bitwise equal to its
  ``engine.run(batch=1)`` reference) and ``table1_ordering`` (cost-min
  spends no more than time-min; time-min finishes no later) bits.

The module enables the JAX persistent compilation cache
(``jax_compilation_cache_dir``; override the directory with the
``JAX_COMPILATION_CACHE_DIR`` env var) so repeated bench runs -- and
the bench rows that share static shapes, which all reuse the single
module-level jitted ``simulation._sweep_grid`` -- skip recompilation.

Sized for the 1-core CPU container (the kernel routes through its XLA
fallback there); the same jit'd program is the TPU-target workload for
kernels.event_scan / event_scan_slab.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gridlet, resource, simulation, types
from repro.kernels import event_scan as event_scan_mod

from . import roofline
from .common import art_path

REPO = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir)
GOLDEN_PATH = os.path.join(REPO, "tests", "data",
                           "golden_pre_refactor.json")


def enable_compilation_cache():
    """Point jax at a persistent on-disk compilation cache (best
    effort: older/newer jax releases differ in knob names)."""
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR",
                               "/tmp/jax_cache")
    for key, val in (("jax_compilation_cache_dir", cache_dir),
                     ("jax_persistent_cache_min_compile_time_secs", 1.0),
                     ("jax_persistent_cache_min_entry_size_bytes", 0)):
        try:
            jax.config.update(key, val)
        except (AttributeError, ValueError):
            pass


def _deep_fleet():
    """Few resources, deep per-resource job tables: 2 x 80-PE
    time-shared resources.  With 4 users the broker stages up to
    ``4 * 2 * 80 = 640`` concurrent jobs per resource, so the job-slot
    axis J reaches 640 -- strictly past RANK_BITONIC_MIN_J = 512, so
    Pallas lane-pads it to 1024 and selects the bitonic in-kernel rank
    on TPU; on CPU it is the widest lexsort the XLA fallback sees."""
    return resource.make_fleet([80, 80], [100.0, 120.0], [1.0, 2.0],
                               types.TIME_SHARED)


# (n_users, n_jobs_per_user, scenario, fleet_fn, deadline, budget,
# extras): the failure cell re-runs the 20-user workload with the
# failure/recovery event source live (MTBF=500, MTTR=25) so the perf
# trajectory tracks the dynamic-resource path -- including how far
# dense interference degrades the speculation horizon -- not just the
# static fleet; the 4-user cell is the large-J rank-crossover workload;
# the net cell re-runs the 20-user workload with real file payloads
# over the contention-aware fair-share links (suffix "_net": the
# NETWORK event source + link_scan kernel live in the hot path, with
# one phantom background flow per link).  ``extras`` keys: suffix,
# in_bytes/out_bytes (payloads; default 0), net (enable the network
# subsystem with an auto-sized transfer table).
SCENARIOS = (
    (1, 200, None, None, 2000.0, 22000.0, None),
    (20, 100, None, None, 2000.0, 22000.0, None),
    (200, 10, None, None, 2000.0, 22000.0, None),
    (20, 100, simulation.Scenario(mtbf=500.0, mttr=25.0, seed=1), None,
     2000.0, 22000.0, dict(suffix="_fail")),
    (4, 512, None, _deep_fleet, 2000.0, 500000.0, None),
    (20, 100, simulation.Scenario(baud_rate=28_000.0, bg_flows=1.0),
     None, 2000.0, 22000.0,
     dict(suffix="_net", net=True, in_bytes=200_000.0,
          out_bytes=100_000.0)),
    # The correlated-failure cell: the WWG fleet's first five resources
    # share one trunk (11 = R + trunk id 0 targets the whole domain) and
    # a replayable trace cuts it mid-run for 100 time units -- every
    # resource behind the trunk fails in ONE superstep, in-flight
    # gridlets refund and resubmit, and the retry/backoff broker knobs
    # are live so the perf trajectory tracks the fault-tolerant path.
    (20, 100, simulation.Scenario(
        trunk_of=[0, 0, 0, 0, 0, -1, -1, -1, -1, -1, -1],
        fault_trace=[(500.0, 11, 0), (600.0, 11, 1)],
        retry_limit=8, backoff_base=1.0, blacklist_cooldown=5.0),
     None, 2000.0, 22000.0, dict(suffix="_trunk")),
)


def _one(fleet, g, n_users, scenario, batch, deadline, budget,
         net_cap=0, timed=True):
    kw = dict(deadline=deadline, budget=budget, opt=types.OPT_COST,
              n_users=n_users, scenario=scenario, batch=batch,
              net_cap=net_cap)
    t0 = time.perf_counter()
    r = simulation.run_experiment(g, fleet, **kw)      # compile + run
    jax.block_until_ready(r.spent)
    first = time.perf_counter() - t0
    if not timed:       # baseline pass: results only, skip the re-run
        return r, float("nan"), float("nan")
    wall = float("inf")
    for _ in range(2):  # best-of-2: damp container load noise
        t0 = time.perf_counter()
        r = simulation.run_experiment(g, fleet, **kw)  # steady state
        jax.block_until_ready(r.spent)
        wall = min(wall, time.perf_counter() - t0)
    return r, wall, max(first - wall, 0.0)


def _rank_crossover():
    """Wall-clock of the three exact ranking algorithms, XLA-compiled
    on [8, J] rows -- the measured basis of the
    ``RANK_BITONIC_MIN_J`` in-kernel crossover (docs/PERFORMANCE.md).
    The bitonic needs a power-of-two width, so J sweeps powers of 2."""
    rows = {}
    rng = np.random.RandomState(0)
    algos = {
        "pairwise_o_j2": event_scan_mod._pairwise_rank,
        "bitonic_o_jlog2j": event_scan_mod._bitonic_rank,
        "lexsort_o_jlogj": event_scan_mod._lexsort_rank,
    }
    for j in (64, 128, 256, 512, 1024):
        rem = jnp.asarray(rng.exponential(50.0, (8, j)), jnp.float32)
        tie = jnp.asarray(
            rng.permutation(8 * j).reshape(8, j), jnp.float32)
        valid = rem > 10.0
        cell = {}
        for name, fn in algos.items():
            f = jax.jit(lambda rem, tie, valid, fn=fn:
                        fn(rem, tie, valid)[0])
            jax.block_until_ready(f(rem, tie, valid))
            t0 = time.perf_counter()
            n = 50
            for _ in range(n):
                out = f(rem, tie, valid)
            jax.block_until_ready(out)
            cell[name] = (time.perf_counter() - t0) / n * 1e6  # us
        rows[f"j{j}"] = cell
    rows["crossover_j"] = event_scan_mod.RANK_BITONIC_MIN_J
    return rows


# "How" counters may pack the same events into supersteps differently
# between the reference and sweep loops; every "what" field must match
# bitwise (same convention as tests/test_sweep_engine.py).  The
# telemetry ring is observability, not a result -- it records one row
# per committed superstep, so it inherits the packing differences.
_HOW_COUNTERS = ("n_steps", "n_spec", "n_scans", "n_reseeds",
                 "telemetry")


def _results_identical(a, b) -> bool:
    for name in a._fields:
        if name in _HOW_COUNTERS:
            continue
        la = jax.tree_util.tree_leaves(getattr(a, name))
        lb = jax.tree_util.tree_leaves(getattr(b, name))
        if len(la) != len(lb):
            return False
        for x, y in zip(la, lb):
            if not np.array_equal(np.asarray(x), np.asarray(y)):
                return False
    return True


# Device-scaling lane mix, chosen so run lengths differ wildly across
# the sharded axis: the deep fleet at J=640 makes per-iteration work
# expensive, the infeasible deadline (2.0) makes its 20 lanes give up
# in a handful of supersteps while the 10000.0 lanes run ~138, and the
# budget axis stays minor (non-sharded).  Sharding deadline-major puts
# all short lanes on one device, which then stops paying while-loop
# iterations for the long lanes -- the convoy effect a single vmap
# cannot avoid on any device count.
_DEVICE_SCALING_CODE = """
    import json, time
    import jax, jax.numpy as jnp
    from benchmarks import engine_bench
    engine_bench.enable_compilation_cache()
    from repro.core import gridlet, resource, simulation, types
    fleet = engine_bench._deep_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=256, n_users=4)
    dls = jnp.asarray([2.0, 10000.0])
    buds = jnp.linspace(150000.0, 500000.0, 20)
    t0 = time.perf_counter()
    r = simulation.sweep_sharded(g, fleet, dls, buds, types.OPT_COST, 4)
    jax.block_until_ready(r.spent)
    first = time.perf_counter() - t0
    t0 = time.perf_counter()
    r = simulation.sweep_sharded(g, fleet, dls, buds, types.OPT_COST, 4)
    jax.block_until_ready(r.spent)
    wall = time.perf_counter() - t0
    print(json.dumps({"devices": len(jax.devices()),
                      "wall_s": wall,
                      "compile_s": max(first - wall, 0.0),
                      "n_done": float(jnp.sum(r.n_done)),
                      "spent": float(jnp.sum(r.spent))}))
"""


def _device_scaling():
    """Time ``sweep_sharded`` at 1 vs 2 host devices, each in its own
    subprocess (``--xla_force_host_platform_device_count`` must be set
    before jax initialises, and the bench parent keeps its single
    device).  One steady run per device count -- each is a minute-scale
    program, far above timer noise."""
    rows = {}
    for n in (1, 2):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(REPO, "src"), REPO]
            + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
               if p])
        env.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_cache")
        r = subprocess.run([sys.executable, "-c",
                            textwrap.dedent(_DEVICE_SCALING_CODE)],
                           capture_output=True, text=True, env=env,
                           timeout=1800, cwd=REPO)
        if r.returncode != 0:
            rows[f"dev{n}"] = {"error": r.stderr[-2000:]}
            continue
        rows[f"dev{n}"] = json.loads(r.stdout.strip().splitlines()[-1])
    if all("wall_s" in rows.get(f"dev{n}", {}) for n in (1, 2)):
        rows["device_speedup"] = (rows["dev1"]["wall_s"] /
                                  rows["dev2"]["wall_s"])
        rows["device_identical"] = bool(
            rows["dev1"]["n_done"] == rows["dev2"]["n_done"] and
            rows["dev1"]["spent"] == rows["dev2"]["spent"])
    return rows


def _sweep_bench():
    """The sweep engine section: the reference batch=1 grid
    (``select_free=False`` -- under vmap its conds lower to selects, so
    both branches execute every superstep) vs the lane-batched sweep
    engine (``select_free=True``: the scenario lanes ride INSIDE the
    while loop, so the reseed sort / broker poll / rare applies run
    under real any-lane conds and the speculation loop exits early).

    Timing discipline: one untimed first call per path (``compile_s``),
    then three timed runs per path, INTERLEAVED (ref, sweep, ref,
    sweep, ...) with the median reported -- on a shared 1-core
    container a best-of or back-to-back scheme lets a load transient
    land entirely on one path and swing the ratio ~25% either way.

    The headline grid uses a coarse broker poll
    (``Scenario(sched_min_period=10, sched_frac=0.05)``): the paper's
    default (re-poll every 1 s of simulated time) makes nearly half the
    reference supersteps pure polls, which caps how deep ANY batching
    engine can speculate; scenarios that poll at realistic rates are
    what the sweep engine is for (see docs/PERFORMANCE.md, "Profiling
    checklist").  The paper-default ratio is recorded alongside as
    ``batch_speedup_paper_polls`` -- identity-checked the same way.

    Also: a bitwise identity check over every "what" field per
    scenario; a single-device ``sweep_sharded`` identity check on the
    same grid; and the 1-vs-2-device scaling rows."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=25, n_users=20)
    deadlines = jnp.asarray([1500.0, 2000.0])
    budgets = jnp.asarray([15000.0, 22000.0])
    coarse = simulation.Scenario(sched_min_period=10.0, sched_frac=0.05)
    out = {"grid": "20u/25j, 2x2 deadline x budget, "
                   "sched_min_period=10 sched_frac=0.05"}

    def measure(scen):
        kws = {"ref": dict(batch=1, select_free=False),
               "sweep": dict(select_free=True)}
        res, walls, first = {}, {k: [] for k in kws}, {}
        for tag, kw in kws.items():
            t0 = time.perf_counter()
            r = simulation.sweep(g, fleet, deadlines, budgets,
                                 types.OPT_COST, 20, scenario=scen, **kw)
            jax.block_until_ready(r.spent)
            first[tag] = time.perf_counter() - t0
            res[tag] = r
        for _ in range(3):
            for tag, kw in kws.items():
                t0 = time.perf_counter()
                r = simulation.sweep(g, fleet, deadlines, budgets,
                                     types.OPT_COST, 20, scenario=scen,
                                     **kw)
                jax.block_until_ready(r.spent)
                walls[tag].append(time.perf_counter() - t0)
        med = {t: sorted(w)[1] for t, w in walls.items()}
        return res, med, first

    res, med, first = measure(coarse)
    for tag in ("ref", "sweep"):
        out[f"wall_s_{tag}"] = med[tag]
        out[f"compile_s_{tag}"] = max(first[tag] - med[tag], 0.0)
        out[f"supersteps_{tag}"] = int(np.asarray(res[tag].n_steps).sum())
    out["batch"] = engine.DEFAULT_BATCH
    out["batch_speedup"] = out["wall_s_ref"] / out["wall_s_sweep"]
    out["sweep_identical"] = _results_identical(res["ref"], res["sweep"])
    res_p, med_p, _ = measure(None)
    out["batch_speedup_paper_polls"] = med_p["ref"] / med_p["sweep"]
    out["sweep_identical_paper_polls"] = _results_identical(
        res_p["ref"], res_p["sweep"])
    sh = simulation.sweep_sharded(g, fleet, deadlines, budgets,
                                  types.OPT_COST, 20, scenario=coarse)
    out["sharded_identical"] = _results_identical(res["sweep"], sh)
    out["device_scaling"] = _device_scaling()
    return out


def _strategy_sweep():
    """The economic-broker section: every DBC strategy and pricing
    model as a ``Scenario`` lane of ONE ``engine.run_sweep_lanes``
    call -- the Table-1 experiment (strategy x deadline/budget) on the
    lane-batched engine.  Seven lanes: the four broker optimisations
    under static pricing, then the cost optimiser under commodity
    repricing, sealed-bid auctions and plan-ahead (cs/0203020)
    dispatch.

    Two gate bits ride into CI like the sweep gates:

    * ``strategy_identical`` -- every lane is bitwise identical (all
      "what" fields) to its own ``engine.run(batch=1)`` reference, so
      the policy/pricing axis rides the select-free lane machinery
      without changing a single event;
    * ``table1_ordering`` -- the paper's qualitative result holds:
      cost-minimisation spends no more than time-minimisation, and
      time-minimisation finishes no later than cost-minimisation.
    """
    fleet = resource.wwg_fleet()
    n_users = 20
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=25,
                          n_users=n_users)
    deadline, budget = 2000.0, 22000.0
    max_events = simulation._max_events(g.n, n_users, deadline, 1.0)
    lanes_sc = (
        ("cost", simulation.Scenario(policy=types.OPT_COST)),
        ("time", simulation.Scenario(policy=types.OPT_TIME)),
        ("cost_time", simulation.Scenario(policy=types.OPT_COST_TIME)),
        ("none", simulation.Scenario(policy=types.OPT_NONE)),
        ("cost_commodity", simulation.Scenario(
            policy=types.OPT_COST, pricing_model="commodity",
            market_period=60.0, market_gain=0.25)),
        ("cost_auction", simulation.Scenario(
            policy=types.OPT_COST, pricing_model="auction",
            auction_period=60.0, seed=5)),
        ("cost_plan", simulation.Scenario(policy=types.OPT_COST,
                                          plan_ahead=True)),
    )
    ps = [simulation._scenario_params(fleet, deadline, budget,
                                      types.OPT_COST, n_users, sc)
          for _, sc in lanes_sc]
    p_lanes = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)
    f = jax.jit(lambda pp: engine.run_sweep_lanes(
        g, fleet, pp, n_users, max_events, batch=engine.DEFAULT_BATCH))
    t0 = time.perf_counter()
    r = f(p_lanes)
    jax.block_until_ready(r.spent)
    first = time.perf_counter() - t0
    t0 = time.perf_counter()
    r = f(p_lanes)
    jax.block_until_ready(r.spent)
    wall = time.perf_counter() - t0
    out = {"grid": f"20u/25j wwg, 7 policy/pricing lanes, "
                   f"deadline={deadline:.0f} budget={budget:.0f}",
           "wall_s": wall, "compile_s": max(first - wall, 0.0),
           "batch": engine.DEFAULT_BATCH, "lanes": {}}
    identical = True
    for i, (name, _) in enumerate(lanes_sc):
        ref = engine.run(
            g, fleet, jax.tree_util.tree_map(lambda x: x[i], p_lanes),
            n_users, max_events, batch=1)
        lane = jax.tree_util.tree_map(lambda a: a[i], r)
        identical = identical and _results_identical(ref, lane)
        identical = identical and (int(np.asarray(ref.n_steps)) +
                                   int(np.asarray(ref.n_spec))
                                   < max_events)
        out["lanes"][name] = {
            "n_done": int((np.asarray(lane.gridlets.status)
                           == types.DONE).sum()),
            "finish_t": float(np.asarray(lane.term_time).max()),
            "spent": float(np.asarray(lane.spent).sum()),
        }
    out["strategy_identical"] = bool(identical)
    rows = out["lanes"]
    out["table1_ordering"] = bool(
        rows["cost"]["spent"] <= rows["time"]["spent"] and
        rows["time"]["finish_t"] <= rows["cost"]["finish_t"])
    return out


def run():
    enable_compilation_cache()
    try:
        golden = json.load(open(GOLDEN_PATH))
    except OSError:
        golden = {}
    report, out = {}, []
    for n_users, n_jobs, scenario, fleet_fn, deadline, budget, extras \
            in SCENARIOS:
        extras = extras or {}
        fleet = resource.wwg_fleet() if fleet_fn is None else fleet_fn()
        g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=n_jobs,
                              n_users=n_users,
                              in_bytes=extras.get("in_bytes", 0.0),
                              out_bytes=extras.get("out_bytes", 0.0))
        net_cap = None if extras.get("net") else 0  # None = auto-size
        r, wall, compile_s = _one(fleet, g, n_users, scenario,
                                  engine.DEFAULT_BATCH, deadline, budget,
                                  net_cap=net_cap)
        r1, _, _ = _one(fleet, g, n_users, scenario, 1, deadline,
                        budget, net_cap=net_cap, timed=False)
        # Telemetry identity gate: the same run with the metrics ring
        # recording must be bitwise identical on every "what" field
        # (the ring is a separate loop carry that must never feed back
        # into the simulation -- see repro/core/telemetry.py).
        r_tel = simulation.run_experiment(
            g, fleet, deadline=deadline, budget=budget,
            opt=types.OPT_COST, n_users=n_users, scenario=scenario,
            batch=engine.DEFAULT_BATCH, net_cap=net_cap, telemetry=1024)
        events = int(np.asarray(r.n_events))
        steps = int(np.asarray(r.n_steps))
        steps_k1 = int(np.asarray(r1.n_steps))
        cell = {
            "n_users": n_users,
            "n_jobs_per_user": n_jobs,
            "batch": engine.DEFAULT_BATCH,
            "wall_s": wall,
            "compile_s": compile_s,
            "events": events,
            "supersteps": steps,
            "spec_supersteps": int(np.asarray(r.n_spec)),
            "supersteps_k1": steps_k1,
            "batch_iteration_ratio": steps_k1 / max(steps, 1),
            "batched_identical": bool(
                np.array_equal(np.asarray(r.n_done),
                               np.asarray(r1.n_done)) and
                np.array_equal(np.asarray(r.spent),
                               np.asarray(r1.spent)) and
                np.array_equal(np.asarray(r.term_time),
                               np.asarray(r1.term_time)) and
                int(np.asarray(r.n_events)) == int(np.asarray(r1.n_events))),
            "events_per_sec": events / max(wall, 1e-9),
            "events_per_superstep": events / max(steps, 1),
            "scan_reseeds": int(np.asarray(r.n_reseeds)),
            "slab_hit_rate": 1.0 - (int(np.asarray(r.n_reseeds)) /
                                    max(int(np.asarray(r.n_scans)), 1)),
            # Mean speculative micro-steps riding each committed
            # superstep, and the dependent-step depth of the
            # associative-scan slab solve (log2 tree over k waves vs
            # the old k sequential fori iterations).
            "slab_depth_mean": int(np.asarray(r.n_spec)) / max(steps, 1),
            "scan_depth": int(math.ceil(math.log2(
                engine.DEFAULT_BATCH))) + 1,
            "n_done": float(np.asarray(r.n_done).sum()),
            "spent": float(np.asarray(r.spent).sum()),
            "overflow": int(np.asarray(r.overflow)),
            "truncated": bool(np.asarray(r.truncated)),
            "telemetry_identical": bool(
                _results_identical(r, r_tel)
                and r_tel.telemetry is not None
                and int(np.asarray(r_tel.telemetry.n)) > 0),
        }
        # Roofline grounding: analytic arithmetic intensity of the
        # associative slab solve at this cell's [r_pad, J] shape, and
        # the measured throughput as a fraction of the intensity-capped
        # ceiling (benchmarks/roofline.bench_row; chip model is the TPU
        # target -- on the CPU CI host the percentage is a tiny
        # relative-regression signal, not a utilisation claim).
        r_pad = -(-fleet.r // engine.BLOCK_R) * engine.BLOCK_R
        j_cap = int(simulation.safe_max_jobs(
            g, engine.default_params(deadline, budget, types.OPT_COST,
                                     n_users, fleet.r), fleet))
        cell.update(roofline.bench_row(
            r_pad, j_cap, engine.DEFAULT_BATCH,
            int(np.asarray(r.n_scans)), wall))
        name = f"engine_{n_users}u_{n_jobs}j" + extras.get("suffix", "")
        if extras.get("suffix") == "_fail":
            cell["scenario"] = {"mtbf": float(np.asarray(scenario.mtbf)),
                                "mttr": float(np.asarray(scenario.mttr)),
                                "seed": scenario.seed}
            cell["n_failed"] = int(np.asarray(r.n_failed))
            cell["n_resubmits"] = int(np.asarray(r.n_resubmits))
            cell["downtime_total"] = float(np.asarray(r.downtime).sum())
        if extras.get("suffix") == "_trunk":
            cell["scenario"] = {
                "trunk_members": int(np.sum(
                    np.asarray(scenario.trunk_of) == 0)),
                "fault_trace": [list(row) for row
                                in scenario.fault_trace],
                "retry_limit": scenario.retry_limit,
                "backoff_base": scenario.backoff_base,
                "blacklist_cooldown": scenario.blacklist_cooldown,
            }
            cell["n_failed"] = int(np.asarray(r.n_failed))
            cell["n_resubmits"] = int(np.asarray(r.n_resubmits))
            cell["downtime_total"] = float(np.asarray(r.downtime).sum())
        if extras.get("net"):
            cell["scenario"] = {
                "baud_rate": float(np.asarray(scenario.baud_rate)),
                "bg_flows": float(np.asarray(scenario.bg_flows)),
                "in_bytes": extras["in_bytes"],
                "out_bytes": extras["out_bytes"],
            }
            cell["net_cap"] = int(simulation.safe_net_cap(
                g, engine.default_params(deadline, budget,
                                         types.OPT_COST, n_users,
                                         fleet.r), fleet, n_users))
        if fleet_fn is not None:
            cell["fleet"] = "deep_2x80pe"
            cell["j_cap"] = int(simulation.safe_max_jobs(
                g, engine.default_params(deadline, budget,
                                         types.OPT_COST, n_users,
                                         fleet.r), fleet))
        base = None if (scenario is not None or fleet_fn is not None) \
            else golden.get(f"{n_users}u_{n_jobs}j")
        if base is not None:
            cell["pre_superstep_iterations"] = base["iterations"]
            cell["iteration_ratio"] = base["iterations"] / max(steps, 1)
            cell["result_identical"] = bool(
                np.allclose(np.asarray(r.n_done), base["n_done"]) and
                np.allclose(np.asarray(r.spent), base["spent"],
                            rtol=1e-5) and
                np.allclose(np.asarray(r.term_time), base["term_time"],
                            rtol=1e-5))
        report[name] = cell
        derived = (f"events/s~{cell['events_per_sec']:.0f} "
                   f"(compile {compile_s:.1f}s) "
                   f"steps={steps} (k1={steps_k1}, "
                   f"{cell['batch_iteration_ratio']:.2f}x) "
                   f"done={cell['n_done']:.0f} "
                   f"identical={cell['batched_identical']} "
                   f"tel={cell['telemetry_identical']} "
                   f"AI={cell['arith_intensity']:.2f}")
        if "iteration_ratio" in cell:
            derived += f" iters_vs_pre={cell['iteration_ratio']:.2f}x"
        if "n_resubmits" in cell:
            derived += (f" failed={cell['n_failed']} "
                        f"resub={cell['n_resubmits']}")
        out.append((name, wall * 1e6, derived))

    report["_rank_crossover"] = _rank_crossover()
    report["_sweep_bench"] = _sweep_bench()
    report["_strategy_sweep"] = _strategy_sweep()
    out.append(("rank_crossover", 0.0,
                " ".join(f"{k}:p{v['pairwise_o_j2']:.0f}us/"
                         f"b{v['bitonic_o_jlog2j']:.0f}us"
                         for k, v in report["_rank_crossover"].items()
                         if k.startswith("j"))))
    sb = report["_sweep_bench"]
    ds = sb.get("device_scaling", {})
    out.append(("sweep_bench", sb["wall_s_ref"] * 1e6,
                f"select-free speedup={sb['batch_speedup']:.2f}x "
                f"identical={sb['sweep_identical']} "
                f"sharded={sb['sharded_identical']} "
                f"2dev/1dev={ds.get('device_speedup', float('nan')):.2f}x"))
    ss = report["_strategy_sweep"]
    out.append(("strategy_sweep", ss["wall_s"] * 1e6,
                f"7 lanes identical={ss['strategy_identical']} "
                f"table1={ss['table1_ordering']} "
                f"cost_spent={ss['lanes']['cost']['spent']:.0f} "
                f"time_t={ss['lanes']['time']['finish_t']:.0f}"))

    with open(art_path("BENCH_engine.json"), "w") as f:
        json.dump(report, f, indent=1)
    return out
