"""Vectorised DES engine throughput (the core's own perf table).

The 2002 toolkit ran one JVM thread per entity; the array engine's cost
is events/second at fleet scale.  Sized for the 1-core CPU container;
the same jit'd program is the TPU-target workload for kernels.event_scan.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import engine, gridlet, resource, simulation, types


def run():
    fleet = resource.wwg_fleet()
    out = []
    for n_users, n_jobs in ((1, 200), (10, 100), (20, 100)):
        g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=n_jobs,
                              n_users=n_users)
        # warmup/compile
        r = simulation.run_experiment(g, fleet, deadline=2000.0,
                                      budget=22000.0, opt=types.OPT_COST,
                                      n_users=n_users)
        t0 = time.perf_counter()
        r = simulation.run_experiment(g, fleet, deadline=2000.0,
                                      budget=22000.0, opt=types.OPT_COST,
                                      n_users=n_users)
        jax.block_until_ready(r.spent)
        wall = time.perf_counter() - t0
        ev = int(r.gridlets.status.shape[0] * 0 + np.asarray(
            getattr(r, "term_time")).size * 0) or int(np.asarray(
                r.n_done).sum() * 4)  # ~4 events per completed gridlet
        n_events = int(np.asarray(r.gridlets.status).size * 0 +
                       float(np.asarray(r.n_done).sum()) * 4)
        out.append((f"engine_{n_users}u_{n_jobs}j",
                    wall * 1e6,
                    f"events/s~{n_events / max(wall, 1e-9):.0f} "
                    f"done={float(np.asarray(r.n_done).sum()):.0f}"))
    return out
