"""Vectorised DES engine throughput (the core's own perf table).

The 2002 toolkit ran one JVM thread per entity; the array engine's cost
is events/second at fleet scale.  Three WWG scenarios (1 / 20 / 200
users), a failure scenario and a large-J deep-queue scenario are timed
and written to ``benchmarks/artifacts/BENCH_engine.json`` with
steady-state events/sec, compile time, while-loop iterations and
wall-clock, so future PRs have a perf trajectory (the full schema and
the PR-over-PR table live in docs/PERFORMANCE.md).

Timing discipline: the first call is timed separately (``compile_s`` --
jit tracing + XLA compile + the first run) from the steady-state run
that follows (``wall_s``/``events_per_sec``).  Folding compilation into
the throughput number hides the real per-iteration constant, which is
what the engine work optimises.

Each scenario runs twice more: once with the k-step speculative
superstep batching that is the engine default
(``engine.DEFAULT_BATCH``) -- the timed run -- and once with
``batch=1`` to record the iteration-count baseline and assert the two
runs are bit-for-bit identical (``batched_identical``).  The 20-user
cell is additionally compared against the recorded pre-superstep engine
(tests/data/golden_pre_refactor.json): results must stay identical
while while-loop iterations keep shrinking (``iteration_ratio``).

Two microbench sections ride along under the ``_`` prefix (skipped by
the per-scenario renderer columns, rendered as their own tables):

* ``_rank_crossover`` -- XLA-compiled wall-clock of the three exact
  in-kernel ranking algorithms (pairwise O(J^2), bitonic O(J log^2 J),
  lexsort O(J log J)) across J, measuring the
  ``event_scan.RANK_BITONIC_MIN_J`` crossover claim;
* ``_sweep_vmap`` -- ``simulation.sweep`` (vmapped grid) at batch=1 vs
  the engine default, documenting why ``sweep``/``run_inner`` keep
  ``batch=1`` (under vmap, conds lower to selects: both branches run).

Sized for the 1-core CPU container (the kernel routes through its XLA
fallback there); the same jit'd program is the TPU-target workload for
kernels.event_scan / event_scan_slab.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gridlet, resource, simulation, types
from repro.kernels import event_scan as event_scan_mod

from .common import art_path

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "tests", "data",
                           "golden_pre_refactor.json")


def _deep_fleet():
    """Few resources, deep per-resource job tables: 2 x 80-PE
    time-shared resources.  With 4 users the broker stages up to
    ``4 * 2 * 80 = 640`` concurrent jobs per resource, so the job-slot
    axis J reaches 640 -- strictly past RANK_BITONIC_MIN_J = 512, so
    Pallas lane-pads it to 1024 and selects the bitonic in-kernel rank
    on TPU; on CPU it is the widest lexsort the XLA fallback sees."""
    return resource.make_fleet([80, 80], [100.0, 120.0], [1.0, 2.0],
                               types.TIME_SHARED)


# (n_users, n_jobs_per_user, scenario, fleet_fn, deadline, budget,
# extras): the failure cell re-runs the 20-user workload with the
# failure/recovery event source live (MTBF=500, MTTR=25) so the perf
# trajectory tracks the dynamic-resource path -- including how far
# dense interference degrades the speculation horizon -- not just the
# static fleet; the 4-user cell is the large-J rank-crossover workload;
# the net cell re-runs the 20-user workload with real file payloads
# over the contention-aware fair-share links (suffix "_net": the
# NETWORK event source + link_scan kernel live in the hot path, with
# one phantom background flow per link).  ``extras`` keys: suffix,
# in_bytes/out_bytes (payloads; default 0), net (enable the network
# subsystem with an auto-sized transfer table).
SCENARIOS = (
    (1, 200, None, None, 2000.0, 22000.0, None),
    (20, 100, None, None, 2000.0, 22000.0, None),
    (200, 10, None, None, 2000.0, 22000.0, None),
    (20, 100, simulation.Scenario(mtbf=500.0, mttr=25.0, seed=1), None,
     2000.0, 22000.0, dict(suffix="_fail")),
    (4, 512, None, _deep_fleet, 2000.0, 500000.0, None),
    (20, 100, simulation.Scenario(baud_rate=28_000.0, bg_flows=1.0),
     None, 2000.0, 22000.0,
     dict(suffix="_net", net=True, in_bytes=200_000.0,
          out_bytes=100_000.0)),
)


def _one(fleet, g, n_users, scenario, batch, deadline, budget,
         net_cap=0, timed=True):
    kw = dict(deadline=deadline, budget=budget, opt=types.OPT_COST,
              n_users=n_users, scenario=scenario, batch=batch,
              net_cap=net_cap)
    t0 = time.perf_counter()
    r = simulation.run_experiment(g, fleet, **kw)      # compile + run
    jax.block_until_ready(r.spent)
    first = time.perf_counter() - t0
    if not timed:       # baseline pass: results only, skip the re-run
        return r, float("nan"), float("nan")
    wall = float("inf")
    for _ in range(2):  # best-of-2: damp container load noise
        t0 = time.perf_counter()
        r = simulation.run_experiment(g, fleet, **kw)  # steady state
        jax.block_until_ready(r.spent)
        wall = min(wall, time.perf_counter() - t0)
    return r, wall, max(first - wall, 0.0)


def _rank_crossover():
    """Wall-clock of the three exact ranking algorithms, XLA-compiled
    on [8, J] rows -- the measured basis of the
    ``RANK_BITONIC_MIN_J`` in-kernel crossover (docs/PERFORMANCE.md).
    The bitonic needs a power-of-two width, so J sweeps powers of 2."""
    rows = {}
    rng = np.random.RandomState(0)
    algos = {
        "pairwise_o_j2": event_scan_mod._pairwise_rank,
        "bitonic_o_jlog2j": event_scan_mod._bitonic_rank,
        "lexsort_o_jlogj": event_scan_mod._lexsort_rank,
    }
    for j in (64, 128, 256, 512, 1024):
        rem = jnp.asarray(rng.exponential(50.0, (8, j)), jnp.float32)
        tie = jnp.asarray(
            rng.permutation(8 * j).reshape(8, j), jnp.float32)
        valid = rem > 10.0
        cell = {}
        for name, fn in algos.items():
            f = jax.jit(lambda rem, tie, valid, fn=fn:
                        fn(rem, tie, valid)[0])
            jax.block_until_ready(f(rem, tie, valid))
            t0 = time.perf_counter()
            n = 50
            for _ in range(n):
                out = f(rem, tie, valid)
            jax.block_until_ready(out)
            cell[name] = (time.perf_counter() - t0) / n * 1e6  # us
        rows[f"j{j}"] = cell
    rows["crossover_j"] = event_scan_mod.RANK_BITONIC_MIN_J
    return rows


def _sweep_vmap():
    """sweep (vmapped deadline x budget grid) at batch=1 vs the engine
    default batch: measures whether speculation pays under vmap (conds
    lower to selects -- both branches execute, so every skipped sort
    runs anyway) and backs the ``sweep``/``run_inner`` ``batch=1``
    default (docs/PERFORMANCE.md).  A reduced 20-user workload keeps
    the cell CI-sized -- the vmap effect is structural, not
    scale-dependent."""
    fleet = resource.wwg_fleet()
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=25, n_users=20)
    deadlines = jnp.asarray([1500.0, 2000.0])
    budgets = jnp.asarray([15000.0, 22000.0])
    out = {}
    ref = None
    for batch in (1, engine.DEFAULT_BATCH):
        kw = dict(opt=types.OPT_COST, n_users=20, batch=batch)
        r = simulation.sweep(g, fleet, deadlines, budgets, **kw)
        jax.block_until_ready(r.spent)
        t0 = time.perf_counter()
        r = simulation.sweep(g, fleet, deadlines, budgets, **kw)
        jax.block_until_ready(r.spent)
        out[f"wall_s_batch{batch}"] = time.perf_counter() - t0
        if ref is None:
            ref = r
        else:
            out["identical"] = bool(
                np.array_equal(np.asarray(r.n_done),
                               np.asarray(ref.n_done)) and
                np.array_equal(np.asarray(r.spent),
                               np.asarray(ref.spent)))
    out["batch_speedup"] = (out["wall_s_batch1"] /
                            out[f"wall_s_batch{engine.DEFAULT_BATCH}"])
    return out


def run():
    try:
        golden = json.load(open(GOLDEN_PATH))
    except OSError:
        golden = {}
    report, out = {}, []
    for n_users, n_jobs, scenario, fleet_fn, deadline, budget, extras \
            in SCENARIOS:
        extras = extras or {}
        fleet = resource.wwg_fleet() if fleet_fn is None else fleet_fn()
        g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=n_jobs,
                              n_users=n_users,
                              in_bytes=extras.get("in_bytes", 0.0),
                              out_bytes=extras.get("out_bytes", 0.0))
        net_cap = None if extras.get("net") else 0  # None = auto-size
        r, wall, compile_s = _one(fleet, g, n_users, scenario,
                                  engine.DEFAULT_BATCH, deadline, budget,
                                  net_cap=net_cap)
        r1, _, _ = _one(fleet, g, n_users, scenario, 1, deadline,
                        budget, net_cap=net_cap, timed=False)
        events = int(np.asarray(r.n_events))
        steps = int(np.asarray(r.n_steps))
        steps_k1 = int(np.asarray(r1.n_steps))
        cell = {
            "n_users": n_users,
            "n_jobs_per_user": n_jobs,
            "batch": engine.DEFAULT_BATCH,
            "wall_s": wall,
            "compile_s": compile_s,
            "events": events,
            "supersteps": steps,
            "spec_supersteps": int(np.asarray(r.n_spec)),
            "supersteps_k1": steps_k1,
            "batch_iteration_ratio": steps_k1 / max(steps, 1),
            "batched_identical": bool(
                np.array_equal(np.asarray(r.n_done),
                               np.asarray(r1.n_done)) and
                np.array_equal(np.asarray(r.spent),
                               np.asarray(r1.spent)) and
                np.array_equal(np.asarray(r.term_time),
                               np.asarray(r1.term_time)) and
                int(np.asarray(r.n_events)) == int(np.asarray(r1.n_events))),
            "events_per_sec": events / max(wall, 1e-9),
            "events_per_superstep": events / max(steps, 1),
            "scan_reseeds": int(np.asarray(r.n_reseeds)),
            "slab_hit_rate": 1.0 - (int(np.asarray(r.n_reseeds)) /
                                    max(int(np.asarray(r.n_scans)), 1)),
            "n_done": float(np.asarray(r.n_done).sum()),
            "spent": float(np.asarray(r.spent).sum()),
            "overflow": int(np.asarray(r.overflow)),
        }
        name = f"engine_{n_users}u_{n_jobs}j" + extras.get("suffix", "")
        if extras.get("suffix") == "_fail":
            cell["scenario"] = {"mtbf": float(np.asarray(scenario.mtbf)),
                                "mttr": float(np.asarray(scenario.mttr)),
                                "seed": scenario.seed}
            cell["n_failed"] = int(np.asarray(r.n_failed))
            cell["n_resubmits"] = int(np.asarray(r.n_resubmits))
            cell["downtime_total"] = float(np.asarray(r.downtime).sum())
        if extras.get("net"):
            cell["scenario"] = {
                "baud_rate": float(np.asarray(scenario.baud_rate)),
                "bg_flows": float(np.asarray(scenario.bg_flows)),
                "in_bytes": extras["in_bytes"],
                "out_bytes": extras["out_bytes"],
            }
            cell["net_cap"] = int(simulation.safe_net_cap(
                g, engine.default_params(deadline, budget,
                                         types.OPT_COST, n_users,
                                         fleet.r), fleet, n_users))
        if fleet_fn is not None:
            cell["fleet"] = "deep_2x80pe"
            cell["j_cap"] = int(simulation.safe_max_jobs(
                g, engine.default_params(deadline, budget,
                                         types.OPT_COST, n_users,
                                         fleet.r), fleet))
        base = None if (scenario is not None or fleet_fn is not None) \
            else golden.get(f"{n_users}u_{n_jobs}j")
        if base is not None:
            cell["pre_superstep_iterations"] = base["iterations"]
            cell["iteration_ratio"] = base["iterations"] / max(steps, 1)
            cell["result_identical"] = bool(
                np.allclose(np.asarray(r.n_done), base["n_done"]) and
                np.allclose(np.asarray(r.spent), base["spent"],
                            rtol=1e-5) and
                np.allclose(np.asarray(r.term_time), base["term_time"],
                            rtol=1e-5))
        report[name] = cell
        derived = (f"events/s~{cell['events_per_sec']:.0f} "
                   f"(compile {compile_s:.1f}s) "
                   f"steps={steps} (k1={steps_k1}, "
                   f"{cell['batch_iteration_ratio']:.2f}x) "
                   f"done={cell['n_done']:.0f} "
                   f"identical={cell['batched_identical']}")
        if "iteration_ratio" in cell:
            derived += f" iters_vs_pre={cell['iteration_ratio']:.2f}x"
        if "n_resubmits" in cell:
            derived += (f" failed={cell['n_failed']} "
                        f"resub={cell['n_resubmits']}")
        out.append((name, wall * 1e6, derived))

    report["_rank_crossover"] = _rank_crossover()
    report["_sweep_vmap"] = _sweep_vmap()
    out.append(("rank_crossover", 0.0,
                " ".join(f"{k}:p{v['pairwise_o_j2']:.0f}us/"
                         f"b{v['bitonic_o_jlog2j']:.0f}us"
                         for k, v in report["_rank_crossover"].items()
                         if k.startswith("j"))))
    out.append(("sweep_vmap", report["_sweep_vmap"]["wall_s_batch1"] * 1e6,
                f"batch{engine.DEFAULT_BATCH}/batch1 speedup="
                f"{report['_sweep_vmap']['batch_speedup']:.2f}x "
                f"identical={report['_sweep_vmap'].get('identical')}"))

    with open(art_path("BENCH_engine.json"), "w") as f:
        json.dump(report, f, indent=1)
    return out
