"""Vectorised DES engine throughput (the core's own perf table).

The 2002 toolkit ran one JVM thread per entity; the array engine's cost
is events/second at fleet scale.  Three WWG scenarios (1 / 20 / 200
users) plus a failure scenario are timed and written to
``benchmarks/artifacts/BENCH_engine.json`` with events/sec, while-loop
iterations and wall-clock, so future PRs have a perf trajectory (the
full schema and the PR-over-PR table live in docs/PERFORMANCE.md).

Each scenario runs twice: once with the k-step speculative superstep
batching that is the engine default (``engine.DEFAULT_BATCH``) -- the
timed run -- and once with ``batch=1`` to record the iteration-count
baseline and assert the two runs are bit-for-bit identical
(``batched_identical``).  The 20-user cell is additionally compared
against the recorded pre-superstep engine
(tests/data/golden_pre_refactor.json): results must stay identical
while while-loop iterations keep shrinking (``iteration_ratio``).

Sized for the 1-core CPU container (the kernel routes through its XLA
fallback there); the same jit'd program is the TPU-target workload for
kernels.event_scan / event_scan_slab.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import engine, gridlet, resource, simulation, types

from .common import art_path

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "tests", "data",
                           "golden_pre_refactor.json")
# (n_users, n_jobs_per_user, scenario): the trailing cell re-runs the
# 20-user workload with the failure/recovery event source live
# (MTBF=500, MTTR=25) so the perf trajectory tracks the dynamic-
# resource path -- including how far dense interference degrades the
# speculation horizon -- not just the static fleet.
SCENARIOS = (
    (1, 200, None),
    (20, 100, None),
    (200, 10, None),
    (20, 100, simulation.Scenario(mtbf=500.0, mttr=25.0, seed=1)),
)


def _one(fleet, n_users, n_jobs, scenario, batch, timed=True):
    g = gridlet.task_farm(jax.random.PRNGKey(3), n_jobs=n_jobs,
                          n_users=n_users)
    kw = dict(deadline=2000.0, budget=22000.0, opt=types.OPT_COST,
              n_users=n_users, scenario=scenario, batch=batch)
    r = simulation.run_experiment(g, fleet, **kw)      # warmup/compile
    jax.block_until_ready(r.spent)
    if not timed:       # baseline pass: results only, skip the re-run
        return r, float("nan")
    t0 = time.perf_counter()
    r = simulation.run_experiment(g, fleet, **kw)
    jax.block_until_ready(r.spent)
    wall = time.perf_counter() - t0
    return r, wall


def run():
    fleet = resource.wwg_fleet()
    try:
        golden = json.load(open(GOLDEN_PATH))
    except OSError:
        golden = {}
    report, out = {}, []
    for n_users, n_jobs, scenario in SCENARIOS:
        r, wall = _one(fleet, n_users, n_jobs, scenario,
                       engine.DEFAULT_BATCH)
        r1, _ = _one(fleet, n_users, n_jobs, scenario, 1, timed=False)
        events = int(np.asarray(r.n_events))
        steps = int(np.asarray(r.n_steps))
        steps_k1 = int(np.asarray(r1.n_steps))
        cell = {
            "n_users": n_users,
            "n_jobs_per_user": n_jobs,
            "batch": engine.DEFAULT_BATCH,
            "wall_s": wall,
            "events": events,
            "supersteps": steps,
            "spec_supersteps": int(np.asarray(r.n_spec)),
            "supersteps_k1": steps_k1,
            "batch_iteration_ratio": steps_k1 / max(steps, 1),
            "batched_identical": bool(
                np.array_equal(np.asarray(r.n_done),
                               np.asarray(r1.n_done)) and
                np.array_equal(np.asarray(r.spent),
                               np.asarray(r1.spent)) and
                np.array_equal(np.asarray(r.term_time),
                               np.asarray(r1.term_time)) and
                int(np.asarray(r.n_events)) == int(np.asarray(r1.n_events))),
            "events_per_sec": events / max(wall, 1e-9),
            "events_per_superstep": events / max(steps, 1),
            "n_done": float(np.asarray(r.n_done).sum()),
            "spent": float(np.asarray(r.spent).sum()),
            "overflow": int(np.asarray(r.overflow)),
        }
        name = f"engine_{n_users}u_{n_jobs}j"
        if scenario is not None:
            name += "_fail"
            cell["scenario"] = {"mtbf": float(np.asarray(scenario.mtbf)),
                                "mttr": float(np.asarray(scenario.mttr)),
                                "seed": scenario.seed}
            cell["n_failed"] = int(np.asarray(r.n_failed))
            cell["n_resubmits"] = int(np.asarray(r.n_resubmits))
            cell["downtime_total"] = float(np.asarray(r.downtime).sum())
        base = None if scenario is not None else \
            golden.get(f"{n_users}u_{n_jobs}j")
        if base is not None:
            cell["pre_superstep_iterations"] = base["iterations"]
            cell["iteration_ratio"] = base["iterations"] / max(steps, 1)
            cell["result_identical"] = bool(
                np.allclose(np.asarray(r.n_done), base["n_done"]) and
                np.allclose(np.asarray(r.spent), base["spent"],
                            rtol=1e-5) and
                np.allclose(np.asarray(r.term_time), base["term_time"],
                            rtol=1e-5))
        report[name] = cell
        derived = (f"events/s~{cell['events_per_sec']:.0f} "
                   f"steps={steps} (k1={steps_k1}, "
                   f"{cell['batch_iteration_ratio']:.2f}x) "
                   f"done={cell['n_done']:.0f} "
                   f"identical={cell['batched_identical']}")
        if "iteration_ratio" in cell:
            derived += f" iters_vs_pre={cell['iteration_ratio']:.2f}x"
        if "n_resubmits" in cell:
            derived += (f" failed={cell['n_failed']} "
                        f"resub={cell['n_resubmits']}")
        out.append((name, wall * 1e6, derived))

    with open(art_path("BENCH_engine.json"), "w") as f:
        json.dump(report, f, indent=1)
    return out
