"""Roofline analysis (EXPERIMENTS.md section Roofline).

Reads the dry-run artifacts and derives, per (arch x shape) on the
single-pod 16x16 mesh, the three per-chip roofline terms:

  compute    = weighted HLO dot-FLOPs / 197e12 FLOP/s    (bf16 MXU peak)
  memory     = weighted HLO HBM bytes / 819e9 B/s
  collective = ring-model transfer bytes / 50e9 B/s      (per-link ICI)

plus MODEL_FLOPS = 6 * N(_active) * tokens and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs.  "roofline fraction" = (MODEL_FLOPS/peak) /
dominant-term time: how close the cell is to the compute roofline given
its actual bottleneck.  FLOP/byte counts are execution-weighted from the
compiled HLO (launch.hlo), not cost_analysis, which does not multiply
scan trip counts.
"""
from __future__ import annotations

import glob
import json
import os

from .common import art_path, write_csv

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / ICI link

DRYRUN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "artifacts", "dryrun", "pod16x16")

_NOTE = {
    "compute": ("compute-bound: raise MXU utilisation (larger blocks, "
                "bf16 grad reduction frees headroom only indirectly)"),
    "memory": ("HBM-bound: fuse/remat to cut activation traffic, or "
               "shard the dominant tensor further"),
    "collective": ("collective-bound: cut FSDP regather (cast-before-"
                   "gather), reduce-scatter grads, overlap DCN"),
}


def analyze(record: dict) -> dict:
    n_dev = record["n_devices"]
    kind = record["kind"]
    tokens = record["global_batch"] * (record["seq_len"]
                                       if kind != "decode" else 1)
    n_params = record["params_active"]
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    model_flops = mult * n_params * tokens            # global
    model_per_chip = model_flops / n_dev

    flops = record.get("weighted", {}).get("dot_flops", 0.0)
    hbm = record.get("weighted", {}).get("hbm_bytes", 0.0)
    coll = record.get("collective_total", 0.0)

    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    t_dom = max(terms.values())
    frac = (model_per_chip / PEAK_FLOPS) / t_dom if t_dom > 0 else 0.0
    return {
        "arch": record["arch"], "shape": record["shape"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": model_flops,
        "useful_ratio": model_per_chip / flops if flops else 0.0,
        "roofline_fraction": frac,
        "note": _NOTE[dom],
        "temp_gb": record.get("memory", {}).get(
            "temp_size_in_bytes", 0) / 1e9,
    }


def table():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": rec["skip_reason"]})
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": f"FAILED: {rec.get('error', '?')[:60]}"})
            continue
        rows.append(analyze(rec))
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | useful ratio | roofline frac | temp GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skip" in r:
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"SKIP | - | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['temp_gb']:.1f} |")
    return "\n".join(out)


def run():
    rows = table()
    done = [r for r in rows if "skip" not in r]
    if not done:
        return [("roofline", 0.0, "no dry-run artifacts yet")]
    csv_rows = [[r["arch"], r["shape"], r["compute_s"], r["memory_s"],
                 r["collective_s"], r["dominant"], r["model_flops"],
                 r["useful_ratio"], r["roofline_fraction"], r["temp_gb"],
                 r["note"]] for r in done]
    write_csv(art_path("roofline.csv"),
              ["arch", "shape", "compute_s", "memory_s", "collective_s",
               "dominant", "model_flops", "useful_ratio",
               "roofline_fraction", "temp_gb", "note"], csv_rows)
    with open(art_path("roofline.md"), "w") as f:
        f.write(markdown(rows))
    worst = min(done, key=lambda r: r["roofline_fraction"])
    coll_bound = [r for r in done if r["dominant"] == "collective"]
    out = [("roofline_cells", 0.0,
            f"{len(done)} analysed / {len(rows) - len(done)} skipped")]
    out.append(("roofline_worst_fraction", 0.0,
                f"{worst['arch']}/{worst['shape']}"
                f"={worst['roofline_fraction']:.3f}"))
    out.append(("roofline_collective_bound", 0.0,
                f"{len(coll_bound)} cells"))
    return out
