"""Roofline analysis for the engine's hot kernel (docs/PERFORMANCE.md).

Two sections:

**Engine slab roofline** (always runs): an analytic per-superstep
FLOP/byte model of the k-wave slab solve inside
``kernels.event_scan``, evaluated for both formulations --

* sequential forward substitution: k *dependent* steps, O(k) FLOPs
  each per resource row;
* associative wave-compose scan: ``ceil(log2 k)`` dependent levels of
  (k+1)x(k+1) matrix products (``_compose_waves``), O(k^3) FLOPs per
  row total

-- against the TPU chip model below.  Both are far under the machine
balance (the slab tables stream from HBM), so the scan's extra FLOPs
are free and the dependent-step depth is the term that matters; the
*measured* side of that claim (``slab_depth_mean`` / ``scan_depth``
per bench cell) is read from the committed
``benchmarks/artifacts/BENCH_engine.json`` when present.

**Dry-run roofline** (optional): the original artifact-driven table --
per (arch x shape) compute / memory / collective terms from compiled
HLO dry-run records under ``artifacts/dryrun/``.  No such artifacts
are committed; the section renders only if a future PR adds them.
"""
from __future__ import annotations

import glob
import json
import math
import os

from .common import art_path, write_csv

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / ICI link
BALANCE = PEAK_FLOPS / HBM_BW   # FLOP/byte at the roofline ridge

_HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN = os.path.join(_HERE, "artifacts", "dryrun", "pod16x16")
BENCH_PATH = os.path.join(_HERE, "artifacts", "BENCH_engine.json")

_NOTE = {
    "compute": ("compute-bound: raise MXU utilisation (larger blocks, "
                "bf16 grad reduction frees headroom only indirectly)"),
    "memory": ("HBM-bound: fuse/remat to cut activation traffic, or "
               "shard the dominant tensor further"),
    "collective": ("collective-bound: cut FSDP regather (cast-before-"
                   "gather), reduce-scatter grads, overlap DCN"),
}


# -- engine slab section ----------------------------------------------

def slab_cost(r_pad: int, j: int, k: int) -> dict:
    """Per-superstep FLOPs / HBM bytes of the k-wave slab solve on an
    ``[r_pad, j]`` job-slot table, for both formulations.

    Shared streaming cost: the kernel reads the slot table once per
    superstep (remaining, rank, valid, column, rates -- ~6 f32 planes)
    and writes the [r_pad, k] wave outputs.  Solve cost: the
    sequential path does one fused multiply-add per earlier wave per
    dependent step (2k(k+1) FLOPs/row over k steps); the associative
    path builds k (k+1)x(k+1) wave matrices and composes k-1 of them
    (2(k+1)^3 FLOPs each per row) over ``ceil(log2 k)`` dependent
    levels.  Intensity is FLOPs/byte against the streamed table.
    """
    f32 = 4
    table_bytes = (6 * r_pad * j + 2 * r_pad * k) * f32
    seq = {
        "flops": 2.0 * r_pad * k * (k + 1),
        "depth": k,
    }
    assoc = {
        "flops": 2.0 * r_pad * max(k - 1, 1) * (k + 1) ** 3,
        "depth": int(math.ceil(math.log2(max(k, 2)))),
    }
    for d in (seq, assoc):
        d["bytes"] = table_bytes + r_pad * k * (k + 1) ** 2 * f32
        d["intensity"] = d["flops"] / d["bytes"]
        d["compute_s"] = d["flops"] / PEAK_FLOPS
        d["memory_s"] = d["bytes"] / HBM_BW
    return {"r_pad": r_pad, "j": j, "k": k, "seq": seq, "assoc": assoc,
            "machine_balance": BALANCE}


def bench_row(r_pad: int, j: int, k: int, supersteps: int,
              wall_s: float) -> dict:
    """Roofline columns for one BENCH_engine cell: grounds the measured
    wall time of a run (``supersteps`` scans at the ``[r_pad, j]``
    job-slot shape, slab depth ``k``) against :func:`slab_cost`'s
    analytic per-superstep model of the associative slab solve.

    * ``arith_intensity`` -- FLOPs per HBM byte of one slab solve;
    * ``pct_of_roofline`` -- achieved FLOP/s (analytic FLOPs x
      measured supersteps / wall) over the intensity-capped ceiling
      ``min(PEAK_FLOPS, intensity x HBM_BW)``;
    * ``roofline_bound`` -- which roof applies at this intensity.

    The chip model is the TPU target; on the CPU CI host the percentage
    is honest-but-tiny and serves as a relative-regression signal, not
    an absolute utilisation claim.
    """
    c = slab_cost(r_pad, j, k)["assoc"]
    achieved = c["flops"] * supersteps / max(wall_s, 1e-12)
    ceiling = min(PEAK_FLOPS, c["intensity"] * HBM_BW)
    return {
        "arith_intensity": c["intensity"],
        "pct_of_roofline": 100.0 * achieved / ceiling,
        "roofline_bound": ("memory" if c["intensity"] < BALANCE
                           else "compute"),
    }


def engine_rows():
    """Analytic slab rooflines at the bench's canonical shapes, plus
    the measured depth counters from the committed bench artifact."""
    shapes = (("wwg_20u", 8, 128), ("deep_4u", 8, 1024))
    rows = []
    for name, r_pad, j in shapes:
        c = slab_cost(r_pad, j, 8)
        rows.append((f"roofline_slab_{name}", 0.0,
                     f"intensity seq={c['seq']['intensity']:.3f} "
                     f"assoc={c['assoc']['intensity']:.3f} "
                     f"FLOP/B (balance {BALANCE:.0f}) "
                     f"depth {c['seq']['depth']}->"
                     f"{c['assoc']['depth']} dependent steps"))
    try:
        report = json.load(open(BENCH_PATH))
    except OSError:
        return rows
    for name, cell in sorted(report.items()):
        if name.startswith("_") or not isinstance(cell, dict):
            continue
        if "slab_depth_mean" not in cell:
            continue
        rows.append((f"roofline_depth_{name}", 0.0,
                     f"slab_depth_mean={cell['slab_depth_mean']:.2f} "
                     f"scan_depth={cell['scan_depth']}"))
    return rows


# -- dry-run section (artifact-driven; optional) ----------------------

def analyze(record: dict) -> dict:
    n_dev = record["n_devices"]
    kind = record["kind"]
    tokens = record["global_batch"] * (record["seq_len"]
                                       if kind != "decode" else 1)
    n_params = record["params_active"]
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    model_flops = mult * n_params * tokens            # global
    model_per_chip = model_flops / n_dev

    flops = record.get("weighted", {}).get("dot_flops", 0.0)
    hbm = record.get("weighted", {}).get("hbm_bytes", 0.0)
    coll = record.get("collective_total", 0.0)

    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    t_dom = max(terms.values())
    frac = (model_per_chip / PEAK_FLOPS) / t_dom if t_dom > 0 else 0.0
    return {
        "arch": record["arch"], "shape": record["shape"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": model_flops,
        "useful_ratio": model_per_chip / flops if flops else 0.0,
        "roofline_fraction": frac,
        "note": _NOTE[dom],
        "temp_gb": record.get("memory", {}).get(
            "temp_size_in_bytes", 0) / 1e9,
    }


def table():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": rec["skip_reason"]})
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": f"FAILED: {rec.get('error', '?')[:60]}"})
            continue
        rows.append(analyze(rec))
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | useful ratio | roofline frac | temp GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skip" in r:
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"SKIP | - | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['temp_gb']:.1f} |")
    return "\n".join(out)


def run():
    out = engine_rows()
    rows = table()
    done = [r for r in rows if "skip" not in r]
    if not done:
        out.append(("roofline_dryrun", 0.0, "no dry-run artifacts"))
        return out
    csv_rows = [[r["arch"], r["shape"], r["compute_s"], r["memory_s"],
                 r["collective_s"], r["dominant"], r["model_flops"],
                 r["useful_ratio"], r["roofline_fraction"], r["temp_gb"],
                 r["note"]] for r in done]
    write_csv(art_path("roofline.csv"),
              ["arch", "shape", "compute_s", "memory_s", "collective_s",
               "dominant", "model_flops", "useful_ratio",
               "roofline_fraction", "temp_gb", "note"], csv_rows)
    with open(art_path("roofline.md"), "w") as f:
        f.write(markdown(rows))
    worst = min(done, key=lambda r: r["roofline_fraction"])
    coll_bound = [r for r in done if r["dominant"] == "collective"]
    out.append(("roofline_cells", 0.0,
                f"{len(done)} analysed / {len(rows) - len(done)} skipped"))
    out.append(("roofline_worst_fraction", 0.0,
                f"{worst['arch']}/{worst['shape']}"
                f"={worst['roofline_fraction']:.3f}"))
    out.append(("roofline_collective_bound", 0.0,
                f"{len(coll_bound)} cells"))
    return out
