"""Paper Table 1: the canonical 3-Gridlet schedule on 2x1-MIPS PEs."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine, gridlet, resource, types

from .common import art_path, time_call, write_csv

ARRIVALS = jnp.array([0.0, 4.0, 7.0])
EXPECTED = {
    types.TIME_SHARED: ([0.0, 4.0, 7.0], [10.0, 14.0, 18.0]),
    types.SPACE_SHARED: ([0.0, 4.0, 10.0], [10.0, 12.5, 19.5]),
}


def run():
    rows, out = [], []
    for policy, pname in ((types.TIME_SHARED, "time_shared"),
                          (types.SPACE_SHARED, "space_shared")):
        g = gridlet.make_batch([10.0, 8.5, 9.5])
        fleet = resource.table1_resource(policy)
        res = engine.run_direct(g, fleet, 0, ARRIVALS, max_events=64)
        us = time_call(lambda: engine.run_direct(
            g, fleet, 0, ARRIVALS, max_events=64))
        starts = [round(float(x), 2) for x in res.gridlets.start]
        fins = [round(float(x), 2) for x in res.gridlets.finish]
        ok = (starts == EXPECTED[policy][0]
              and fins == EXPECTED[policy][1])
        for i in range(3):
            rows.append([pname, f"G{i+1}", [10.0, 8.5, 9.5][i],
                         float(ARRIVALS[i]), starts[i], fins[i],
                         round(fins[i] - float(ARRIVALS[i]), 2)])
        out.append((f"table1_{pname}", us,
                    f"finish={'/'.join(str(f) for f in fins)}"
                    f" match={ok}"))
        assert ok, f"Table 1 mismatch for {pname}: {fins}"
    write_csv(art_path("table1.csv"),
              ["policy", "gridlet", "length_mi", "arrival", "start",
               "finish", "elapsed"], rows)
    return out
