"""Render ``BENCH_engine.json`` as a GitHub-flavoured markdown table.

Used by CI to surface the engine perf trajectory in the Actions job
summary (``$GITHUB_STEP_SUMMARY``) so events/sec or batching regressions
are visible directly in the PR checks:

  # committed artifact only
  python benchmarks/render_bench.py benchmarks/artifacts/BENCH_engine.json

  # fresh run vs the committed artifact (delta columns)
  python benchmarks/render_bench.py fresh.json --baseline committed.json

Pure stdlib; schema documented in docs/PERFORMANCE.md.
"""
from __future__ import annotations

import argparse
import json


def _fmt(v, nd=0):
    if v is None:
        return "--"
    return f"{v:.{nd}f}"


def _delta(new, old):
    """Signed percentage delta; positive = new is larger."""
    if new is None or old in (None, 0):
        return "--"
    pct = 100.0 * (new - old) / old
    return f"{pct:+.1f}%"


def render(report: dict, baseline: dict | None = None) -> str:
    cols = ["scenario", "events/sec", "compile s", "while-loop iters",
            "events/superstep", "events", "identical"]
    if baseline is not None:
        cols += ["Δ events/sec", "Δ events/superstep"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for name, cell in sorted(report.items()):
        if name.startswith("_"):
            continue            # microbench sections rendered below
        eps = cell.get("events_per_sec")
        epb = cell.get("events_per_superstep")
        ident = cell.get("batched_identical",
                         cell.get("result_identical"))
        row = [name, _fmt(eps), _fmt(cell.get("compile_s"), 1),
               _fmt(cell.get("supersteps")),
               _fmt(epb, 2), _fmt(cell.get("events")),
               "--" if ident is None else ("yes" if ident else "**NO**")]
        if baseline is not None:
            base = baseline.get(name, {})
            row += [_delta(eps, base.get("events_per_sec")),
                    _delta(epb, base.get("events_per_superstep"))]
        lines.append("| " + " | ".join(row) + " |")
    if baseline is not None:
        lines.append("")
        lines.append("Δ columns compare against the committed artifact "
                     "(wall-clock varies with runner load; "
                     "events/superstep is deterministic).")
    rc = report.get("_rank_crossover")
    if rc:
        lines += ["", "#### In-kernel rank crossover (us per call, "
                  "[8, J] rows, XLA CPU; crossover constant J = "
                  f"{rc.get('crossover_j')})", ""]
        lines += ["| J | pairwise O(J^2) | bitonic O(J log^2 J) | "
                  "lexsort O(J log J) |", "|---|---|---|---|"]
        for k, v in sorted(rc.items(),
                           key=lambda kv: (len(kv[0]), kv[0])):
            if not k.startswith("j"):
                continue
            lines.append(
                f"| {k[1:]} | {_fmt(v.get('pairwise_o_j2'), 1)} | "
                f"{_fmt(v.get('bitonic_o_jlog2j'), 1)} | "
                f"{_fmt(v.get('lexsort_o_jlogj'), 1)} |")
    sv = report.get("_sweep_vmap")
    if sv:
        lines += ["", "#### sweep under vmap (2x2 grid, 20u scenario)",
                  "", "| batch=1 wall s | batched wall s | speedup | "
                  "identical |", "|---|---|---|---|"]
        walls = sorted(k for k in sv if k.startswith("wall_s_batch"))
        lines.append(
            "| " + " | ".join(
                [_fmt(sv.get(walls[0]), 2), _fmt(sv.get(walls[-1]), 2),
                 f"{sv.get('batch_speedup', 0):.2f}x",
                 str(sv.get("identical"))]) + " |")
    return "\n".join(lines)


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("artifact", help="BENCH_engine.json to render")
    p.add_argument("--baseline", default=None,
                   help="optional baseline BENCH_engine.json for deltas")
    p.add_argument("--title", default="Engine throughput "
                   "(benchmarks/artifacts/BENCH_engine.json)")
    args = p.parse_args()
    report = json.load(open(args.artifact))
    baseline = json.load(open(args.baseline)) if args.baseline else None
    print(f"### {args.title}\n")
    print(render(report, baseline))


if __name__ == "__main__":
    main()
