"""Render ``BENCH_engine.json`` as a GitHub-flavoured markdown table.

Used by CI to surface the engine perf trajectory in the Actions job
summary (``$GITHUB_STEP_SUMMARY``) so events/sec or batching regressions
are visible directly in the PR checks:

  # committed artifact only
  python benchmarks/render_bench.py benchmarks/artifacts/BENCH_engine.json

  # fresh run vs the committed artifact (delta columns)
  python benchmarks/render_bench.py fresh.json --baseline committed.json

Pure stdlib; schema documented in docs/PERFORMANCE.md.
"""
from __future__ import annotations

import argparse
import json


def _fmt(v, nd=0):
    if v is None:
        return "--"
    return f"{v:.{nd}f}"


def _delta(new, old):
    """Signed percentage delta; positive = new is larger."""
    if new is None or old in (None, 0):
        return "--"
    pct = 100.0 * (new - old) / old
    return f"{pct:+.1f}%"


def render(report: dict, baseline: dict | None = None) -> str:
    cols = ["scenario", "events/sec", "compile s", "while-loop iters",
            "events/superstep", "events", "identical", "telemetry",
            "AI FLOP/B", "% roofline"]
    if baseline is not None:
        cols += ["Δ events/sec", "Δ events/superstep"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for name, cell in sorted(report.items()):
        if name.startswith("_"):
            continue            # microbench sections rendered below
        eps = cell.get("events_per_sec")
        epb = cell.get("events_per_superstep")
        ident = cell.get("batched_identical",
                         cell.get("result_identical"))
        tel = cell.get("telemetry_identical")
        pct = cell.get("pct_of_roofline")
        row = [name, _fmt(eps), _fmt(cell.get("compile_s"), 1),
               _fmt(cell.get("supersteps")),
               _fmt(epb, 2), _fmt(cell.get("events")),
               "--" if ident is None else ("yes" if ident else "**NO**"),
               "--" if tel is None else ("yes" if tel else "**NO**"),
               _fmt(cell.get("arith_intensity"), 2),
               "--" if pct is None else
               f"{pct:.2g} ({cell.get('roofline_bound', '?')}-bound)"]
        if baseline is not None:
            base = baseline.get(name, {})
            row += [_delta(eps, base.get("events_per_sec")),
                    _delta(epb, base.get("events_per_superstep"))]
        lines.append("| " + " | ".join(row) + " |")
    if baseline is not None:
        lines.append("")
        lines.append("Δ columns compare against the committed artifact "
                     "(wall-clock varies with runner load; "
                     "events/superstep is deterministic).")
    rc = report.get("_rank_crossover")
    if rc:
        lines += ["", "#### In-kernel rank crossover (us per call, "
                  "[8, J] rows, XLA CPU; crossover constant J = "
                  f"{rc.get('crossover_j')})", ""]
        lines += ["| J | pairwise O(J^2) | bitonic O(J log^2 J) | "
                  "lexsort O(J log J) |", "|---|---|---|---|"]
        for k, v in sorted(rc.items(),
                           key=lambda kv: (len(kv[0]), kv[0])):
            if not k.startswith("j"):
                continue
            lines.append(
                f"| {k[1:]} | {_fmt(v.get('pairwise_o_j2'), 1)} | "
                f"{_fmt(v.get('bitonic_o_jlog2j'), 1)} | "
                f"{_fmt(v.get('lexsort_o_jlogj'), 1)} |")
    sb = report.get("_sweep_bench")
    if sb:
        lines += ["", f"#### Sweep engine ({sb.get('grid', 'grid')})",
                  "", "| path | steady wall s | compile s | supersteps |",
                  "|---|---|---|---|"]
        lines.append(
            f"| reference (batch=1, conds->selects) | "
            f"{_fmt(sb.get('wall_s_ref'), 2)} | "
            f"{_fmt(sb.get('compile_s_ref'), 1)} | "
            f"{_fmt(sb.get('supersteps_ref'))} |")
        lines.append(
            f"| select-free sweep (batch={sb.get('batch')}) | "
            f"{_fmt(sb.get('wall_s_sweep'), 2)} | "
            f"{_fmt(sb.get('compile_s_sweep'), 1)} | "
            f"{_fmt(sb.get('supersteps_sweep'))} |")
        lines += ["",
                  f"speedup **{sb.get('batch_speedup', 0):.2f}x** | "
                  f"bitwise identical: "
                  f"{'yes' if sb.get('sweep_identical') else '**NO**'} | "
                  f"sharded identical: "
                  f"{'yes' if sb.get('sharded_identical') else '**NO**'}"]
        if "batch_speedup_paper_polls" in sb:
            ident_p = sb.get("sweep_identical_paper_polls")
            lines += ["",
                      "paper-default poll rate (1 s re-poll floor): "
                      f"**{sb['batch_speedup_paper_polls']:.2f}x** | "
                      "bitwise identical: "
                      f"{'yes' if ident_p else '**NO**'}"]
        ds = sb.get("device_scaling") or {}
        if "device_speedup" in ds:
            lines += ["", "#### Device scaling (sweep_sharded, "
                      "heterogeneous-run-length lanes)",
                      "", "| devices | steady wall s | compile s |",
                      "|---|---|---|"]
            for key in ("dev1", "dev2"):
                cell = ds.get(key, {})
                lines.append(f"| {cell.get('devices', key[3:])} | "
                             f"{_fmt(cell.get('wall_s'), 2)} | "
                             f"{_fmt(cell.get('compile_s'), 1)} |")
            lines += ["",
                      f"2-device speedup "
                      f"**{ds['device_speedup']:.2f}x** | identical "
                      "across device counts: "
                      f"{'yes' if ds.get('device_identical') else '**NO**'}"]
        else:
            err = next((ds[k].get("error") for k in ("dev1", "dev2")
                        if isinstance(ds.get(k), dict)
                        and "error" in ds[k]), None)
            if err:
                lines += ["", "Device-scaling rows failed to run: "
                          f"`{err.splitlines()[-1] if err else ''}`"]
    return "\n".join(lines)


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("artifact", help="BENCH_engine.json to render")
    p.add_argument("--baseline", default=None,
                   help="optional baseline BENCH_engine.json for deltas")
    p.add_argument("--title", default="Engine throughput "
                   "(benchmarks/artifacts/BENCH_engine.json)")
    args = p.parse_args()
    report = json.load(open(args.artifact))
    baseline = json.load(open(args.baseline)) if args.baseline else None
    print(f"### {args.title}\n")
    print(render(report, baseline))


if __name__ == "__main__":
    main()
