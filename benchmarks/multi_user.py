"""Paper section 5.4 / Figures 33-38: N users competing for the WWG
fleet under DBC cost-minimisation, deadline 3100 and 10000.

Paper sweeps 1..100 users x 18 budgets (hundreds of separate runs); here
each (n_users, deadline) cell is one vectorised simulation and budgets
vmap.  User counts are a CPU-sized subset; the trend claims (fewer
completions per user under competition, deadline overshoot at 3100 due
to stale first estimates, budget tracking completions) are asserted.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import gridlet, resource, simulation, types

from .common import art_path, write_csv

USERS = [1, 5, 10, 20]
BUDGETS = [1000.0, 2000.0, 4000.0, 8000.0]
N_JOBS = 60          # per user (paper: 200; scaled for 1-core CPU wall)
# paper uses 3100/10000 with 200 jobs; with 60 jobs the equivalent
# contention points are tighter deadlines (calibrated so competition
# binds: see EXPERIMENTS.md section Repro).
DEADLINES = [400.0, 1500.0]


def run():
    fleet = resource.wwg_fleet()
    out = []
    rows = []
    for deadline in DEADLINES:
        mean_done = {}
        mean_term = {}
        for n_users in USERS:
            g = gridlet.task_farm(jax.random.PRNGKey(11), n_jobs=N_JOBS,
                                  n_users=n_users)
            t0 = time.perf_counter()
            done_b, term_b, spent_b = [], [], []
            for b in BUDGETS:
                r = simulation.run_experiment(
                    g, fleet, deadline=deadline, budget=b,
                    opt=types.OPT_COST, n_users=n_users)
                done_b.append(float(np.mean(np.asarray(r.n_done))))
                term_b.append(float(np.mean(np.asarray(r.term_time))))
                spent_b.append(float(np.mean(np.asarray(r.spent))))
                rows.append([deadline, n_users, b, done_b[-1],
                             round(spent_b[-1], 1), round(term_b[-1], 1)])
            wall = time.perf_counter() - t0
            mean_done[n_users] = float(np.mean(done_b))
            mean_term[n_users] = float(np.mean(term_b))
            out.append((f"multi_user_u{n_users}_d{deadline:.0f}",
                        wall * 1e6 / len(BUDGETS),
                        f"mean_done/user={mean_done[n_users]:.1f} "
                        f"mean_term={mean_term[n_users]:.0f}"))
        # Fig 33/36: completions per user fall with competition
        claim = all(mean_done[USERS[i + 1]] <= mean_done[USERS[i]] + 1e-6
                    for i in range(len(USERS) - 1))
        out.append((f"multi_user_claim_d{deadline:.0f}", 0.0,
                    f"monotone_decrease={claim}"))
    write_csv(art_path("fig33_38_multi_user.csv"),
              ["deadline", "n_users", "budget", "mean_done_per_user",
               "mean_spent", "mean_term_time"], rows)
    return out
