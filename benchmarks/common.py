"""Benchmark helpers: timing + artifact paths."""
from __future__ import annotations

import csv
import os
import time

import jax

ARTIFACTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts")


def art_path(*parts: str) -> str:
    p = os.path.join(ARTIFACTS, *parts[:-1])
    os.makedirs(p, exist_ok=True)
    return os.path.join(p, parts[-1])


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall-time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def write_csv(path: str, header, rows):
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
