"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Artifacts (full grids, the
roofline table) are written to benchmarks/artifacts/.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (engine_bench, multi_user, roofline, single_user,
                   table1)
    modules = [
        ("table1", table1),            # paper Table 1
        ("single_user", single_user),  # Figures 21-27
        ("multi_user", multi_user),    # Figures 33-38
        ("engine", engine_bench),      # core DES throughput
        ("roofline", roofline),        # section Roofline (from dry-run)
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the harness going
            failed += 1
            print(f"{name},0.0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
