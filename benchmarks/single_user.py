"""Paper section 5.3 / Figures 21-27: single-user DBC cost-optimisation
over the full deadline x budget grid on the WWG fleet (Table 2).

Paper: deadline 100..3600 step 500, budget 5000..22000 step 1000,
200 Gridlets of >=10,000 MI.  The whole 8 x 18 grid runs as ONE
jit+vmap'd simulation -- the "beyond-paper" speedup of the vectorised
engine (the 2002 toolkit ran each scenario as a separate JVM run).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import gridlet, resource, simulation, types

from .common import art_path, write_csv

DEADLINES = [100.0 + 500.0 * i for i in range(8)]        # 100..3600
BUDGETS = [5000.0 + 1000.0 * i for i in range(18)]       # 5000..22000
N_JOBS = 200
CHEAPEST = 8


def run():
    key = jax.random.PRNGKey(7)
    farm = gridlet.task_farm(key, n_jobs=N_JOBS)
    fleet = resource.wwg_fleet()

    t0 = time.perf_counter()
    res = simulation.sweep(farm, fleet, DEADLINES, BUDGETS,
                           opt=types.OPT_COST)
    jax.block_until_ready(res.n_done)
    wall = time.perf_counter() - t0
    cells = len(DEADLINES) * len(BUDGETS)

    n_done = np.asarray(res.n_done)[..., 0]          # [D, B]
    spent = np.asarray(res.spent)[..., 0]
    term = np.asarray(res.term_time)[..., 0]
    per_res = np.asarray(res.per_resource_done)[..., 0, :]  # [D, B, R]

    rows = []
    for i, d in enumerate(DEADLINES):
        for j, b in enumerate(BUDGETS):
            rows.append([d, b, n_done[i, j], round(float(spent[i, j]), 1),
                         round(float(term[i, j]), 1)]
                        + per_res[i, j].astype(int).tolist())
    write_csv(art_path("fig21_24_single_user_grid.csv"),
              ["deadline", "budget", "n_done", "spent", "term_time"]
              + [f"R{r}" for r in range(fleet.r)], rows)

    # ---- the paper's qualitative claims as derived checks ----
    # Fig 21: tight deadline -> completions rise with budget
    claim_a = bool(np.all(np.diff(n_done[0]) >= -1e-6)) and \
        n_done[0, -1] > n_done[0, 0]
    # Fig 22: low budget -> completions rise with deadline
    claim_b = bool(np.all(np.diff(n_done[:, 0]) >= -1e-6))
    # Fig 24: tight deadline spends (nearly) the whole budget while
    # capacity-limited
    lim = n_done[0] < N_JOBS
    claim_c = bool(np.all((spent[0][lim] / np.asarray(BUDGETS)[lim])
                          > 0.85)) if lim.any() else True
    # Fig 27: relaxed deadline -> only the cheapest resource used
    relaxed = per_res[-2]                             # deadline 3100 row
    claim_d = bool(np.all(relaxed[:, CHEAPEST] == n_done[-2])) and \
        bool(np.all(relaxed.sum(-1) == n_done[-2]))

    return [
        ("single_user_grid_144cells", wall * 1e6 / cells,
         f"claims a={claim_a} b={claim_b} c={claim_c} d={claim_d} "
         f"done[tight,minB]={n_done[0,0]:.0f} "
         f"done[relaxed,maxB]={n_done[-1,-1]:.0f}"),
    ]
